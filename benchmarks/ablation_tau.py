"""Beyond-paper ablation: staleness sweep.

Theorem IV.1 says the tau-dependent regret terms are O(tau) and
O(tau^2 log T) — sub-dominant to the sigma^2 sqrt(m) term whenever
tau <= O(m^(1/4)). We sweep tau (by varying T_c at fixed T_p) and
measure (a) per-epoch degradation at a fixed epoch count — should grow
mildly with tau; (b) wall-clock time to a fixed error — should stay
~flat for AMB-DG (updates keep flowing every T_p) while AMB's grows
linearly in T_c.

    PYTHONPATH=src python -m benchmarks.ablation_tau
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_to
from repro.configs.base import AmbdgConfig, ModelConfig, LINREG
from repro.data.timing import ShiftedExponential
from repro import api
from repro.sim import SimProblem


def run(full: bool = False):
    d = 2048 if full else 1024
    t_p = 2.5
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=d)
    results = {}
    for tau in (0, 1, 2, 4, 8, 16):
        t_c = tau * t_p
        opt = AmbdgConfig(t_p=t_p, t_c=t_c, tau=tau, smoothness_L=1.0,
                          b_bar=800.0, proximal="l2_ball",
                          radius_C=float(1.05 * np.sqrt(d)))
        tr = api.simulate(
            "ambdg", SimProblem(cfg, 10, b_max=1024, seed=7), t_p=t_p,
            t_c=t_c, total_time=60 * t_p + 0.5 * t_c + 1, timing=timing,
            opt_cfg=opt)
        err_40 = tr.errors[39] if len(tr.errors) >= 40 else float("nan")
        emit("ablation_tau", f"err_at_epoch40_tau{tau}", round(err_40, 4))
        results[tau] = err_40
    # theory check: per-epoch error degrades gracefully in tau — the
    # tau=16 run should still converge (no blow-up), and small taus
    # should be within a small factor of tau=0
    emit("ablation_tau", "tau4_over_tau0",
         round(results[4] / results[0], 2))
    emit("ablation_tau", "tau16_converges", int(results[16] < 1.0))
    return results


if __name__ == "__main__":
    run()
