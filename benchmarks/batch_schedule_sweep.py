"""Batch-schedule sweep: the sample efficiency of adaptive minibatch
targets (``core.batch_schedule``).

One seeded linreg simulator run per cell (CPU-sized, the same stable
step-size regime the convergence property test pins): a grid of FIXED
batch sizes plus the three adaptive controllers, all driving
``simulate_anytime`` through the schedule path (alpha takes b(t) in
place of the static b_bar). Columns per cell:

  * ``samples_to_target`` — total samples consumed when Err(t) first
    reaches the target (inf when the run never gets there): the
    subsystem's headline number. The refresh ASSERTS adadamp beats
    every fixed batch size in the sweep — the convergence property as
    a tracked benchmark — and that no adaptive cell regresses past
    1.25x its committed ``BENCH_batch_schedule.json`` baseline;
  * final/min error, total samples, the emitted target range — the
    shape of each schedule's trajectory.

Emits ``name,metric,value`` CSV rows (run.py contract) and writes
``BENCH_batch_schedule.json`` so the trajectory is tracked across PRs
alongside BENCH_delay.json / BENCH_elastic.json.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit
from repro.configs.base import (AmbdgConfig, BatchScheduleConfig, LINREG,
                                ModelConfig)
from repro.core.batch_schedule import make_batch_schedule
from repro.data.timing import ShiftedExponential
from repro.sim import SimProblem, simulate_anytime

DIM = 16
B_BAR = 64.0
TAU = 4
TARGET_ERR = 5e-6           # below the small-b noise floors
TOTAL_TIME = 750.0          # ~300 master updates
FIXED_SWEEP = (64, 256, 1024)
ADAPTIVE = {
    "adadamp": dict(b0=8, b_cap=1024, growth_factor=1.5, ema=0.5),
    "linear": dict(b0=8, b_cap=1024, growth_rate=4.0),
    "delay_aware": dict(b0=64, b_cap=1024, ema=0.5),
}


def _run(bs_cfg: BatchScheduleConfig):
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0,
                      d_model=0, n_heads=0, n_kv_heads=0, d_ff=0,
                      vocab_size=0, linreg_dim=DIM)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=TAU, b_bar=B_BAR,
                      smoothness_L=8.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(DIM)))
    problem = SimProblem(cfg, n_workers=4, seed=7, b_max=512)
    return simulate_anytime(
        problem, t_p=2.5, t_c=10.0, total_time=TOTAL_TIME,
        timing=ShiftedExponential(lam=2 / 3, xi=1.0, b=60),
        opt_cfg=opt, scheme="ambdg", rng_seed=11,
        batch_schedule=make_batch_schedule(bs_cfg, B_BAR, TAU))


def cell(name: str, bs_cfg: BatchScheduleConfig) -> dict:
    tr = _run(bs_cfg)
    cum = np.cumsum(tr.minibatches)
    err = np.asarray(tr.errors)
    hit = np.nonzero(err <= TARGET_ERR)[0]
    return {
        "schedule": bs_cfg.schedule, "name": name,
        "samples_to_target": (int(cum[hit[0]]) if len(hit)
                              else float("inf")),
        "total_samples": int(cum[-1]),
        "updates": len(tr.times),
        "final_error": float(err[-1]),
        "min_error": float(err.min()),
        "target_range": [int(min(tr.targets)), int(max(tr.targets))],
    }


def _committed_samples() -> dict:
    """samples_to_target of the committed BENCH_batch_schedule.json
    (the baseline the refresh is asserted against); {} when absent."""
    try:
        with open("BENCH_batch_schedule.json") as f:
            committed = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}
    return {c["name"]: c["samples_to_target"]
            for c in committed.get("cells", [])
            if c.get("samples_to_target") != float("inf")}


def main():
    baseline = _committed_samples()
    results = {"target_err": TARGET_ERR, "dim": DIM, "cells": []}
    regressions = []
    for b0 in FIXED_SWEEP:
        results["cells"].append(
            cell(f"fixed{b0}",
                 BatchScheduleConfig(schedule="fixed", b0=b0,
                                     b_cap=4096)))
    for name, kw in ADAPTIVE.items():
        results["cells"].append(
            cell(name, BatchScheduleConfig(schedule=name, **kw)))

    by_name = {c["name"]: c for c in results["cells"]}
    for c in results["cells"]:
        emit(f"bsched_{c['name']}", "samples_to_target",
             c["samples_to_target"])
        emit(f"bsched_{c['name']}", "min_error", c["min_error"])
        emit(f"bsched_{c['name']}", "total_samples", c["total_samples"])
        base = baseline.get(c["name"])
        if base is not None and c["samples_to_target"] > 1.25 * base:
            # regression wall: a schedule (or alpha-plumbing) change
            # that makes any cell need >1.25x the committed samples to
            # reach the target fails the bench job
            regressions.append((c["name"], c["samples_to_target"], base))

    # the convergence property as a tracked benchmark: adadamp reaches
    # the target with fewer total samples than EVERY fixed batch size
    ada = by_name["adadamp"]["samples_to_target"]
    for b0 in FIXED_SWEEP:
        fixed = by_name[f"fixed{b0}"]["samples_to_target"]
        if not ada < fixed:
            regressions.append((f"adadamp_vs_fixed{b0}", ada, fixed))
    if regressions:
        raise SystemExit(
            "batch-schedule sample efficiency regressed vs committed "
            f"BENCH_batch_schedule.json: {regressions}")
    with open("BENCH_batch_schedule.json", "w") as f:
        json.dump(results, f, indent=1)
    print("wrote BENCH_batch_schedule.json")


if __name__ == "__main__":
    main()
