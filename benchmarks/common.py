"""Shared benchmark utilities. Each fig*_ module reproduces one paper
artifact and prints ``name,metric,value`` CSV rows; run.py aggregates.
Scale knobs default CI-sized; pass --full for paper-scale runs."""
from __future__ import annotations

import bisect
import time
from typing import Dict, List

import numpy as np


def time_to(times, errors, tgt: float) -> float:
    for t, e in zip(times, errors):
        if e <= tgt:
            return t
    return float("inf")


def err_at(times, errors, t: float) -> float:
    i = bisect.bisect_right(times, t) - 1
    return errors[i] if i >= 0 else float("nan")


def emit(name: str, metric: str, value) -> None:
    print(f"{name},{metric},{value}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
