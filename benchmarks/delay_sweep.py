"""Delay-process sweep: the cost and behavior of stochastic staleness.

Three columns per (process x tau_max) cell, all CPU-sized (the arena
runs its pure-XLA reference path, as in CI):

  * sequence statistics of the seeded process (mean / p95 / max delay,
    fraction of zero-arrival master steps under the delivery model) —
    the shape of the traffic each process injects;
  * master-pipeline throughput of the delay-tolerant ring
    (``arena.push_pop_variable``) vs the static-phase fixed path on
    the same ~6.3M-param arena — since PR 7 the variable pop is a
    single pass over the stacked ring (the CPU reference gathers only
    the O(arrivals) due slots), so this column tracks the residual
    price of delay tolerance rather than a tau_max+1 read
    amplification. The refresh ASSERTS the per-cell slowdown never
    regresses past 1.25x the committed baseline;
  * short seeded linreg simulator runs: final Err(t) and update count
    under the process vs the fixed-tau baseline at the same wall
    clock, with the delay-adaptive step size — the Fig.-2-style
    robustness story the subsystem exists for.

Emits ``name,metric,value`` CSV rows (run.py contract) and writes
``BENCH_delay.json`` so the trajectory is tracked across PRs alongside
BENCH_master_update.json / BENCH_gossip.json.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import (AmbdgConfig, DelayConfig, LINREG,
                                ModelConfig)
from repro.core import arena
from repro.core.delay_process import make_delay_process
from repro.core.staleness import delivery_schedule
from repro.data.timing import ShiftedExponential
from repro.sim import SimProblem, simulate_anytime

TAU = 4                     # nominal staleness (the Fig-2 regime)
SEQ_LEN = 4096              # draws for the sequence statistics
# bench arena: 49152*128 ~ 6.3M params/pod — large enough that the
# per-step constant overheads of the variable path (mask metadata,
# the gather's H-switch) amortize into the row traffic being measured
ROWS = 49152


def delay_cfg(process: str, tau_max: int) -> DelayConfig:
    return DelayConfig(process=process, tau_max=tau_max, seed=7)


def sequence_stats(process: str, tau_max: int) -> dict:
    dp = make_delay_process(delay_cfg(process, tau_max), TAU)
    seq = dp.sequence(SEQ_LEN)
    sched = delivery_schedule(seq.tolist())
    horizon = len(seq)      # steps the pushes could have landed in
    arrivals = sum(1 for u in sched if u <= horizon)
    return {
        "mean": float(seq.mean()), "p95": float(np.percentile(seq, 95)),
        "max": int(seq.max()),
        "zero_arrival_frac": 1.0 - arrivals / horizon,
    }


def bench_ring(process: str, tau_max: int, iters: int = 50) -> dict:
    """steps/s of the delay-tolerant ring under the process vs the
    static fixed-tau path on the same arena size (f32, 1 pod)."""
    params = {"w": jnp.zeros((ROWS * 128,), jnp.float32)}
    layout = arena.make_layout(params)
    n_pods = 1
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                    (n_pods, ROWS * 128), jnp.float32)}
    counts = jnp.full((n_pods,), 7.0)
    dp = make_delay_process(delay_cfg(process, tau_max), TAU)
    delays = jnp.asarray(dp.sequence(iters + 8), jnp.int32)

    var_step = jax.jit(
        lambda a, g, c, d: arena.push_pop_variable(layout, a, g, c, d),
        donate_argnums=(0,))
    fix_step = jax.jit(
        lambda a, g, c: arena.push_pop(layout, a, g, c),
        donate_argnums=(0,))

    def run_var():
        ar = arena.init_arena(layout, tau_max, n_pods, variable=True)
        for i in range(4):                      # warm all phases
            gs, c, to, ar = var_step(ar, grads, counts, delays[i])
        jax.block_until_ready((gs, c, to, ar))
        t0 = time.perf_counter()
        for i in range(iters):
            gs, c, to, ar = var_step(ar, grads, counts, delays[4 + i])
        # block on EVERY step output, not just the ring: the popped
        # grad_sum/count/tau_obs are the fold work being measured —
        # async dispatch must not let them finish off the clock
        jax.block_until_ready((gs, c, to, ar))
        return iters / (time.perf_counter() - t0)

    def run_fix():
        ar = arena.init_arena(layout, tau_max, n_pods)
        for _ in range(4):
            gs, c, ar = fix_step(ar, grads, counts)
        jax.block_until_ready((gs, c, ar))
        t0 = time.perf_counter()
        for _ in range(iters):
            gs, c, ar = fix_step(ar, grads, counts)
        jax.block_until_ready((gs, c, ar))
        return iters / (time.perf_counter() - t0)

    # interleave rounds so shared-box noise hits both pipelines
    best_v = best_f = 0.0
    for _ in range(3):
        best_v = max(best_v, run_var())
        best_f = max(best_f, run_fix())
    return {"variable_steps_per_s": round(best_v, 2),
            "fixed_steps_per_s": round(best_f, 2),
            "slowdown": round(best_f / best_v, 3)}


def sim_error(process: str, tau_max: int) -> dict:
    """Final paper Err(t) of short seeded linreg runs: the process
    (delay-adaptive alpha via the sim's downlink model) vs fixed tau
    at the same wall clock."""
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=64)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=TAU, b_bar=180.0,
                      proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(64)))
    common = dict(t_p=2.5, t_c=10.0, total_time=60.0, timing=timing,
                  opt_cfg=opt, scheme="ambdg", rng_seed=11)
    problem = lambda: SimProblem(cfg, n_workers=3, seed=7, b_max=128)
    dp = make_delay_process(delay_cfg(process, tau_max), TAU)
    tr = simulate_anytime(problem(), delay_process=dp, **common)
    base = simulate_anytime(problem(), **common)
    return {"final_error": float(tr.errors[-1]),
            "updates": len(tr.times),
            "fixed_final_error": float(base.errors[-1]),
            "mean_staleness": float(np.mean(tr.staleness))}


def _committed_slowdowns() -> dict:
    """Per-cell ring slowdowns of the committed BENCH_delay.json (the
    baseline the refresh is asserted against); {} when absent."""
    try:
        with open("BENCH_delay.json") as f:
            committed = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}
    return {(c["process"], c["tau_max"]): c["ring"]["slowdown"]
            for c in committed.get("cells", [])
            if "ring" in c and "slowdown" in c["ring"]}


def main():
    baseline = _committed_slowdowns()
    regressions = []
    results = {"tau": TAU, "cells": []}
    for process in ("fixed", "jitter", "heavy_tail", "bursty"):
        for tau_max in (4, 16):
            if process == "fixed" and tau_max != TAU:
                continue
            name = f"delay_{process}_tmax{tau_max}"
            cell = {"process": process, "tau_max": tau_max,
                    "seq": sequence_stats(process, tau_max),
                    "ring": bench_ring(process, tau_max)}
            if process != "fixed":
                cell["sim"] = sim_error(process, tau_max)
            results["cells"].append(cell)
            emit(name, "seq_mean", cell["seq"]["mean"])
            emit(name, "seq_p95", cell["seq"]["p95"])
            emit(name, "zero_arrival_frac",
                 round(cell["seq"]["zero_arrival_frac"], 4))
            emit(name, "ring_steps_per_s",
                 cell["ring"]["variable_steps_per_s"])
            emit(name, "ring_slowdown_vs_fixed",
                 cell["ring"]["slowdown"])
            key = (process, tau_max)
            if key in baseline:
                # regression wall: the refreshed slowdown must stay
                # within noise (1.25x) of the committed baseline —
                # i.e. once the single-pass pop lands, a return to the
                # tau_max+1 read amplification fails the bench job
                if cell["ring"]["slowdown"] > 1.25 * baseline[key]:
                    regressions.append(
                        (name, cell["ring"]["slowdown"], baseline[key]))
            if "sim" in cell:
                emit(name, "sim_final_error", cell["sim"]["final_error"])
    if regressions:
        raise SystemExit(
            "variable-ring slowdown regressed vs committed "
            f"BENCH_delay.json: {regressions}")
    with open("BENCH_delay.json", "w") as f:
        json.dump(results, f, indent=1)
    print("wrote BENCH_delay.json")


if __name__ == "__main__":
    main()
