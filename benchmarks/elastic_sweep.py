"""Elastic-worker sweep: throughput and convergence vs churn rate,
per strategy.

For each (elastic process x churn rate) cell, seeded CPU-sized runs
of every registered strategy:

  * amb / ambdg / kbatch through the cluster simulator engines, the
    elastic worker process wired in exactly as ``api.simulate`` wires
    it (masked/rescaled anytime counts; lost k-batch jobs restart at
    the worker's next active epoch);
  * decentralized through the on-device strategy step (dense masked
    gossip fold; dead workers frozen), the same seeded process
    supplying the per-step active mask.

Reported per cell: mean alive fraction of the drawn masks, update
throughput (updates landed in the fixed simulated wall clock, or
device steps run for decentralized), and convergence (final paper
Err(t) for the simulator schemes, final loss + consensus error for
decentralized). Emits ``name,metric,value`` CSV rows (run.py
contract) and writes ``BENCH_elastic.json`` so the robustness
trajectory is tracked across PRs alongside BENCH_delay.json.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import (AmbdgConfig, ConsensusConfig,
                                ElasticConfig, LINREG, MeshConfig,
                                ModelConfig, RunConfig, TRAIN_4K)
from repro.core.worker_process import make_worker_process
from repro.data.timing import ShiftedExponential
from repro.sim import SimProblem, simulate_anytime, simulate_kbatch

DIM = 64
N_WORKERS = 4
TOTAL_TIME = 60.0
T_P, T_C, TAU = 2.5, 10.0, 4
DEC_STEPS = 16              # device steps for the decentralized cell

CFG = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                  n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                  linreg_dim=DIM)


def _elastic_cfg(process: str, churn_rate: float) -> ElasticConfig:
    if process == "churn":
        return ElasticConfig(process="churn", p_fail=churn_rate,
                             p_recover=0.5, seed=7)
    if process == "crash_restart":
        # map the rate to an MTTF of 1/rate epochs at a fixed 3-epoch
        # MTTR, so the two families sweep comparable availability
        return ElasticConfig(process="crash_restart",
                             mttf=1.0 / max(churn_rate, 1e-6),
                             mttr=3.0, seed=7)
    return ElasticConfig(process=process, seed=7)


def _opt() -> AmbdgConfig:
    return AmbdgConfig(t_p=T_P, t_c=T_C, tau=TAU, b_bar=180.0,
                       smoothness_L=1.0, proximal="l2_ball",
                       radius_C=float(1.05 * np.sqrt(DIM)))


def _problem() -> SimProblem:
    return SimProblem(CFG, n_workers=N_WORKERS, seed=7, b_max=128)


def sim_cell(scheme: str, ecfg: ElasticConfig) -> dict:
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    wp = (make_worker_process(ecfg, N_WORKERS)
          if ecfg.process != "static" else None)
    if scheme == "kbatch":
        tr = simulate_kbatch(_problem(), b_per_msg=60, K=2, t_c=T_C,
                             total_time=TOTAL_TIME, timing=timing,
                             opt_cfg=_opt(), rng_seed=11,
                             worker_process=wp,
                             t_p=T_P if wp is not None else None)
    else:
        tr = simulate_anytime(_problem(), t_p=T_P, t_c=T_C,
                              total_time=TOTAL_TIME, timing=timing,
                              opt_cfg=_opt(), scheme=scheme,
                              rng_seed=11, worker_process=wp)
    alive = (float(np.mean(tr.active)) / N_WORKERS
             if tr.active else 1.0)
    return {"updates": len(tr.times),
            "final_error": float(tr.errors[-1]) if tr.errors else None,
            "alive_frac": round(alive, 4),
            "total_minibatch": float(np.sum(tr.minibatches))}


def decentralized_cell(ecfg: ElasticConfig) -> dict:
    from repro import api
    from repro.models import build_model
    batch = 32
    rc = RunConfig(
        model=CFG,
        shape=dataclasses.replace(TRAIN_4K, seq_len=0,
                                  global_batch=batch),
        mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(tau=1, n_microbatches=2, b_bar=float(batch),
                          smoothness_L=1.0),
        strategy="decentralized",
        consensus=ConsensusConfig(topology="ring", n_workers=N_WORKERS,
                                  rounds=3, gossip_impl="dense"),
        elastic=ecfg)
    model = build_model(CFG)
    s = api.build(model, rc)
    wp = (make_worker_process(ecfg, N_WORKERS)
          if ecfg.process != "static" else None)
    state = s.init_state(jax.random.PRNGKey(0))
    step = jax.jit(s.train_step, donate_argnums=(0,))
    losses, cons, alive = [], [], []
    for t in range(DEC_STEPS):
        b = model.dummy_batch(batch, key=jax.random.PRNGKey(1000 + t))
        if wp is not None:
            active, _ = wp.step()
            b["active"] = active.astype(np.float32)
            alive.append(float(active.sum()) / N_WORKERS)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
        cons.append(float(m["consensus_error"]))
    return {"updates": DEC_STEPS,
            "final_loss": losses[-1],
            "final_consensus_error": cons[-1],
            "alive_frac": round(float(np.mean(alive)) if alive else 1.0,
                                4)}


def main():
    cells = []
    grid = [("static", 0.0), ("heterogeneous", 0.0),
            ("churn", 0.05), ("churn", 0.2), ("churn", 0.5),
            ("crash_restart", 0.05), ("crash_restart", 0.2)]
    for process, rate in grid:
        ecfg = _elastic_cfg(process, rate)
        cell = {"process": process, "churn_rate": rate, "strategies": {}}
        for scheme in ("amb", "ambdg", "kbatch"):
            cell["strategies"][scheme] = sim_cell(scheme, ecfg)
        cell["strategies"]["decentralized"] = decentralized_cell(ecfg)
        cells.append(cell)
        tag = (f"elastic_{process}" if rate == 0.0
               else f"elastic_{process}_r{rate}")
        for scheme, r in cell["strategies"].items():
            emit(tag, f"{scheme}_updates", r["updates"])
            emit(tag, f"{scheme}_alive_frac", r["alive_frac"])
            if "final_error" in r and r["final_error"] is not None:
                emit(tag, f"{scheme}_final_error",
                     round(r["final_error"], 6))
            if "final_loss" in r:
                emit(tag, f"{scheme}_final_loss",
                     round(r["final_loss"], 6))
    results = {"n_workers": N_WORKERS, "total_time": TOTAL_TIME,
               "cells": cells}
    with open("BENCH_elastic.json", "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
