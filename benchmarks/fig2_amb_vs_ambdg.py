"""Paper Fig. 2: AMB vs AMB-DG on linear regression — per-epoch error
(2a) and wall-clock error (2b) under long communication delay
(T_p = 2.5, T_c = 10, n = 10 workers, shifted-exp speeds)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, err_at, time_to
from repro.configs.base import AmbdgConfig, ModelConfig, LINREG
from repro.data.timing import ShiftedExponential
from repro import api
from repro.sim import SimProblem


def run(full: bool = False):
    d = 10_000 if full else 2048
    total = 300.0 if full else 250.0
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=d)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=800.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(d)))
    dg = api.simulate("ambdg", SimProblem(cfg, 10, b_max=1024), t_p=2.5,
                      t_c=10.0, total_time=total, timing=timing,
                      opt_cfg=opt)
    amb = api.simulate("amb", SimProblem(cfg, 10, b_max=1024), t_p=2.5,
                       t_c=10.0, total_time=total, timing=timing,
                       opt_cfg=opt)

    tgt = 0.35   # the paper's Fig-2 reference error level
    t_dg = time_to(dg.times, dg.errors, tgt)
    t_amb = time_to(amb.times, amb.errors, tgt)
    emit("fig2", "ambdg_time_to_0.35_s", round(t_dg, 1))
    emit("fig2", "amb_time_to_0.35_s", round(t_amb, 1))
    emit("fig2", "wallclock_speedup", round(t_amb / t_dg, 2))
    k = min(8, len(amb.errors) - 1)
    emit("fig2", "per_epoch8_err_ambdg", round(dg.errors[k], 4))
    emit("fig2", "per_epoch8_err_amb", round(amb.errors[k], 4))
    emit("fig2", "updates_per_100s_ambdg",
         round(100 / 2.5, 1))
    emit("fig2", "updates_per_100s_amb", round(100 / 12.5, 1))
    return {"speedup": t_amb / t_dg}


if __name__ == "__main__":
    run()
