"""Paper Fig. 3: AMB-DG vs K-batch async wall-clock convergence
(b = 60 per message, K = 10 => per-update minibatch ~ 600 in both)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_to
from repro.configs.base import AmbdgConfig, ModelConfig, LINREG
from repro.data.timing import ShiftedExponential
from repro import api
from repro.sim import SimProblem


def run(full: bool = False):
    d = 10_000 if full else 2048
    total = 300.0 if full else 250.0
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=d)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=800.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(d)))
    dg = api.simulate("ambdg", SimProblem(cfg, 10, b_max=1024), t_p=2.5,
                      t_c=10.0, total_time=total, timing=timing,
                      opt_cfg=opt)
    kb = api.simulate("kbatch", SimProblem(cfg, 10, b_max=1024),
                      b_per_msg=60, K=10, t_c=10.0, total_time=total,
                      timing=timing, opt_cfg=opt)
    tgt = 0.35
    t_dg = time_to(dg.times, dg.errors, tgt)
    t_kb = time_to(kb.times, kb.errors, tgt)
    emit("fig3", "ambdg_time_to_0.35_s", round(t_dg, 1))
    emit("fig3", "kbatch_time_to_0.35_s", round(t_kb, 1))
    emit("fig3", "speedup_vs_kbatch", round(t_kb / t_dg, 2))
    return {"speedup": t_kb / t_dg}


if __name__ == "__main__":
    run()
