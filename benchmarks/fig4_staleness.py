"""Paper Fig. 4: gradient-staleness distribution — K-batch async is
random with a tail; AMB-DG is deterministic at tau."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.base import AmbdgConfig, ModelConfig, LINREG
from repro.data.timing import PersistentWorkerSpeeds, ShiftedExponential
from repro import api
from repro.sim import SimProblem


def run(full: bool = False):
    d = 512
    total = 400.0 if full else 200.0
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=d)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=800.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(d)))
    dg = api.simulate("ambdg", SimProblem(cfg, 10, b_max=512), t_p=2.5,
                      t_c=10.0, total_time=total, timing=timing,
                      opt_cfg=opt)
    kb = api.simulate("kbatch", SimProblem(cfg, 10, b_max=512),
                      b_per_msg=60, K=10, t_c=10.0, total_time=total,
                      timing=timing, opt_cfg=opt)
    ks = np.asarray(kb.staleness)
    emit("fig4", "ambdg_staleness_fixed", dg.staleness[-1])
    emit("fig4", "kbatch_staleness_mean", round(float(ks.mean()), 2))
    emit("fig4", "kbatch_staleness_p90", float(np.percentile(ks, 90)))
    emit("fig4", "kbatch_staleness_max", int(ks.max()))
    emit("fig4", "kbatch_frac_ge_5", round(float((ks >= 5).mean()), 3))
    hist, _ = np.histogram(ks, bins=range(0, 12))
    emit("fig4", "kbatch_hist_0_11", "|".join(map(str, hist)))
    # the paper's SciNet workers straggle persistently: per-worker speeds
    # drawn once reproduce Fig. 4's heavy tail (~80% >= 5 staleness)
    kb_p = api.simulate(
        "kbatch", SimProblem(cfg, 10, b_max=512), b_per_msg=60, K=10,
        t_c=10.0, total_time=total,
        timing=PersistentWorkerSpeeds(timing, 10, seed=3), opt_cfg=opt)
    kp = np.asarray(kb_p.staleness)
    emit("fig4", "kbatch_persistent_mean", round(float(kp.mean()), 2))
    emit("fig4", "kbatch_persistent_frac_ge_5",
         round(float((kp >= 5).mean()), 3))
    return {"kbatch_mean": float(ks.mean()),
            "kbatch_persistent_frac_ge_5": float((kp >= 5).mean())}


if __name__ == "__main__":
    run()
