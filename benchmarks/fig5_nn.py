"""Paper Fig. 5: neural-network training, AMB-DG vs K-batch async.
The paper trains the 14-layer CNN on CIFAR-10 over n=4 workers with an
induced T_c = 10 s and T_p = 10 s; offline we use the same architecture
on a synthetic class-conditional image stream and compare wall-clock
loss. Both schemes share data/timing worlds."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_to
import repro.configs as C
from repro.configs.base import AmbdgConfig
from repro.data.timing import ShiftedExponential
from repro import api
from repro.sim import SimProblem


def run(full: bool = False):
    cfg = C.get_config("amb-cnn") if full else C.get_smoke_config("amb-cnn")
    total = 2000.0 if full else 250.0
    # paper Sec. VI-B: n=4 workers, T_p = T_c = 10 s, K-batch b=60 K=4
    timing = ShiftedExponential(lam=2 / 3, xi=4.0, b=60)
    opt = AmbdgConfig(t_p=10.0, t_c=10.0, tau=1, smoothness_L=4.0,
                      b_bar=240.0)

    prob = SimProblem(cfg, 4, b_max=128)
    dg = api.simulate("ambdg", prob, t_p=10.0, t_c=10.0,
                      total_time=total, timing=timing, opt_cfg=opt)
    prob_kb = SimProblem(cfg, 4, b_max=128)
    kb = api.simulate("kbatch", prob_kb, b_per_msg=60, K=4, t_c=10.0,
                      total_time=total, timing=timing, opt_cfg=opt)

    def eval_loss(problem, params):
        import jax
        import jax.numpy as jnp
        batch = problem.streams[0].next_batch(128)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        s, aux = problem.model.loss(params, batch)
        return float(s) / float(aux["count"])

    # final loss comparison at equal wall clock (both end at `total`)
    dg_loss = eval_loss(prob, dg_params := _final_params(prob, dg))
    kb_loss = eval_loss(prob_kb, _final_params(prob_kb, kb))
    emit("fig5", "ambdg_updates", len(dg.times))
    emit("fig5", "kbatch_updates", len(kb.times))
    emit("fig5", "ambdg_final_loss", round(dg_loss, 4))
    emit("fig5", "kbatch_final_loss", round(kb_loss, 4))
    emit("fig5", "ambdg_beats_kbatch", int(dg_loss <= kb_loss * 1.05))
    return {"ambdg_loss": dg_loss, "kbatch_loss": kb_loss}


def _final_params(problem, trace):
    # the simulators keep final params implicitly; re-derive via master
    # state is overkill — traces carry errors only for linreg, so for the
    # CNN we re-run the update sequence? Instead the simulate functions
    # return final params on the trace:
    return trace.final_params


if __name__ == "__main__":
    run()
