"""Paper Fig. 6: b-hat (min), b-bar (mean) per-epoch minibatch and
their ratio, across T_p — both scale ~linearly in T_p and the ratio is
bounded by a small constant (paper observed < 1.1 on SciNet; the
shifted-exp model is heavier-tailed, so the bound is larger but still
O(1) and T_p-independent)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.data.timing import ShiftedExponential


def run(full: bool = False):
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    n, epochs = 10, 200
    rng = np.random.default_rng(0)
    tps = [0.5, 1.0, 2.0, 4.0, 8.0] if not full else \
        [0.2, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0]
    b_bars, b_hats = [], []
    for tp in tps:
        totals = np.array([timing.minibatch_in(rng, n, tp).sum()
                           for _ in range(epochs)], dtype=float)
        b_bar, b_hat = totals.mean(), totals.min()
        b_bars.append(b_bar)
        b_hats.append(b_hat)
        emit("fig6", f"b_bar_Tp_{tp}", round(b_bar, 1))
        emit("fig6", f"b_hat_Tp_{tp}", round(b_hat, 1))
        emit("fig6", f"ratio_Tp_{tp}", round(b_bar / b_hat, 3))
    # linear-in-T_p check: correlation of b_bar with tp
    r = np.corrcoef(tps, b_bars)[0, 1]
    emit("fig6", "b_bar_linearity_corr", round(float(r), 4))
    ratios = np.array(b_bars) / np.array(b_hats)
    emit("fig6", "max_ratio", round(float(ratios.max()), 3))
    return {"linearity": float(r), "max_ratio": float(ratios.max())}


if __name__ == "__main__":
    run()
