"""Gossip wire-bytes benchmark: per topology x compression.

The decentralized strategy's cost on real multi-host topologies is the
per-round ppermute payload (ROADMAP: the DCN bound). This benchmark
compiles the ACTUAL shard_map gossip programs (16 virtual CPU devices,
forced at import exactly like the dry-run) and reports, per topology
(ring/torus/complete) and compression mode (none/int8):

  * measured per-round collective-permute wire bytes, parsed from the
    optimized HLO by the shared census in ``repro.launch.hlo`` (the
    rounds run under ``lax.scan``, whose body appears once in the HLO
    module — so the census IS per-round bytes, independent of r);
  * the analytic payload model (``consensus.payload_bytes_per_round``)
    — the two must agree, or the census/model has rotted;
  * the consensus error both modes reach after the SAME eq.-(24)
    round count on unit-norm messages (matched tolerance: the int8
    error-feedback path must land in the same regime, not just move
    fewer bytes);
  * wall-clock time of the r-round exchange.

Emits ``name,metric,value`` CSV rows (run.py contract) and writes
``BENCH_gossip.json`` so the payload trajectory is tracked across PRs
alongside ``BENCH_master_update.json``.
"""
from __future__ import annotations

import os

# No-clobber: a device count already pinned in XLA_FLAGS (or injected
# via REPRO_HOST_DEVICES) wins; only the bare default forces the 16
# virtual devices the topology table below needs.
from repro.launch.xla import ensure_host_platform_device_count
ensure_host_platform_device_count(default=16)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map

from benchmarks.common import emit
from repro.core import consensus
from repro.dist.sharding import gossip_specs
from repro.launch.hlo import collective_bytes

ROWS = 256          # message rows: (rows, 128) per worker, ~131 KB f32
DELTA, J = 0.05, 1.0


def bench_topology(topology: str, n: int, rows: int = ROWS) -> dict:
    Q = consensus.gossip_matrix(topology, n)
    lam2 = consensus.lambda2(Q)
    r = consensus.min_rounds(DELTA, n, J, lam2)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:n]), ("worker",))
    sp = gossip_specs().msg

    rng = np.random.default_rng(0)
    v = rng.standard_normal((n, rows, 128)).astype(np.float32)
    v = v / np.linalg.norm(v.reshape(n, -1), axis=1)[:, None, None] * J
    v = jnp.asarray(v)
    res0 = jnp.zeros_like(v)

    result = {"topology": topology, "n_workers": n, "rows": rows,
              "lambda2": round(lam2, 6), "rounds_eq24": r,
              "delta": DELTA, "modes": {}}
    for compression in ("none", "int8"):
        if compression == "int8":
            def local(x, res):
                return consensus.gossip_rounds_shard_int8(
                    x, res, "worker", topology, n, r)
        else:
            def local(x, res):
                return consensus.gossip_rounds_shard(
                    x, "worker", topology, n, r), res
        fn = jax.jit(shard_map(local, mesh=mesh, in_specs=(sp, sp),
                               out_specs=(sp, sp), check_rep=False))
        compiled = fn.lower(v, res0).compile()
        coll = collective_bytes(compiled.as_text())
        # the SPMD program text is per-device, so the census is
        # already per-worker — directly comparable to the model
        wire_per_round = coll["collective-permute"]
        analytic = consensus.payload_bytes_per_round(
            topology, n, rows, compression=compression)
        z, _ = compiled(v, res0)
        jax.block_until_ready(z)
        t0 = time.perf_counter()
        iters = 5
        for _ in range(iters):
            z, rout = compiled(v, res0)
        jax.block_until_ready(z)
        dt = (time.perf_counter() - t0) / iters
        err = float(consensus.consensus_error(
            jnp.reshape(z, (n, -1))))
        result["modes"][compression] = {
            "wire_bytes_per_round": int(wire_per_round),
            "analytic_bytes_per_round": int(analytic),
            "consensus_error_at_r": err,
            "exchange_seconds": round(dt, 6),
        }
    none_b = result["modes"]["none"]["wire_bytes_per_round"]
    int8_b = result["modes"]["int8"]["wire_bytes_per_round"]
    result["payload_reduction"] = round(none_b / int8_b, 3)
    return result


def run() -> None:
    results = []
    for topology, n in (("ring", 8), ("torus", 16), ("complete", 8)):
        r = bench_topology(topology, n)
        results.append(r)
        tag = f"gossip_{topology}"
        emit(tag, "rounds_eq24", r["rounds_eq24"])
        for mode, m in r["modes"].items():
            emit(tag, f"wire_bytes_per_round_{mode}",
                 m["wire_bytes_per_round"])
            emit(tag, f"consensus_error_{mode}",
                 round(m["consensus_error_at_r"], 6))
        emit(tag, "payload_reduction", r["payload_reduction"])
        # the acceptance gates this trajectory exists to pin: the
        # measured census matches the analytic wire model, >= 3.5x
        # payload reduction, at matched consensus-error tolerance
        for mode, m in r["modes"].items():
            assert (m["wire_bytes_per_round"]
                    == m["analytic_bytes_per_round"]), (topology, mode, m)
        assert r["payload_reduction"] >= 3.5, r
        assert (r["modes"]["int8"]["consensus_error_at_r"]
                <= 2 * DELTA), r
    with open("BENCH_gossip.json", "w") as f:
        json.dump({"results": results}, f, indent=1)


if __name__ == "__main__":
    run()
