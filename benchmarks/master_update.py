"""Master-update microbenchmark: pytree vs arena pipeline.

Times ONLY the master side of the AMB-DG step — delayed pod exchange
(ring push/pop) + count-normalization + dual-averaging update — for a
>= 10M-parameter, many-leaf tree shaped like an LM config, on CPU
(interpret-mode environment: the arena path runs its pure-XLA
reference kernels, the same code the CPU fallback uses in production).

Emits ``name,metric,value`` CSV rows (run.py contract) and writes
``BENCH_master_update.json`` so the perf trajectory is tracked across
PRs: steps/sec for the pytree path and BOTH arena ring layouts (v2
per-slot/static-phase, v1 stacked), analytic bytes/step, and two
MEASURED bytes-moved/step columns from the compiled executable —
cost_analysis' bytes-accessed, and the bytes of ``copy`` instructions
XLA:CPU inserted (the whole-ring copy-protection v2 exists to remove:
v1 pays ~3 ring copies per step for the pop-read/push-write hazard +
lax.switch, v2 compiles copy-free on the uncompressed path).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import (AmbdgConfig, LINREG, MeshConfig, ModelConfig,
                                RunConfig, TRAIN_4K)
from repro.core import ambdg, anytime, arena, delayed
from repro.launch.hlo import copy_bytes
from repro.optim import make_arena_optimizer, make_optimizer


def _lm_like_tree(key, target_params: int):
    """A many-leaf tree with LM-config-like leaf statistics: a few big
    embedding/projection matrices and hundreds of small norms/biases."""
    leaves = {}
    big = [("emb", (target_params // 4 // 1024, 1024)),
           ("head", (target_params // 4 // 1024, 1024))]
    n_layers = 48
    d = int(np.sqrt(target_params // 2 // (4 * n_layers)))
    for i in range(n_layers):
        leaves[f"l{i:02d}"] = {
            "wq": (d, d), "wo": (d, d), "w_up": (d, 2 * d),
            "norm1": (d,), "norm2": (d,), "bias": (d,),
        }
    for name, shape in big:
        leaves[name] = shape
    flat, treedef = jax.tree.flatten(
        leaves, is_leaf=lambda x: isinstance(x, tuple))
    ks = jax.random.split(key, len(flat))
    return jax.tree.unflatten(
        treedef, [jax.random.normal(k, s, jnp.float32) * 0.02
                  for k, s in zip(ks, flat)])


class _Timed:
    """One benchmarked pipeline: an AOT-compiled step (so its measured
    cost/copy stats come from the exact executable being timed) with
    its (donated) state chained across timing rounds."""

    def __init__(self, step_fn, state, grads, counts):
        lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(
            state, grads, counts)
        self.compiled = lowered.compile()
        cost = self.compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        self.bytes_accessed = int(cost.get("bytes accessed", -1))
        self.copy_bytes = copy_bytes(self.compiled.as_text())
        self.state = state

    def warm(self, grads, counts):
        for _ in range(2):
            self.state = self.compiled(self.state, grads, counts)
        jax.block_until_ready(self.state)

    def round(self, grads, counts, iters: int) -> float:
        t0 = time.perf_counter()
        for _ in range(iters):
            self.state = self.compiled(self.state, grads, counts)
        jax.block_until_ready(self.state)
        return iters / (time.perf_counter() - t0)


def _time_interleaved(pipelines, grads, counts, iters: int,
                      rounds: int = 5):
    """Alternate short rounds of all pipelines and keep each one's
    best — noise on a shared CI box hits all of them, alternation keeps
    it from biasing whichever ran later."""
    for p in pipelines:
        p.warm(grads, counts)
    best = [0.0] * len(pipelines)
    for _ in range(rounds):
        for i, p in enumerate(pipelines):
            best[i] = max(best[i], p.round(grads, counts, iters))
    return best


def bench_one(params, tau: int, n_pods: int, compression: str,
              iters: int):
    rc = RunConfig(
        model=ModelConfig(name="bench", family=LINREG, n_layers=0,
                          d_model=0, n_heads=0, n_kv_heads=0, d_ff=0,
                          vocab_size=0, linreg_dim=8),
        shape=TRAIN_4K, mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(tau=tau, pod_compression=compression))
    key = jax.random.PRNGKey(0)
    grads = jax.tree.map(
        lambda p: jax.random.normal(
            jax.random.fold_in(key, p.size % 9973),
            (n_pods,) + p.shape, jnp.float32),
        params)
    counts = jnp.full((n_pods,), 7.0)

    # --- pytree reference path (donated, as in train.loop) ---
    opt_p = make_optimizer(rc)

    def step_pytree(state, grads, counts):
        p, o, b = state
        gs, c, b = delayed.push_pop(b, grads, counts, compression)
        g = anytime.normalize(gs, c)
        p, o = opt_p.update(o, p, g)
        return p, o, b

    pytree = _Timed(step_pytree,
                    (params, opt_p.init(params),
                     delayed.init_buffer(params, tau, n_pods, compression)),
                    grads, counts)

    # --- arena path, both ring layouts ---
    layout = arena.make_layout(params)
    opt_a = make_arena_optimizer(rc, layout)

    def step_arena(state, grads, counts):
        p, o, a = state
        p, o, a, _, _ = ambdg.arena_master_update(
            layout, opt_a, p, o, a, grads, counts, compression)
        return p, o, a

    def arena_state(ring_version):
        return (params, opt_a.init(),
                arena.init_arena(layout, tau, n_pods, compression,
                                 ring_version=ring_version))

    # NB: v2's phase advances per step, so steady-state timing would
    # cycle tau+1 executables; benchmarking the phase-0 program is
    # representative (every phase compiles the same static-slot code,
    # just with different slot numbers). The AOT-compiled step keeps
    # the donated output structure == input structure for re-feeding,
    # which phase advancement would break — so the timed v2 step runs
    # with the phase pinned (the per-step work is identical).
    arena_v2 = _Timed(_pin_phase(step_arena), arena_state(2),
                      grads, counts)
    arena_v1 = _Timed(step_arena, arena_state(1), grads, counts)

    pytree_sps, v2_sps, v1_sps = _time_interleaved(
        [pytree, arena_v2, arena_v1], grads, counts, iters)

    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    elem = 1 if compression == "int8" else 4
    # analytic HBM traffic per step (reads+writes of the big buffers)
    bytes_arena = n_pods * n_params * (
        4 +          # gradient scatter write
        2 * elem +   # ring slot: pop read + push write
        (12 if compression == "int8" else 0)   # residual r/w + fed read
    ) + n_params * (4 * 4 + 4)  # z r/w + w write + popped read (+unflatten)
    bytes_pytree = bytes_arena + 4 * 4 * n_params  # z/g re-flatten+unflatten

    return {
        "n_params": n_params,
        "n_leaves": len(jax.tree.leaves(params)),
        "tau": tau, "n_pods": n_pods, "compression": compression,
        "pytree_steps_per_s": round(pytree_sps, 3),
        "arena_steps_per_s": round(v2_sps, 3),
        "arena_v1_steps_per_s": round(v1_sps, 3),
        "speedup": round(v2_sps / pytree_sps, 3),
        "speedup_vs_ring_v1": round(v2_sps / v1_sps, 3),
        "approx_bytes_per_step_arena": int(bytes_arena),
        "approx_bytes_per_step_pytree": int(bytes_pytree),
        "measured_bytes_per_step": {
            "pytree": {"bytes_accessed": pytree.bytes_accessed,
                       "copy_bytes": pytree.copy_bytes},
            "arena": {"bytes_accessed": arena_v2.bytes_accessed,
                      "copy_bytes": arena_v2.copy_bytes},
            "arena_ring_v1": {"bytes_accessed": arena_v1.bytes_accessed,
                              "copy_bytes": arena_v1.copy_bytes},
        },
    }


def _pin_phase(step_fn):
    """Keep the v2 arena's static phase fixed across timed iterations
    so the donated AOT executable can be re-fed its own output (see
    the note at the call site)."""
    def step(state, grads, counts):
        p, o, a = state
        p, o, a = step_fn((p, o, a), grads, counts)
        return p, o, a._replace(phase=state[2].phase)
    return step


def run(full: bool = False) -> None:
    target = 40_000_000 if full else 12_000_000
    iters = 10 if full else 6
    params = _lm_like_tree(jax.random.PRNGKey(0), target)
    results = []
    for compression in ("none", "int8"):
        r = bench_one(params, tau=2, n_pods=2, compression=compression,
                      iters=iters)
        results.append(r)
        tag = f"master_update_{compression}"
        emit(tag, "params", r["n_params"])
        emit(tag, "pytree_steps_per_s", r["pytree_steps_per_s"])
        emit(tag, "arena_steps_per_s", r["arena_steps_per_s"])
        emit(tag, "arena_v1_steps_per_s", r["arena_v1_steps_per_s"])
        emit(tag, "speedup", r["speedup"])
        emit(tag, "speedup_vs_ring_v1", r["speedup_vs_ring_v1"])
        emit(tag, "copy_bytes_per_step_arena",
             r["measured_bytes_per_step"]["arena"]["copy_bytes"])
        emit(tag, "copy_bytes_per_step_ring_v1",
             r["measured_bytes_per_step"]["arena_ring_v1"]["copy_bytes"])
    with open("BENCH_master_update.json", "w") as f:
        json.dump({"results": results}, f, indent=1)


if __name__ == "__main__":
    run()
