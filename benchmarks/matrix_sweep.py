"""Scenario-matrix sweep: consolidate ``repro.launch.matrix`` groups
into ``BENCH_matrix.json`` (docs/matrix.md).

XLA reads ``--xla_force_host_platform_device_count`` exactly once per
process, so the matrix's 8/64/128/512-device cells cannot share one
interpreter: this driver groups cells by device count and spawns ONE
subprocess per group, injecting the count via ``REPRO_HOST_DEVICES``
into an env whose ``XLA_FLAGS`` has been scrubbed of any count the
parent pinned (``launch.xla.without_host_device_flag`` — otherwise the
no-clobber conflict check would rightly refuse).

Every cell row carries the dry-run compile metrics (flops / bytes /
collectives / memory) plus the three HLO invariants (ring-copy
freedom, compressed DCN edges, census == analytic wire model); a cell
with a failing invariant fails the sweep. The refresh additionally
ASSERTS a regression wall on the compile-side wire metrics: per cell,
total exchange collective bytes and full-step copy bytes must stay
within 1.25x of the committed BENCH_matrix.json (compile-side numbers
are deterministic — the slack only absorbs toolchain drift).

Emits ``name,metric,value`` CSV rows (run.py contract) and rewrites
``BENCH_matrix.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import emit
from repro.launch.xla import ENV_VAR, without_host_device_flag

BENCH_PATH = "BENCH_matrix.json"
WALL = 1.25
WALLED_METRICS = ("exchange_bytes_total", "copy_bytes")


def cell_groups():
    """{device_count: [cell names]} over the full matrix (imported
    lazily: ``launch.matrix`` pulls in jax, which the subprocesses —
    not this parent — actually initialize)."""
    from repro.launch.matrix import CELLS
    groups = {}
    for c in CELLS:
        groups.setdefault(c.devices, []).append(c.name)
    return dict(sorted(groups.items()))


def run_group(devices: int, names, timeout: int) -> dict:
    env = dict(os.environ)
    env[ENV_VAR] = str(devices)
    env["XLA_FLAGS"] = without_host_device_flag(env.get("XLA_FLAGS", ""))
    env.setdefault("PYTHONPATH", "src")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out_path = f.name
    cmd = [sys.executable, "-m", "repro.launch.matrix",
           "--devices", str(devices), "--cells", ",".join(names),
           "--json", out_path]
    proc = subprocess.run(cmd, env=env, timeout=timeout,
                          capture_output=True, text=True)
    try:
        with open(out_path) as f:
            group = json.load(f)
    except (OSError, json.JSONDecodeError):
        group = {"results": [], "failures": [
            {"cell": n, "error": "group subprocess produced no output"}
            for n in names]}
    finally:
        os.unlink(out_path)
    if proc.returncode != 0 and not group["failures"]:
        group["failures"].append({
            "cell": f"group-{devices}",
            "error": f"exit {proc.returncode}: {proc.stderr[-800:]}"})
    return group


def cell_metrics(row: dict) -> dict:
    ex = row["invariants"]["exchange"]
    return {
        "exchange_bytes_total": sum(ex["census_by_dtype"].values()),
        "copy_bytes": row["copy_bytes"],
    }


def committed_metrics() -> dict:
    """{cell: metrics} of the committed BENCH_matrix.json — the wall
    baseline; {} when absent (first run)."""
    try:
        with open(BENCH_PATH) as f:
            committed = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    return {c["cell"]: cell_metrics(c) for c in committed.get("cells", [])
            if "invariants" in c}


def run(groups=None, timeout: int = 1800) -> None:
    baseline = committed_metrics()
    all_groups = cell_groups()
    selected = groups or sorted(all_groups)
    cells, failures, walls = [], [], []
    for devices in selected:
        if devices not in all_groups:
            raise SystemExit(f"no matrix cells at {devices} devices "
                             f"(groups: {sorted(all_groups)})")
        group = run_group(devices, all_groups[devices], timeout)
        cells.extend(group["results"])
        failures.extend(group["failures"])
    for row in cells:
        m = cell_metrics(row)
        emit(row["cell"], "invariants_ok", int(row["invariants"]["ok"]))
        for k, v in m.items():
            emit(row["cell"], k, v)
        base = baseline.get(row["cell"])
        if base:
            for k in WALLED_METRICS:
                if base[k] and m[k] > WALL * base[k]:
                    walls.append((row["cell"], k, m[k], base[k]))
    out = {"wall": WALL, "cells": cells, "failures": failures}
    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=1)
    n_inv = sum(1 for c in cells if not c["invariants"]["ok"])
    print(f"{len(cells)} cells, {len(failures)} failures, "
          f"{n_inv} invariant violations, {len(walls)} wall breaches "
          f"-> {BENCH_PATH}")
    # fail AFTER writing: the refreshed file is the debugging artifact
    assert not failures, failures
    assert not n_inv, [c["cell"] for c in cells
                       if not c["invariants"]["ok"]]
    assert not walls, [f"{c}:{k} {v} > {WALL}x committed {b}"
                       for c, k, v, b in walls]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", default=None,
                    help="comma-separated device counts (default: all)")
    ap.add_argument("--timeout", type=int, default=1800,
                    help="per-group subprocess timeout (s)")
    args = ap.parse_args()
    groups = ([int(g) for g in args.groups.split(",")]
              if args.groups else None)
    run(groups=groups, timeout=args.timeout)


if __name__ == "__main__":
    main()
