"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run / roofline JSON artifacts."""
from __future__ import annotations

import argparse
import json

HBM = 16e9


def dryrun_table(path: str, title: str) -> str:
    d = json.load(open(path))
    out = [f"### {title}", "",
           "| arch | shape | per-dev FLOPs* | HBM args | HBM temp | fits 16G | collective wire bytes/dev* | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in d["results"]:
        m = r["memory"]
        coll = sum(v for k, v in r["collectives"].items() if k != "count")
        tot = (m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['flops']:.3g} "
            f"| {m['argument_bytes']/1e9:.2f} G | {m['temp_bytes']/1e9:.2f} G "
            f"| {'yes' if tot < HBM else 'NO'} | {coll/1e6:.1f} MB "
            f"| {r['compile_s']} |")
    if d["failures"]:
        out.append("")
        out.append(f"**{len(d['failures'])} FAILURES**: " + "; ".join(
            f"{f['arch']}/{f['shape']}" for f in d["failures"]))
    out.append("")
    out.append("*while-loop bodies counted once by XLA — see §Roofline "
               "methodology for the corrected per-step numbers.")
    return "\n".join(out)


def roofline_table(path: str) -> str:
    d = json.load(open(path))
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | bound s | MODEL_FLOPs/dev | useful ratio | "
           "roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in d["rows"]:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['bound_s']:.2e} "
            f"| {r['model_flops_per_device']:.3g} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    if d.get("failures"):
        out.append("")
        out.append(f"**{len(d['failures'])} FAILURES**: " + "; ".join(
            f"{f['arch']}/{f['shape']}" for f in d["failures"]))
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-single", default="dryrun_single_pod.json")
    ap.add_argument("--dryrun-multi", default="dryrun_multi_pod.json")
    ap.add_argument("--roofline", default=None)
    args = ap.parse_args()
    print(dryrun_table(args.dryrun_single, "Single pod (16x16 = 256 chips)"))
    print()
    try:
        print(dryrun_table(args.dryrun_multi,
                           "Multi-pod (2x16x16 = 512 chips)"))
    except FileNotFoundError:
        print("(multi-pod sweep pending)")
    if args.roofline:
        print()
        print(roofline_table(args.roofline))
