"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run / roofline JSON artifacts, plus the per-strategy registry
table (one row per registered Strategy)."""
from __future__ import annotations

import argparse
import json

HBM = 16e9


def strategy_table() -> str:
    """One row per registered strategy, straight from the live
    registry — scheme, staleness schedule kind, and the timeline
    model's epoch duration at the paper's reference (T_p=2.5,
    T_c=10)."""
    from repro import api
    out = ["### Strategies", "",
           "| strategy | staleness | epoch duration (T_p=2.5, T_c=10) "
           "| timeline |",
           "|---|---|---|---|"]
    for name in api.available_strategies():
        cls = api.get_strategy(name)
        tm = cls.timeline_model()
        if tm.event_driven:
            dur, timeline = "event-driven", "arrival heap (simulator)"
        else:
            dur = f"{tm.epoch_duration(2.5, 10.0):g} s"
            timeline = f"t-th update at {tm.update_time(3, 2.5, 10.0):g} s (t=3)"
        out.append(f"| {name} | {cls.schedule_summary} | {dur} "
                   f"| {timeline} |")
    out.append("")
    return "\n".join(out)


def dryrun_table(path: str, title: str) -> str:
    d = json.load(open(path))
    out = [f"### {title}", "",
           "| arch | shape | strategy | per-dev FLOPs* | HBM args | HBM temp | fits 16G | collective wire bytes/dev* | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in d["results"]:
        m = r["memory"]
        coll = sum(v for k, v in r["collectives"].items() if k != "count")
        tot = (m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('strategy', 'ambdg')} "
            f"| {r['flops']:.3g} "
            f"| {m['argument_bytes']/1e9:.2f} G | {m['temp_bytes']/1e9:.2f} G "
            f"| {'yes' if tot < HBM else 'NO'} | {coll/1e6:.1f} MB "
            f"| {r['compile_s']} |")
    if d["failures"]:
        out.append("")
        out.append(f"**{len(d['failures'])} FAILURES**: " + "; ".join(
            f"{f['arch']}/{f['shape']}" for f in d["failures"]))
    out.append("")
    out.append("*while-loop bodies counted once by XLA — see §Roofline "
               "methodology for the corrected per-step numbers.")
    return "\n".join(out)


def roofline_table(path: str) -> str:
    d = json.load(open(path))
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | bound s | MODEL_FLOPs/dev | useful ratio | "
           "roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in d["rows"]:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['bound_s']:.2e} "
            f"| {r['model_flops_per_device']:.3g} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    if d.get("failures"):
        out.append("")
        out.append(f"**{len(d['failures'])} FAILURES**: " + "; ".join(
            f"{f['arch']}/{f['shape']}" for f in d["failures"]))
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-single", default="dryrun_single_pod.json")
    ap.add_argument("--dryrun-multi", default="dryrun_multi_pod.json")
    ap.add_argument("--roofline", default=None)
    args = ap.parse_args()
    print(strategy_table())
    print()
    print(dryrun_table(args.dryrun_single, "Single pod (16x16 = 256 chips)"))
    print()
    try:
        print(dryrun_table(args.dryrun_multi,
                           "Multi-pod (2x16x16 = 512 chips)"))
    except FileNotFoundError:
        print("(multi-pod sweep pending)")
    if args.roofline:
        print()
        print(roofline_table(args.roofline))
