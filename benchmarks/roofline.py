"""Roofline analysis (deliverable g).

For each (arch x shape) cell on the single-pod mesh, derive the three
roofline terms:

    compute    = FLOPs_per_device / 197e12           [bf16 TFLOP/s]
    memory     = bytes_per_device / 819e9            [HBM GB/s]
    collective = wire_bytes_per_device / 50e9        [ICI GB/s/link]

Methodology note (CPU dry-run environment): XLA's cost_analysis counts a
while-loop body ONCE, so a scanned-layers program under-reports by ~L x
n_microbatches. We therefore lower each cell twice at reduced depth
(L0 and 2*L0 layer units) with scans fully UNROLLED and one microbatch,
measure (flops, bytes, collectives) exactly, and extrapolate:

    per_layer = f(2*L0) - f(L0);   outside = f(L0) - L0 * per_layer
    total     = outside + L_full * per_layer, then x n_microbatches

The layer "unit" respects each family's period (zamba2: shared-attn
group of 6; xlstm: slstm_every pair; encdec: enc+dec pair). MODEL_FLOPS
(6*N*D / 6*N_active*D) is computed analytically for the waste ratio.
Memory-fit numbers come from the FULL-depth dry-run compile (scans
rolled), recorded separately in EXPERIMENTS.md §Dry-run.
"""
# No-clobber: a device count already pinned in XLA_FLAGS (or injected
# via REPRO_HOST_DEVICES) wins; only the bare default forces 512.
from repro.launch.xla import ensure_host_platform_device_count
ensure_host_platform_device_count(default=512)

import argparse
import dataclasses
import json
import sys
from typing import Dict, Optional, Tuple

import jax

import repro.configs as C
from repro.configs.base import (AmbdgConfig, ENCDEC, HYBRID, SSM,
                                ModelConfig, RunConfig, SHAPES)
from repro.launch import dryrun as dr
from repro.launch.mesh import make_mesh, mesh_config

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s/link
N_CHIPS = 256


def layer_unit(cfg: ModelConfig) -> int:
    if cfg.family == HYBRID:
        return cfg.shared_attn_every
    if cfg.family == SSM:
        return cfg.xlstm.slstm_every
    return 1


def with_depth(cfg: ModelConfig, units: int) -> ModelConfig:
    u = layer_unit(cfg)
    kw = {"n_layers": units * u, "scan_unroll": True}
    if cfg.family == ENCDEC:
        kw["n_encoder_layers"] = units * u
    return dataclasses.replace(cfg, **kw)


def measure(cfg: ModelConfig, shape_name: str, n_mb: int = 1,
            tau: int = 1) -> Dict:
    """Lower+compile one reduced-depth cell; return raw counters."""
    rc = RunConfig(model=cfg, shape=SHAPES[shape_name],
                   mesh=mesh_config(False),
                   ambdg=AmbdgConfig(tau=tau, n_microbatches=n_mb),
                   remat="none")
    mesh = make_mesh(rc.mesh)
    if rc.shape.kind == "train":
        lowered = dr.lower_train(rc, mesh)
    elif rc.shape.kind == "prefill":
        lowered = dr.lower_prefill(rc, mesh)
    else:
        lowered = dr.lower_serve(rc, mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = dr.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": sum(v for k, v in coll.items() if k != "count"),
        "coll_by_type": coll,
    }


def extrapolate(cfg_full: ModelConfig, shape_name: str,
                n_mb_full: int = 8, u0: int = 1) -> Dict:
    """Two reduced-depth unrolled lowerings -> full-depth estimate."""
    u = layer_unit(cfg_full)
    total_units = cfg_full.n_layers // u
    f1 = measure(with_depth(cfg_full, u0), shape_name)
    f2 = measure(with_depth(cfg_full, 2 * u0), shape_name)
    out = {}
    kind = SHAPES[shape_name].kind
    mb_scale = n_mb_full if kind == "train" else 1
    # a train step at n_mb microbatches does the same total work as one
    # full-batch pass (we measure n_mb=1 at full batch)
    for key in ("flops", "bytes", "coll"):
        per = (f2[key] - f1[key]) / u0
        outside = f1[key] - u0 * per
        total = outside + total_units * per
        if total <= 0 or per < 0:
            # fusion differences between the two depths can make the
            # finite difference noisy; fall back to proportional
            # scaling from the deeper measurement (upper-bounds the
            # fixed part, conservative for the roofline)
            total = f2[key] * total_units / (2 * u0)
            per = f2[key] / (2 * u0)
            outside = 0.0
        out[key] = total
        out[f"{key}_per_unit"] = per
        out[f"{key}_outside"] = outside
    out["coll_by_type_2u"] = f2["coll_by_type"]
    return out


def model_flops(cfg: ModelConfig, shape) -> Tuple[float, float]:
    """(MODEL_FLOPS 6*N*D, active variant) global per step/token batch."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    n_total = cfg.n_params()
    n_active = cfg.n_active_params()
    return mult * n_total * tokens, mult * n_active * tokens


def roofline_terms(est: Dict, cfg: ModelConfig, shape) -> Dict:
    compute_s = est["flops"] / PEAK_FLOPS
    memory_s = est["bytes"] / HBM_BW
    coll_s = est["coll"] / ICI_BW
    dominant = max((compute_s, "compute"), (memory_s, "memory"),
                   (coll_s, "collective"))[1]
    mf_total, mf_active = model_flops(cfg, shape)
    mf_per_device = mf_active / N_CHIPS
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops_per_device": mf_per_device,
        "useful_ratio": (mf_per_device / est["flops"]
                         if est["flops"] else float("nan")),
        "bound_s": max(compute_s, memory_s, coll_s),
        "roofline_fraction": (mf_per_device / PEAK_FLOPS) /
                             max(compute_s, memory_s, coll_s)
                             if max(compute_s, memory_s, coll_s) else 0.0,
    }


def run_cell(arch: str, shape_name: str, n_mb: int = 8,
             cfg: Optional[ModelConfig] = None) -> Dict:
    cfg = cfg or C.get_config(arch)
    shape = SHAPES[shape_name]
    if cfg.family in (SSM, HYBRID):
        # time-scan families: unrolling the SSD/mLSTM chunk loops makes
        # the measurement compile impractically slow on one CPU core;
        # use analytic FLOPs (the time-scan is FLOP-dominated by its
        # within-chunk matmuls, captured by 6*N*D) and the rolled
        # compile's bytes/collectives as LOWER BOUNDS (while bodies
        # counted once) — flagged in the row.
        est = measure(dataclasses.replace(cfg, scan_unroll=False),
                      shape_name, n_mb=1)
        mf_total, mf_active = model_flops(cfg, shape)
        remat_mult = 4.0 / 3.0 if (shape.kind == "train" and
                                   cfg.block_remat == "full") else 1.0
        est = {"flops": mf_active / N_CHIPS * remat_mult,
               "bytes": est["bytes"], "coll": est["coll"],
               "coll_by_type_2u": est["coll_by_type"],
               "methodology": "analytic-flops+rolled-lower-bounds"}
    else:
        est = extrapolate(cfg, shape_name, n_mb_full=n_mb)
        est["methodology"] = "unrolled-L-extrapolation"
    terms = roofline_terms(est, cfg, shape)
    row = {"arch": arch, "shape": shape_name,
           "methodology": est["methodology"], **{
        k: (round(v, 6) if isinstance(v, float) else v)
        for k, v in {**est, **terms}.items()
        if k not in ("coll_by_type_2u", "methodology")}}
    row["coll_by_type"] = est["coll_by_type_2u"]
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in C.ARCH_IDS:
            for shape in C.applicable_shapes(arch):
                cells.append((arch, shape.name))
    else:
        cells.append((args.arch, args.shape))

    rows, failures = [], []
    for arch, shape in cells:
        try:
            row = run_cell(arch, shape)
            rows.append(row)
            print(json.dumps(row))
        except Exception as e:  # noqa: BLE001
            failures.append({"arch": arch, "shape": shape,
                             "error": repr(e)[:300]})
            print(f"FAIL {arch} {shape}: {e!r}", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "failures": failures}, f, indent=1)
    print(f"{len(rows)} ok, {len(failures)} failed")


if __name__ == "__main__":
    main()
