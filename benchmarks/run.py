"""Benchmark aggregator: one module per paper artifact.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,metric,value`` CSV. The roofline sweep (benchmarks/
roofline.py) and the dry-run (repro.launch.dryrun) are separate entry
points because they force a 512-device platform.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (slow); default is CI scale")
    args, _ = ap.parse_known_args()

    from benchmarks import (ablation_tau, fig2_amb_vs_ambdg, fig3_kbatch,
                            fig4_staleness, fig5_nn, fig6_bbar,
                            master_update)
    modules = [
        ("fig2", fig2_amb_vs_ambdg),
        ("fig3", fig3_kbatch),
        ("fig4", fig4_staleness),
        ("fig5", fig5_nn),
        ("fig6", fig6_bbar),
        ("ablation_tau", ablation_tau),
        ("master_update", master_update),
    ]
    print("name,metric,value")
    failed = []
    for name, mod in modules:
        try:
            mod.run(full=args.full)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
