"""Serve-load benchmark: continuous batching under seeded Poisson
traffic with the bounded-staleness publish channel attached.

Two cells -> BENCH_serve.json:

  * load — qwen1.5-0.5b smoke engine driven by the seeded open-loop
    arrival process, stand-in master publishing on its own clock:
    requests/s (completed requests over wall time), p50/p99 request
    latency in ms (submit -> completion), decode tok/s, and the
    observed publish staleness (mean/max over pops — every served
    snapshot must satisfy the bound).
  * quality — train-while-serve on linreg through the REAL train-loop
    publish hook (rc.serve.publish_period > 0): after training, every
    live ring snapshot is dequantized and scored on a fixed eval
    batch, giving loss as a function of observed staleness. Stale
    served weights must track the master: the worst in-bound snapshot
    stays within a small factor of the final master loss.

Regression wall (mirrors delay_sweep): requests/s is higher-better, so
the run fails when it drops below committed/1.25 of the checked-in
BENCH_serve.json.

    PYTHONPATH=src python -m benchmarks.serve_load
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import numpy as np

import repro.configs as C
from benchmarks.common import emit
from repro.configs.base import (LINREG, AmbdgConfig, MeshConfig,
                                ModelConfig, RunConfig, ServeConfig,
                                TRAIN_4K)
from repro.core.arena import make_layout
from repro.models import build_model
from repro.serve import Engine, RequestQueue, WeightPublisher

ARCH = "qwen1.5-0.5b"
ROUNDS = 3            # best-of over measured rounds (interleave-free:
STEPS = 64            # one warm engine, requests/s is per-round best)
WARMUP = 16


def _load_cell():
    """Throughput/latency under Poisson load on the smoke LM."""
    cfg = C.get_smoke_config(ARCH)
    model = build_model(cfg)
    sc = ServeConfig(slots=4, max_len=48, max_new=8,
                     arrival="poisson", arrival_rate=0.7,
                     publish_period=4, staleness_bound=8,
                     prompt_len_min=4, prompt_len_max=10, seed=3)
    engine = Engine(model, sc.slots, sc.max_len, seed=sc.seed)
    queue = RequestQueue(sc, cfg.vocab_size)
    publisher = WeightPublisher(make_layout(engine.params), sc)
    engine.attach_publisher(publisher)

    submit_step = {}
    latencies = []

    def run_steps(t0, n, record):
        done = len(engine.completions)
        for t in range(t0, t0 + n):
            if t % sc.publish_period == 0:
                # stand-in master on the publish clock; refresh on a
                # coprime clock so observed staleness actually varies
                publisher.publish(engine.params, t)
            if t % 6 == 0:
                engine.refresh_weights(t)
            prev = queue.next_rid
            queue.step()
            for rid in range(prev, queue.next_rid):
                submit_step[rid] = t
            engine.step(queue)
            if record:
                for rid, _toks in engine.completions[done:]:
                    latencies.append(t - submit_step[rid])
                done = len(engine.completions)
        return t0 + n

    t = run_steps(0, WARMUP, record=False)     # compile + fill slots
    best_rps, step_s = 0.0, float("inf")
    for _ in range(ROUNDS):
        done0 = len(engine.completions)
        wall = time.perf_counter()
        t = run_steps(t, STEPS, record=True)
        wall = time.perf_counter() - wall
        completed = len(engine.completions) - done0
        best_rps = max(best_rps, completed / wall)
        step_s = min(step_s, wall / STEPS)

    s = engine.stats
    lat_ms = np.asarray(latencies, np.float64) * step_s * 1e3
    cell = {
        "requests_per_s": round(best_rps, 3),
        "latency_p50_ms": round(float(np.percentile(lat_ms, 50)), 2),
        "latency_p99_ms": round(float(np.percentile(lat_ms, 99)), 2),
        "decode_tok_per_s": round(s.decode_tokens / (s.steps * step_s), 1),
        "completed": len(engine.completions),
        "staleness_mean": round(s.staleness_mean(), 3),
        "staleness_max": int(s.staleness_max),
        "staleness_bound": sc.staleness_bound,
        "publish_pops": int(s.publish_pops),
    }
    assert 0 <= s.staleness_max <= sc.staleness_bound, \
        "served snapshot violated the staleness bound"
    return cell


def _quality_cell():
    """loss(w_served) vs loss(w_master) across observed staleness, with
    the snapshots produced by the actual train-loop publish hook."""
    from repro.train.loop import LoopConfig, train

    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0,
                      d_model=0, n_heads=0, n_kv_heads=0, d_ff=0,
                      vocab_size=0, linreg_dim=64)
    n_steps = 24
    rc = RunConfig(model=cfg,
                   shape=dataclasses.replace(TRAIN_4K, seq_len=32,
                                             global_batch=16),
                   mesh=MeshConfig(n_pods=1, data=1, model=1),
                   ambdg=AmbdgConfig(tau=2, n_microbatches=2,
                                     b_bar=16.0, smoothness_L=8.0),
                   serve=ServeConfig(publish_period=2,
                                     staleness_bound=6))
    model = build_model(cfg)
    out = train(model, rc, LoopConfig(n_steps=n_steps, n_workers=4,
                                      samples_per_worker=4,
                                      log_every=100))
    pub = out["publisher"]
    assert pub is not None and pub.seq > 0, "publish hook never fired"

    batch = model.dummy_batch(64, 0, key=jax.random.PRNGKey(123))

    def eval_loss(params):
        loss_sum, aux = model.loss(params, batch)
        return float(loss_sum) / float(aux["count"])

    from repro.train.loop import _served_params
    master = eval_loss(_served_params(out["state"], rc.strategy))

    # every live ring snapshot, scored: loss vs observed staleness
    by_stale = {}
    for k in range(pub.n_slots):
        if pub.pub_step[k] < 0:
            continue
        stale = n_steps - int(pub.pub_step[k])
        if stale > rc.serve.staleness_bound:
            continue
        w = pub._dequantize(pub.ring[k], pub.scales[k])
        by_stale[stale] = eval_loss(w)

    worst = max(by_stale.values())
    cell = {"loss_master": round(master, 5),
            "loss_by_staleness": {str(k): round(v, 5)
                                  for k, v in sorted(by_stale.items())},
            "worst_served_over_master": round(worst / master, 3)}
    # the delayed-consumer contract: in-bound snapshots track the
    # master (loose wall — smoke runs, int8 wire, tau=2 dynamics)
    assert worst <= 2.0 * master + 1e-6, \
        f"stale served loss {worst} far from master {master}"
    return cell


def _committed_requests_per_s():
    try:
        with open("BENCH_serve.json") as f:
            return json.load(f)["load"]["requests_per_s"]
    except (FileNotFoundError, KeyError, json.JSONDecodeError):
        return None


def main():
    load = _load_cell()
    for k in ("requests_per_s", "latency_p50_ms", "latency_p99_ms",
              "decode_tok_per_s", "staleness_mean", "staleness_max"):
        emit("serve_load", k, load[k])

    quality = _quality_cell()
    emit("serve_load", "loss_master", quality["loss_master"])
    for k, v in quality["loss_by_staleness"].items():
        emit("serve_load", f"loss_at_staleness_{k}", v)
    emit("serve_load", "worst_served_over_master",
         quality["worst_served_over_master"])

    committed = _committed_requests_per_s()
    results = {"load": load, "quality": quality}
    with open("BENCH_serve.json", "w") as f:
        json.dump(results, f, indent=1)
    print("wrote BENCH_serve.json")

    if committed is not None and load["requests_per_s"] < committed / 1.25:
        raise SystemExit(
            f"serve throughput regression: {load['requests_per_s']} "
            f"req/s vs committed {committed} (wall: committed/1.25 = "
            f"{committed / 1.25:.3f})")
    return results


if __name__ == "__main__":
    main()
