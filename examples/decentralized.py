"""Decentralized AMB-DG (paper Sec. V): no master — workers gossip
z + g over a topology and each applies its own dual-averaging update,
now through the Strategy API:

    PYTHONPATH=src python examples/decentralized.py [--topology ring]

Shows: the gossip matrix's spectral gap, the eq.-(24) round bound
computed from the config, and that the on-device decentralized
strategy (per-worker duals in arena layout; ``lax.ppermute`` gossip
under shard_map when the device count allows, the bit-identical dense
fold otherwise) converges with consensus error below delta.
"""
import argparse
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

import repro.api as api
from repro.configs.base import (AmbdgConfig, ConsensusConfig, LINREG,
                                MeshConfig, ModelConfig, RunConfig,
                                TRAIN_4K)
from repro.data.synthetic import make_stream
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="ring",
                    choices=("ring", "torus", "complete"))
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=60)
    ap.add_argument("--compression", default="none",
                    choices=("none", "int8"),
                    help="int8: ~3.9x less gossip payload per round "
                         "(per-row scales + error feedback)")
    args = ap.parse_args()

    n, d = args.workers, 256
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=d)
    model = build_model(cfg)
    batch_size = 32 * n
    rc = RunConfig(
        model=cfg,
        shape=dataclasses.replace(TRAIN_4K, seq_len=0,
                                  global_batch=batch_size),
        mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(tau=1, n_microbatches=2, smoothness_L=1.0,
                          b_bar=float(batch_size), proximal="l2_ball",
                          radius_C=float(1.1 * np.sqrt(d))),
        strategy="decentralized",
        consensus=ConsensusConfig(topology=args.topology, n_workers=n,
                                  delta=0.05, msg_norm_J=1.0,
                                  compression=args.compression))

    strategy = api.build(model, rc)
    sched = strategy.staleness_schedule()
    print(f"{args.topology} Q: lambda2={strategy.lam2:.4f}; "
          f"eq.(24) rounds for delta={rc.consensus.delta}: "
          f"r={strategy.rounds}")
    from repro.core.consensus import payload_bytes_per_round
    rows = strategy.layout.rows
    print(f"gossip impl: {strategy.gossip_impl} "
          f"({jax.device_count()} device(s)); schedule: {sched.kind}; "
          f"compression: {args.compression} "
          f"({payload_bytes_per_round(args.topology, n, rows, compression=args.compression)} "
          f"wire bytes/worker/round)")

    state = strategy.init_state(jax.random.PRNGKey(rc.seed))
    step = jax.jit(strategy.train_step, donate_argnums=(0,))
    stream = make_stream(cfg, seed=0, sample_seed=100)   # fixed w_star
    err = float("inf")
    for epoch in range(1, args.epochs + 1):
        batch = jax.tree.map(jnp.asarray, stream.next_batch(batch_size))
        state, m = step(state, batch)
        if epoch % 10 == 0:
            # paper eq. (28): mean over workers of ||w_i - w*||^2 /
            # ||w*||^2 (every worker holds its own parameters)
            w = np.asarray(state.params["w"])          # (n, d)
            err = float(np.mean(np.sum((w - stream.w_star) ** 2, -1)
                                / np.sum(stream.w_star ** 2)))
            print(f"epoch {epoch:3d}: mean err={err:.4f} "
                  f"consensus err={float(m['consensus_error']):.5f} "
                  f"(delta={rc.consensus.delta})")
    assert err < 0.05, "decentralized AMB-DG failed to converge"
    print("converged; consensus error stayed bounded")


if __name__ == "__main__":
    main()
