"""Decentralized AMB-DG (paper Sec. V): no master — workers gossip
z + g over a ring and each applies its own dual-averaging update.

    PYTHONPATH=src python examples/decentralized.py

Shows: gossip matrix spectral gap, the eq.-(24) round bound, and that
the decentralized scheme converges with consensus error below delta.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import AmbdgConfig
from repro.core import consensus
from repro.core import dual_averaging as da


def main():
    n, d = 8, 256
    rng = np.random.default_rng(0)
    w_star = rng.standard_normal(d).astype(np.float32)

    Q = consensus.gossip_matrix("ring", n)
    lam2 = consensus.lambda2(Q)
    J, delta = 1.0, 0.05
    r = consensus.min_rounds(delta, n, J, lam2)
    print(f"ring Q: lambda2={lam2:.4f}; eq.(24) rounds for delta={delta}: r={r}")

    opt = AmbdgConfig(tau=1, smoothness_L=1.0, b_bar=256.0,
                      proximal="l2_ball", radius_C=float(1.1 * np.sqrt(d)))
    # per-worker dual variables; all start at 0
    z = jnp.zeros((n, d))
    t = 0
    w = jnp.zeros((n, d))
    for epoch in range(1, 41):
        t += 1
        # each worker computes a local anytime minibatch gradient
        b = rng.integers(100, 300, size=n)
        msgs = []
        for i in range(n):
            x = rng.standard_normal((b[i], d)).astype(np.float32)
            y = x @ w_star
            g_i = x.T @ (x @ np.asarray(w[i]) - y)          # sum of grads
            msgs.append((g_i, b[i]))
        total_b = sum(bi for _, bi in msgs)
        # message m_i = n * b_i * (z_i + g_i/b_i); consensus ~ b(t)[z-bar + g]
        m0 = jnp.stack([
            n * (z[i] * bi + jnp.asarray(gi)) / total_b
            for i, (gi, bi) in enumerate(msgs)])
        m_r = consensus.run_consensus(m0, Q, r)
        z = m_r                                             # z_i(t+1)
        a = da.alpha(jnp.float32(t + 1), opt)
        w = jnp.stack([da.prox_step({"w": z[i]}, a, opt)["w"]
                       for i in range(n)])
        if epoch % 10 == 0:
            err = float(jnp.mean(jnp.sum((w - w_star[None]) ** 2, -1)
                                 / np.sum(w_star ** 2)))
            ce = float(consensus.consensus_error(z))
            print(f"epoch {epoch:3d}: mean err={err:.4f} "
                  f"consensus err={ce:.5f} (delta={delta})")
    assert err < 0.05, "decentralized AMB-DG failed to converge"
    print("converged; consensus error stayed bounded")


if __name__ == "__main__":
    main()
