"""Reproduce the paper's Sec. VI-A linear-regression comparison
(Fig. 2 + Fig. 3): AMB-DG vs AMB vs K-batch async under long
communication delay, in the event-driven cluster simulator.

    PYTHONPATH=src python examples/linreg_paper.py [--dim 2048]

Prints wall-clock error traces and the headline speedups (paper: ~3x
over AMB, ~1.5x over K-batch async).
"""
import argparse
import bisect

import numpy as np

from repro.configs.base import AmbdgConfig, ModelConfig, LINREG
from repro.data.timing import ShiftedExponential
from repro import api
from repro.sim import SimProblem


def time_to(tr, tgt):
    for t, e in zip(tr.times, tr.errors):
        if e <= tgt:
            return t
    return float("inf")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=2048)
    ap.add_argument("--total-time", type=float, default=250.0)
    args = ap.parse_args()

    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=args.dim)
    # paper constants: n=10, T_p=2.5, T_c=10 (tau=4), shifted-exp workers
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=800.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(args.dim)))

    runs = {}
    runs["ambdg"] = api.simulate(
        "ambdg", SimProblem(cfg, 10, b_max=1024), t_p=2.5, t_c=10.0,
        total_time=args.total_time, timing=timing, opt_cfg=opt)
    runs["amb"] = api.simulate(
        "amb", SimProblem(cfg, 10, b_max=1024), t_p=2.5, t_c=10.0,
        total_time=args.total_time, timing=timing, opt_cfg=opt)
    runs["kbatch"] = api.simulate(
        "kbatch", SimProblem(cfg, 10, b_max=1024), b_per_msg=60, K=10,
        t_c=10.0, total_time=args.total_time, timing=timing, opt_cfg=opt)

    for name, tr in runs.items():
        head = " ".join(f"{e:.3f}" for e in tr.errors[:8])
        print(f"{name:7s} updates={len(tr.times):3d} errs: {head} ...")
    for tgt in (0.5, 0.35, 0.1):
        ts = {k: time_to(tr, tgt) for k, tr in runs.items()}
        print(f"time to err {tgt:4.2f}: "
              + "  ".join(f"{k}={v:6.1f}s" for k, v in ts.items())
              + f"   speedup vs AMB: {ts['amb']/ts['ambdg']:.2f}x"
              + f", vs K-batch: {ts['kbatch']/ts['ambdg']:.2f}x")
    st = np.array(runs["kbatch"].staleness)
    print(f"K-batch staleness: mean={st.mean():.2f} p90={np.percentile(st,90):.0f}"
          f" | AMB-DG staleness: fixed tau={runs['ambdg'].staleness[-1]}")


if __name__ == "__main__":
    main()
