"""Quickstart: train a small LM with AMB-DG on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API end to end: config -> model -> strategy
(``repro.api.build``: anytime accumulation + delayed gradients + dual
averaging for the default "ambdg") -> loop.
"""
import jax

import repro.configs as C
from repro.configs.base import AmbdgConfig, MeshConfig, RunConfig, TRAIN_4K
import dataclasses
import repro.api as api
from repro.models import build_model
from repro.data import TokenStream


def main():
    cfg = C.get_smoke_config("qwen3-1.7b")      # reduced same-family config
    model = build_model(cfg)

    rc = RunConfig(
        model=cfg,
        shape=dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=16),
        mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(tau=2, n_microbatches=4, b_bar=16.0,
                          smoothness_L=8.0),
        strategy="ambdg",                       # the Strategy registry id
        optimizer="dual_averaging",             # the paper's workhorse
    )
    strategy = api.build(model, rc)             # one front-end, any variant
    state = strategy.init_state(jax.random.PRNGKey(0))
    step = jax.jit(strategy.train_step, donate_argnums=(0,))

    stream = TokenStream(cfg, seed=0)
    for i in range(20):
        batch = jax.tree.map(jax.numpy.asarray,
                             stream.next_batch(16, 64))
        state, metrics = step(state, batch)
        tau_note = " (delay pipeline filling)" if i < rc.ambdg.tau else ""
        print(f"step {i+1:2d} loss/token={float(metrics['loss']):7.4f} "
              f"applied_count={float(metrics['applied_count']):5.0f}"
              f"{tau_note}")


if __name__ == "__main__":
    main()
