"""Train-while-serve demo: continuous batching under a seeded Poisson
request load, with the master publishing weight snapshots into the
bounded-staleness channel the engine pops from (reduced config, CPU).

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

import repro.configs as C
from repro.configs.base import ServeConfig
from repro.core.arena import make_layout
from repro.models import build_model
from repro.serve import Engine, RequestQueue, WeightPublisher


def main():
    cfg = C.get_smoke_config("mixtral-8x7b")     # MoE decode path
    model = build_model(cfg)
    sc = ServeConfig(slots=4, max_len=64, max_new=8,
                     arrival="poisson", arrival_rate=0.6,
                     publish_period=4, staleness_bound=8)
    engine = Engine(model, sc.slots, sc.max_len)
    queue = RequestQueue(sc, cfg.vocab_size)
    publisher = WeightPublisher(make_layout(engine.params), sc)
    engine.attach_publisher(publisher)

    # one explicit request alongside the seeded open-loop traffic
    rng = np.random.default_rng(0)
    queue.submit(list(rng.integers(1, cfg.vocab_size, size=5)))

    for t in range(48):
        if t % sc.publish_period == 0:
            # stand-in master: in training this is the loop's publish
            # hook firing every publish_period master updates
            publisher.publish(engine.params, t)
            engine.refresh_weights(t)
        queue.step()
        ev = engine.step(queue)
        if ev["admits"] or ev["evicts"]:
            print(f"step {ev['step']:3d}: admits={ev['admits']} "
                  f"evicts={ev['evicts']} active={ev['active']}")

    s = engine.stats
    print(f"\nstats: {s.steps} steps, {s.admitted} admitted, "
          f"{s.completed} completed, {s.prefill_tokens} prefill tok, "
          f"{s.decode_tokens} decode tok")
    print(f"publish: {s.publish_pops} pops, staleness mean "
          f"{s.staleness_mean():.2f} max {s.staleness_max} "
          f"(bound {sc.staleness_bound})")
    for rid, toks in engine.completions[:3]:
        print(f"req {rid}: {toks[-8:]}")


if __name__ == "__main__":
    main()
