"""Batched serving demo: continuous greedy decoding with a shared
KV cache through the serving engine (reduced config, CPU).

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

import repro.configs as C
from repro.models import build_model
from repro.serve.engine import Engine


def main():
    cfg = C.get_smoke_config("mixtral-8x7b")     # MoE decode path
    model = build_model(cfg)
    engine = Engine(model, batch_slots=4, max_len=64)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (5, 7, 3, 6)]
    out = engine.generate(prompts, max_new=8)
    for i, o in enumerate(out):
        print(f"req {i}: prompt len {len(prompts[i])} -> "
              f"generated {o[len(prompts[i]):]}")
    s = engine.stats
    print(f"stats: {s.steps} steps, {s.prefill_tokens} prefill tok, "
          f"{s.decode_tokens} decode tok")


if __name__ == "__main__":
    main()
