"""``repro.api`` — the one front-end for every algorithm variant.

    import repro.api as api

    strategy = api.build(model, rc)          # rc.strategy picks the variant
    state    = strategy.init_state(jax.random.PRNGKey(rc.seed))
    step     = jax.jit(strategy.train_step, donate_argnums=(0,))
    state, metrics = step(state, batch)

Registered strategies (``api.available_strategies()``):

    "ambdg"          the paper: anytime minibatch + delayed gradients
    "amb"            synchronous baseline (tau = 0, idle round trips)
    "kbatch"         fixed-minibatch K-batch baseline (Dutta et al.)
    "decentralized"  Sec.-V gossip consensus, mastered by no one

``api.simulate(name, problem, ...)`` dispatches the cluster simulator
through the same registry (epoch-timeline schemes vs the event-driven
k-batch heap), so benchmarks and examples never hard-code a scheme's
wall-clock algebra. See docs/strategies.md for the protocol and how to
add a scenario.

``api.serve(model, rc)`` builds the continuous-batching inference
engine from ``rc.serve`` (slots, max_len, arrival process) — the
consumer side of the train-while-serve channel (docs/serve.md).
"""
from __future__ import annotations

from repro.configs.base import RunConfig
from repro.core.strategy import (  # noqa: F401  (re-exports)
    StalenessSchedule, Strategy, TimelineModel, available_strategies,
    get_strategy, register)
from repro.models.api import Model


def build(model: Model, rc: RunConfig) -> Strategy:
    """Construct the strategy named by ``rc.strategy``."""
    return get_strategy(rc.strategy)(model, rc)


def serve(model: Model, rc: RunConfig, publisher=None):
    """Construct the continuous-batching engine + seeded request queue
    from ``rc.serve``. Returns (engine, queue); pass the train loop's
    ``WeightPublisher`` to attach the bounded-staleness weight channel
    (``engine.refresh_weights(now)`` pops the freshest due snapshot)."""
    from repro.serve import Engine, RequestQueue
    engine = Engine(model, rc.serve.slots, rc.serve.max_len,
                    seed=rc.seed)
    queue = RequestQueue(rc.serve, model.cfg.vocab_size)
    if publisher is not None:
        engine.attach_publisher(publisher)
    return engine, queue


def simulate(strategy, problem, **kw):
    """Run the cluster simulator for one registered strategy. Keyword
    arguments are forwarded to the engine the strategy class declares
    (``Strategy.sim_engine``): ``simulate_anytime`` for epoch-timeline
    master-ful schemes, ``simulate_kbatch`` for the event-driven
    arrival heap. Returns the engine's ``Trace``. Strategies with no
    engine (the on-device decentralized variant) raise.

    ``strategy`` is a registered name OR a built ``Strategy`` instance
    — passing the instance is how ``rc.delay`` and ``rc.elastic`` reach
    the simulator: a stochastic delay config wires its seeded process
    (``Strategy.delay_process()``) into the engine automatically, and a
    non-static elastic config likewise wires its seeded worker process
    (``Strategy.worker_process(n)``), and an adaptive batch-schedule
    config wires its seeded controller (``Strategy.batch_schedule()``).
    Explicit ``delay_process=...`` / ``worker_process=...`` /
    ``batch_schedule=...`` kwargs still win. The kbatch engine also
    receives the config's ``t_p`` whenever either process needs the
    epoch clock (uplink conversion / elastic epoch boundaries)."""
    from repro.sim import simulate_anytime, simulate_kbatch
    if isinstance(strategy, Strategy):
        inst, cls, name = strategy, type(strategy), type(strategy).name
        dp = inst.delay_process()
        if dp is not None and "delay_process" not in kw:
            kw["delay_process"] = dp
            if cls.sim_engine == "kbatch":
                kw.setdefault("t_p", inst.rc.ambdg.t_p)
        wp = inst.worker_process(problem.n_workers)
        if wp is not None and "worker_process" not in kw:
            kw["worker_process"] = wp
            if cls.sim_engine == "kbatch":
                kw.setdefault("t_p", inst.rc.ambdg.t_p)
        bs = inst.batch_schedule()
        if bs is not None and "batch_schedule" not in kw:
            kw["batch_schedule"] = bs
    else:
        cls, name = get_strategy(strategy), strategy
    if cls.sim_engine == "kbatch":
        return simulate_kbatch(problem, **kw)
    if cls.sim_engine == "anytime":
        return simulate_anytime(problem, scheme=name, **kw)
    raise NotImplementedError(
        f"strategy {name!r} declares no simulator engine "
        f"(Strategy.sim_engine); run it on device via repro.api.build "
        f"(see examples/decentralized.py)")


__all__ = ["Strategy", "StalenessSchedule", "TimelineModel",
           "available_strategies", "build", "get_strategy", "register",
           "serve", "simulate"]
