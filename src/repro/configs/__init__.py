"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``.

Each assigned architecture lives in its own module exposing ``FULL`` (the
exact published config) and ``SMOKE`` (a reduced same-family config for
CPU tests). ``--arch`` ids use dashes; module names use underscores.
"""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    AmbdgConfig, MeshConfig, ModelConfig, MoEConfig, RunConfig, ShapeConfig,
    SSMConfig, XLSTMConfig, SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K,
    LONG_500K, DENSE, MOE, SSM, HYBRID, ENCDEC, VLM, LINREG, CNN,
    LM_FAMILIES,
)

from repro.configs import (
    mixtral_8x7b, mixtral_8x22b, xlstm_125m, paligemma_3b, qwen1_5_0_5b,
    yi_6b, chatglm3_6b, qwen3_1_7b, zamba2_2_7b, seamless_m4t_large_v2,
    amb_linreg, amb_cnn,
)

_MODULES = {
    "mixtral-8x7b": mixtral_8x7b,
    "mixtral-8x22b": mixtral_8x22b,
    "xlstm-125m": xlstm_125m,
    "paligemma-3b": paligemma_3b,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "yi-6b": yi_6b,
    "chatglm3-6b": chatglm3_6b,
    "qwen3-1.7b": qwen3_1_7b,
    "zamba2-2.7b": zamba2_2_7b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    # paper's own experiments
    "amb-linreg": amb_linreg,
    "amb-cnn": amb_cnn,
}

ARCH_IDS = tuple(k for k in _MODULES if not k.startswith("amb-"))
ALL_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch].FULL


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch].SMOKE


def applicable_shapes(arch: str) -> list:
    """Which of the four assigned shapes run for this arch (spec skips)."""
    cfg = get_config(arch)
    if cfg.family not in LM_FAMILIES:
        return []
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.is_subquadratic:
        out.append(LONG_500K)
    return out
