"""The paper's CIFAR-10 CNN experiment (Sec. VI-B), offline stand-in.

The paper trains a 14-layer conv/fc net on CIFAR-10 over n=4 workers with
induced T_c = 10 s, T_p = 10 s. Offline we use the same net shape on a
synthetic 32x32x3 10-class stream.
"""
from repro.configs.base import ModelConfig, CNN

FULL = ModelConfig(
    name="amb-cnn",
    family=CNN,
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    image_size=32,
    n_classes=10,
)

SMOKE = ModelConfig(
    name="amb-cnn-smoke",
    family=CNN,
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    image_size=16,
    n_classes=10,
)
