"""The paper's own linear-regression problem (Sec. VI-A).

d = 10^4, noise sigma^2 = 1e-3, n = 10 workers, shifted-exponential
compute model (lambda=2/3, xi=1), T_p = 2.5, T_c = 10 => tau = 4.
"""
from repro.configs.base import ModelConfig, LINREG

FULL = ModelConfig(
    name="amb-linreg",
    family=LINREG,
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    linreg_dim=10_000,
)

SMOKE = ModelConfig(
    name="amb-linreg-smoke",
    family=LINREG,
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    linreg_dim=128,
)
