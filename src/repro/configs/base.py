"""Config system for the AMB-DG framework.

Plain frozen dataclasses (pytree-static, hashable) so they can be closed
over by jitted functions. One ``ModelConfig`` covers every assigned
architecture family; per-arch files in this package instantiate it and
register under the public ``--arch`` id.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"          # xLSTM-style (mLSTM/sLSTM blocks)
HYBRID = "hybrid"    # zamba2: mamba2 backbone + shared attention
ENCDEC = "encdec"    # seamless: encoder-decoder
VLM = "vlm"          # paligemma: patch-embedding stub + prefix-LM decoder
LINREG = "linreg"    # paper's own linear-regression experiment
CNN = "cnn"          # paper's own CIFAR-style CNN experiment

LM_FAMILIES = (DENSE, MOE, SSM, HYBRID, ENCDEC, VLM)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # aux load-balancing loss weight (Switch/Mixtral style)
    aux_loss_weight: float = 0.01
    # tokens are routed in independent groups of this size (the Mesh/
    # Switch "group" trick): keeps dispatch tensors O(group^2) instead
    # of O(T^2) and keeps routing local to the batch shard.
    dispatch_group: int = 4096


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD parameters (used by hybrid + ssm families)."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    # number of mamba "groups" for B/C projections (mamba2 ngroups)
    n_groups: int = 1


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block layout: which layer indices are sLSTM (rest mLSTM)."""
    slstm_every: int = 2          # every k-th block is sLSTM (offset 1)
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333333
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # defaults to d_model // n_heads
    # --- attention flavour ---
    rope_theta: float = 10000.0
    rope_partial: float = 1.0               # fraction of head_dim rotated (chatglm: 0.5)
    qk_norm: bool = False                   # qwen3-style per-head RMSNorm
    qkv_bias: bool = False                  # qwen1.5-style
    sliding_window: Optional[int] = None    # SWA window (mixtral: 4096)
    prefix_lm: bool = False                 # paligemma: bidirectional prefix
    logit_softcap: Optional[float] = None
    # --- ffn / norms ---
    act: str = "silu"                       # silu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- family-specific ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    shared_attn_every: int = 0              # zamba2: shared attn block period
    n_encoder_layers: int = 0               # encdec only
    cross_attention: bool = False           # encdec only
    frontend: Optional[str] = None          # "siglip_stub" | "w2vbert_stub"
    n_frontend_tokens: int = 0              # patches / frames fed by stub
    # --- paper's own problems ---
    linreg_dim: int = 0
    image_size: int = 0
    n_classes: int = 0
    # --- implementation knobs (perf levers, not architecture) ---
    moe_impl: str = "einsum"                # "einsum" | "gather" (see models/moe.py)
    attn_impl: str = "xla"                  # "xla" | "flash" (Pallas, TPU only)
    # fully unroll layer/chunk scans (roofline measurement mode: makes
    # cost_analysis() and the HLO collective census count every
    # iteration instead of one while-loop body)
    scan_unroll: bool = False
    # sequence-parallel residual stream: shard the inter-block
    # activations' sequence dim over the TP axis (Megatron-SP).
    # MEASURED NEGATIVE on this GSPMD version (see EXPERIMENTS.md §Perf
    # iteration log): the per-layer reshard triggers involuntary
    # rematerialization and ~2x temp memory; default OFF.
    seq_parallel: bool = False
    # activation rematerialization at the scanned-block level:
    # "full" recomputes the block in backward (residuals = block inputs
    # only); "dots" saves matmul outputs; "none" saves everything.
    block_remat: str = "full"
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim always
        divides the TP axis (seamless: 256206 -> 256256). Logits cover
        the padded range; real token ids never index the pad."""
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Does this arch admit an O(1)/O(window) per-token decode state?"""
        if self.family in (SSM, HYBRID):
            return True
        if self.sliding_window is not None:
            return True
        return False

    def n_params(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        return _count_params(self)

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        return _count_params(self, active_only=True)


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    if cfg.family == LINREG:
        return cfg.linreg_dim
    if cfg.family == CNN:
        return _cnn_param_count(cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    emb = cfg.vocab_size * d
    out_head = 0 if cfg.tie_embeddings else cfg.vocab_size * d
    total = emb + out_head + d  # final norm

    def attn_params() -> int:
        p = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
        if cfg.qkv_bias:
            p += (nq + 2 * nkv) * hd
        return p

    def dense_ffn(dff: int) -> int:
        return 3 * d * dff  # gated (SwiGLU/GeGLU): up, gate, down

    def moe_ffn() -> int:
        m = cfg.moe
        per = 3 * d * cfg.d_ff
        router = d * m.n_experts
        n_used = m.top_k if active_only else m.n_experts
        return router + n_used * per

    def mamba2_params() -> int:
        s = cfg.ssm
        d_in = s.expand * d
        nh = d_in // s.head_dim
        zxbcdt = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
        conv = s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
        out = d_in * d
        extra = 2 * nh + d_in  # A_log, D, norm
        return zxbcdt + conv + out + extra

    if cfg.family in (DENSE, VLM):
        per_layer = attn_params() + dense_ffn(cfg.d_ff) + 2 * d
        total += cfg.n_layers * per_layer
        if cfg.frontend:
            total += cfg.n_frontend_tokens  # stub: positional table only
    elif cfg.family == MOE:
        per_layer = attn_params() + moe_ffn() + 2 * d
        total += cfg.n_layers * per_layer
    elif cfg.family == SSM:  # xlstm
        x = cfg.xlstm
        d_in_m = int(x.proj_factor_mlstm * d)
        n_sl = cfg.n_layers // x.slstm_every
        n_ml = cfg.n_layers - n_sl
        # mLSTM block: up(2x), q/k/v proj, gates, out
        mlstm = d * 2 * d_in_m + 3 * d_in_m * d_in_m + 2 * d_in_m + d_in_m * d + 2 * d
        # sLSTM block: 4 gates on (x,h) + ffn
        slstm = 8 * d * d + dense_ffn(int(x.proj_factor_slstm * d)) + 2 * d
        total += n_ml * mlstm + n_sl * slstm
    elif cfg.family == HYBRID:
        per_layer = mamba2_params() + 2 * d
        total += cfg.n_layers * per_layer
        # one shared attention+mlp block (counted once — it is shared)
        total += attn_params() + dense_ffn(cfg.d_ff) + 2 * d
    elif cfg.family == ENCDEC:
        enc_layer = attn_params() + dense_ffn(cfg.d_ff) + 2 * d
        dec_layer = 2 * attn_params() + dense_ffn(cfg.d_ff) + 3 * d
        total += cfg.n_encoder_layers * enc_layer + cfg.n_layers * dec_layer
        total += d  # encoder final norm
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return total


def _cnn_param_count(cfg: ModelConfig) -> int:
    # mirrors models/cnn.py: 6 conv layers + 3 fc
    chans = [3, 64, 64, 128, 128, 256, 256]
    total = 0
    for cin, cout in zip(chans[:-1], chans[1:]):
        total += 3 * 3 * cin * cout + cout
    feat = 256 * (cfg.image_size // 8) * (cfg.image_size // 8)
    for fin, fout in [(feat, 512), (512, 128), (128, cfg.n_classes)]:
        total += fin * fout + fout
    return total


# ---------------------------------------------------------------------------
# Input shapes (assigned set)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# AMB-DG technique knobs (paper Sec. III)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AmbdgConfig:
    # Timeline (paper): compute epoch T_p, round-trip T_c, staleness tau.
    t_p: float = 2.5
    t_c: float = 10.0
    # Staleness of the cross-pod (DCN) gradient exchange, in steps.
    # tau = ceil(T_c / T_p) in the paper; tau=0 degenerates to sync AMB.
    tau: int = 1
    # Max microbatches a shard may contribute per epoch (scan length);
    # the anytime mask activates b_i(t) <= n_microbatches of them.
    n_microbatches: int = 4
    # "scan_masked": fixed-trip scan, masked samples still cost FLOPs
    #   (deterministic roofline; the SPMD-friendly default).
    # "while_dynamic": lax.while_loop with a per-shard dynamic trip
    #   count from batch["n_active"] — zero wasted FLOPs on stragglers
    #   (devices genuinely run different iteration counts; no
    #   collectives inside the body). Deployment mode on real HW.
    anytime_impl: str = "scan_masked"
    # Dual averaging step-size constants: alpha(t)^-1 = L + sqrt((t+tau)/b_bar)
    smoothness_L: float = 1.0
    b_bar: float = 600.0
    # Proximal psi: "l2" (psi = 0.5||w||^2) or "l2_ball" (projection radius C)
    proximal: str = "l2"
    radius_C: float = 0.0
    # Cross-pod gradient compression: "none" | "int8"
    pod_compression: str = "none"
    # K-batch baseline (Dutta et al.): the master updates on every K-th
    # arriving fixed-size message (used by the "kbatch" strategy and
    # the event-driven simulator).
    kbatch_K: int = 10

    @property
    def staleness(self) -> int:
        import math
        return max(int(math.ceil(self.t_c / self.t_p)), 0)


@dataclass(frozen=True)
class DelayConfig:
    """Stochastic delay process driving a time-varying staleness
    ``tau_t`` (paper analyzes the fixed ``tau = ceil(T_c/T_p)``; real
    networks jitter, burst and heavy-tail — Agarwal & Duchi 2011,
    Attia et al. 2024). Resolved by ``core.delay_process``:

      "fixed"       tau_t = tau every step — the paper, and the exact
                    pre-existing static-phase master path (pinned
                    bit-identical by the regression suites).
      "jitter"      tau_t = clip(tau + U{-jitter..jitter}).
      "heavy_tail"  tau_t = clip(delay_min + floor(Pareto(tail_alpha))).
      "bursty"      2-state Gilbert-Elliott chain: base delay in the
                    normal state, tau_max inside a burst.

    All processes are seeded (``seed``) and emit integer delays in
    ``[delay_min, tau_max]``; the host loop draws one per step and
    ships it to the device step as ``batch["delay"]``. Non-fixed
    processes run the delay-tolerant arena ring (tau_max+1 slots; see
    docs/arena.md) and, with ``adaptive_alpha``, the Agarwal-Duchi
    style delay-adaptive dual-averaging step size (alpha(t)^-1 =
    L + sqrt((t + tau_obs(t)) / b_bar), tau_obs = observed staleness
    of the gradients applied at t)."""
    process: str = "fixed"      # fixed | jitter | heavy_tail | bursty
    # Hard staleness cap (ring depth = tau_max + 1). 0 resolves to
    # ambdg.tau for "fixed"; stochastic processes must set it.
    tau_max: int = 0
    delay_min: int = 1          # floor for stochastic draws
    jitter: int = 1             # "jitter": +- range around ambdg.tau
    tail_alpha: float = 1.1     # "heavy_tail": Pareto shape (smaller = fatter)
    p_burst: float = 0.1        # "bursty": P(normal -> burst) per step
    p_exit: float = 0.3         # "bursty": P(burst -> normal) per step
    seed: int = 0
    # Scale the dual-averaging step by the OBSERVED staleness of each
    # update (Agarwal-Duchi) instead of the static worst case.
    adaptive_alpha: bool = True


@dataclass(frozen=True)
class ElasticConfig:
    """Elastic-worker process driving a time-varying active set and
    per-worker speed skew (the straggler premise of the AMB line —
    Ferdinand et al. — promoted to a simulable, seeded, checkpointable
    scenario). Resolved by ``core.worker_process``:

      "static"         every worker alive at speed 1.0 every epoch —
                       the degenerate process: the host loop and every
                       strategy route it to the exact pre-existing
                       no-churn path (pinned bit-identical by the
                       regression suites).
      "heterogeneous"  persistent per-worker speed skew: speeds drawn
                       ONCE from lognormal(-speed_sigma^2/2,
                       speed_sigma) (mean 1.0), floored at speed_min;
                       everyone stays alive.
      "churn"          per-worker Gilbert-Elliott up/down chain:
                       up -> down with ``p_fail`` per epoch, down ->
                       up with ``p_recover`` (geometric dwell times —
                       the join/leave membership model).
      "crash_restart"  exponential MTTF/MTTR in epoch units: each
                       worker alternates Exp(mttf)-long lives and
                       Exp(mttr)-long outages (fail-stop + restart).

    All processes are seeded (``seed``) and emit one per-epoch
    ``(active_mask, speeds)`` pair; the host loop folds the pair into
    ``batch["weights"]`` (a dead worker contributes b_i = 0 and the
    eq. (5) normalization stays exact — paper Sec. IV-C), and
    ``api.simulate`` wires the same seeded sequence into both cluster-
    simulator engines. ``state_dict``/``load_state_dict`` keep the
    restart-exactness contract of the data pipeline and the delay
    processes."""
    process: str = "static"   # static | heterogeneous | churn | crash_restart
    speed_sigma: float = 0.5    # "heterogeneous": lognormal shape
    speed_min: float = 0.05     # "heterogeneous": floor on drawn speeds
    p_fail: float = 0.05        # "churn": P(up -> down) per epoch
    p_recover: float = 0.5      # "churn": P(down -> up) per epoch
    mttf: float = 50.0          # "crash_restart": mean epochs between failures
    mttr: float = 5.0           # "crash_restart": mean epochs to restart
    seed: int = 0


@dataclass(frozen=True)
class BatchScheduleConfig:
    """Adaptive minibatch schedule b(t): a seeded batch-size controller
    that replaces the static anytime target (and the static ``b_bar``
    inside the dual-averaging step size) with a per-step schedule.
    Resolved by ``core.batch_schedule``:

      "fixed"       b(t) = b0 every step — the degenerate schedule: the
                    host loop, both simulator engines and every strategy
                    route it to the exact pre-existing timing-driven
                    path (pinned bit-identical by the regression
                    suites).
      "linear"      b(t) = b0 + floor(growth_rate * (t-1)) — a
                    deterministic warmup ramp.
      "adadamp"     grow b(t) to damp gradient noise as the loss drops
                    (AdaDamp principle): b(t) = b0 * loss(1)/loss(t),
                    monotone non-decreasing, per-step growth capped at
                    ``growth_factor``x; the loss signal is an EMA
                    (weight ``ema``) of the feedback fed through
                    ``BatchSchedule.observe(loss=...)``.
      "delay_aware" scale b(t) by the observed staleness of applied
                    gradients (Attia-Gaash-Koren: larger accumulated
                    minibatches amortize larger delays):
                    b(t) = b0 * (1 + ema_tau(t)) / (1 + tau_ref), fed
                    through ``observe(tau_obs=...)`` and composing with
                    the Agarwal-Duchi ``rc.delay.adaptive_alpha``.

    All schedules are seeded (``seed``), emit integer targets in
    ``[b_min, b_cap]``, and checkpoint/restore exactly
    (``state_dict``/``load_state_dict``, matching the delay/worker
    processes). The drawn b(t) is injected as the anytime target (the
    per-worker shares of b(t) cap the timing-driven draw) and shipped
    to the device step as ``batch["b_sched"]``, where it replaces
    ``b_bar`` inside alpha(t)^-1 = L + sqrt((t + tau) / b(t))."""
    schedule: str = "fixed"   # fixed | linear | adadamp | delay_aware
    # Base target b(1); 0 resolves to round(ambdg.b_bar).
    b0: int = 0
    b_min: int = 1            # floor on emitted targets
    # Cap on emitted targets; 0 resolves to 16 * b0.
    b_cap: int = 0
    growth_rate: float = 1.0    # "linear": +samples per step
    growth_factor: float = 2.0  # "adadamp": max per-step growth multiplier
    ema: float = 0.3            # feedback EMA weight in (0, 1]
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    """Train-while-serve: the continuous-batching inference engine fed
    by staleness-bounded async weight publication (the Agarwal-Duchi
    delayed-consumer argument applied to *serving*: an inference server
    reading asynchronously published master snapshots is exactly a
    consumer of delayed parameters, so bounded staleness preserves the
    quality guarantees the training analysis already needs).

    Resolved by ``repro.serve``:

      * ``slots``/``max_len``/``max_new`` size the engine: ``slots``
        concurrent sequences share one fixed-slot KV/recurrent cache of
        depth ``max_len``; finished sequences are evicted and new
        requests admitted every decode step (continuous batching, per-
        slot positions — see ``serve/engine.py``).
      * ``arrival`` names the seeded request arrival process
        (``serve/request_queue.py``, mirroring ``core/delay_process``):
        "poisson" draws Poisson(``arrival_rate``) new requests per
        decode step; "bursty" is a 2-state Gilbert-Elliott chain
        emitting Poisson(``arrival_rate``) in the normal state and
        Poisson(``burst_rate``) inside a burst. Synthesized prompts
        have seeded lengths in [prompt_len_min, prompt_len_max].
      * ``publish_period``/``staleness_bound`` drive the weight-
        publication channel (``serve/publisher.py``): every
        ``publish_period`` master steps the train loop pushes a
        ``w = -alpha z`` snapshot into a bounded-staleness publish ring
        (arena (rows, 128) layout, int8 + bf16-scales wire format —
        the gossip path's quantizer, bit-identical); servers pop the
        freshest snapshot whose age is <= ``staleness_bound`` master
        steps. ``publish_period = 0`` (the default) disables the
        channel entirely — the pre-existing paths are untouched.
    """
    slots: int = 4
    max_len: int = 128
    max_new: int = 16
    # master steps between published snapshots; 0 = channel disabled
    publish_period: int = 0
    # max age (master steps) of a servable snapshot; ring depth =
    # staleness_bound // publish_period + 1 slots
    staleness_bound: int = 4
    arrival: str = "poisson"    # poisson | bursty
    arrival_rate: float = 0.5   # mean new requests per decode step
    burst_rate: float = 4.0     # "bursty": rate inside a burst
    p_burst: float = 0.1        # "bursty": P(normal -> burst) per step
    p_exit: float = 0.3         # "bursty": P(burst -> normal) per step
    prompt_len_min: int = 4
    prompt_len_max: int = 12
    seed: int = 0


@dataclass(frozen=True)
class ConsensusConfig:
    """Decentralized AMB-DG (paper Sec. V): gossip-consensus knobs.

    Workers exchange messages through a doubly-stochastic matrix Q for
    ``rounds`` gossip rounds per epoch; ``rounds=0`` derives the count
    from the paper's eq. (24) lower bound with (delta, msg_norm_J) and
    lambda_2(Q) of the configured topology.
    """
    topology: str = "ring"        # "ring" | "torus" | "complete"
    n_workers: int = 8
    delta: float = 0.05           # consensus-error target of eq. (24)
    msg_norm_J: float = 1.0       # message-norm bound J in eq. (24)
    rounds: int = 0               # 0 = derive from eq. (24)
    # "auto" runs the gossip under shard_map (one mesh index = one
    # worker, lax.ppermute neighbour exchange) exactly when the local
    # device count equals n_workers — the deployment shape where the
    # strategy's private ('worker',) mesh owns the same devices any
    # surrounding jit lowers for — and on the dense per-round fold
    # (one program, bit-identical arithmetic) otherwise;
    # "dense"/"shard_map" force one path.
    gossip_impl: str = "auto"
    # Gossip message compression: "none" exchanges full f32 messages;
    # "int8" quantizes each outgoing message per round to int8 with
    # per-row scales (the delay-ring scheme) and carries the
    # quantization error in a per-worker error-feedback residual
    # (DecentralizedState.residual), so the compression error
    # telescopes across rounds instead of accumulating. ~3.9x less
    # wire payload per round; dense and shard_map executions stay
    # bit-identical on the same (messages, residual).
    compression: str = "none"
    # Debug/validation: also return the pre-gossip messages m^(0) in
    # the step metrics ("gossip_m0"), so a harness can re-apply the
    # dense gossip-matrix fold oracle to the EXACT in-program messages
    # and bit-compare with the step's consensus output. Keep False in
    # training loops (metrics are assumed scalar there).
    debug_messages: bool = False


@dataclass(frozen=True)
class MeshConfig:
    n_pods: int = 1
    data: int = 16
    model: int = 16

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.n_pods > 1 else ("data", "model")

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self.n_pods, self.data, self.model) if self.n_pods > 1 else (self.data, self.model)

    @property
    def n_devices(self) -> int:
        return self.n_pods * self.data * self.model


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    ambdg: AmbdgConfig = field(default_factory=AmbdgConfig)
    # Algorithm variant, resolved through the Strategy registry by
    # ``repro.api.build``: "ambdg" (the paper), "amb" (synchronous
    # baseline), "kbatch" (fixed-minibatch baseline), "decentralized"
    # (Sec.-V gossip consensus). See docs/strategies.md.
    strategy: str = "ambdg"
    consensus: ConsensusConfig = field(default_factory=ConsensusConfig)
    # Staleness process of the cross-pod exchange: the default "fixed"
    # keeps the paper's constant tau (and the exact pre-existing master
    # path); stochastic processes drive the delay-tolerant ring. See
    # DelayConfig / core/delay_process.py / docs/arena.md.
    delay: DelayConfig = field(default_factory=DelayConfig)
    # Elastic-worker process: the default "static" keeps every worker
    # alive at speed 1.0 (and the exact pre-existing no-churn path);
    # stochastic processes drive a seeded per-epoch (active_mask,
    # speeds) sequence through the host loop and both simulator
    # engines. See ElasticConfig / core/worker_process.py /
    # docs/strategies.md.
    elastic: ElasticConfig = field(default_factory=ElasticConfig)
    # Train-while-serve: continuous-batching engine + bounded-staleness
    # weight publication. The default (publish_period=0) keeps the
    # publish channel off and the train loop byte-identical to the
    # serve-less path. See ServeConfig / repro.serve / docs/serve.md.
    serve: ServeConfig = field(default_factory=ServeConfig)
    # Adaptive minibatch schedule b(t): the default "fixed" keeps the
    # timing-driven anytime target (and the static b_bar inside alpha)
    # and the exact pre-existing step/sim paths; adaptive schedules
    # drive a seeded batch-size controller through the host loop, both
    # simulator engines and the dual-averaging step size. See
    # BatchScheduleConfig / core/batch_schedule.py / docs/strategies.md.
    batch_schedule: BatchScheduleConfig = field(default_factory=BatchScheduleConfig)
    optimizer: str = "dual_averaging"   # paper-faithful default
    remat: str = "none"                 # "none" | "full" | "dots"
    # Master-pipeline implementation: "arena" runs the delay ring +
    # dual update on the persistent flat gradient arena (fused Pallas
    # kernels on TPU; see core/arena.py + docs/arena.md); "pytree" is
    # the per-leaf reference path kept for ablations/verification.
    master_impl: str = "arena"
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
