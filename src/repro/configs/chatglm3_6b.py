"""ChatGLM3-6B [arXiv:2406.12793; hf] — 2d (partial) RoPE, GQA kv=2."""
from repro.configs.base import ModelConfig, DENSE

FULL = ModelConfig(
    name="chatglm3-6b",
    family=DENSE,
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    rope_partial=0.5,       # 2d RoPE: rotate half of each head dim
    qkv_bias=True,          # chatglm uses bias on qkv
    act="silu",
)

SMOKE = ModelConfig(
    name="chatglm3-6b-smoke",
    family=DENSE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    head_dim=16,
    rope_partial=0.5,
    qkv_bias=True,
    act="silu",
)
