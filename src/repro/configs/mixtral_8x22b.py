"""Mixtral 8x22B [arXiv:2401.04088; hf] — MoE 8 experts top-2, SWA."""
from repro.configs.base import ModelConfig, MoEConfig, MOE

FULL = ModelConfig(
    name="mixtral-8x22b",
    family=MOE,
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    rope_theta=1e6,
    sliding_window=4096,
    act="silu",
    moe=MoEConfig(n_experts=8, top_k=2, dispatch_group=2048),
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family=MOE,
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    head_dim=16,
    sliding_window=32,
    act="silu",
    moe=MoEConfig(n_experts=4, top_k=2),
)
