"""Mixtral 8x7B [arXiv:2401.04088; hf] — MoE 8 experts top-2, SWA."""
from repro.configs.base import ModelConfig, MoEConfig, MOE

FULL = ModelConfig(
    name="mixtral-8x7b",
    family=MOE,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1e6,
    sliding_window=4096,
    act="silu",
    moe=MoEConfig(n_experts=8, top_k=2),
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke",
    family=MOE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    sliding_window=32,
    act="silu",
    moe=MoEConfig(n_experts=4, top_k=2),
)
