"""PaliGemma-3B [arXiv:2407.07726; hf] — SigLIP stub + gemma decoder.

Per spec the modality frontend is a STUB: ``input_specs()`` feeds
precomputed patch embeddings (256 patches at 224px/14px patching).
The backbone is the gemma-2b decoder: 18L, d=2048, MQA (kv=1),
head_dim 256, GeGLU d_ff=16384, vocab 257216, prefix-LM attention over
the image+prefix region.
"""
from repro.configs.base import ModelConfig, VLM

FULL = ModelConfig(
    name="paligemma-3b",
    family=VLM,
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    act="gelu",
    prefix_lm=True,
    tie_embeddings=True,
    frontend="siglip_stub",
    n_frontend_tokens=256,
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke",
    family=VLM,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    act="gelu",
    prefix_lm=True,
    tie_embeddings=True,
    frontend="siglip_stub",
    n_frontend_tokens=16,
)
