"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B; hf] — dense MHA with QKV bias."""
from repro.configs.base import ModelConfig, DENSE

FULL = ModelConfig(
    name="qwen1.5-0.5b",
    family=DENSE,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    act="silu",
)

SMOKE = ModelConfig(
    name="qwen1.5-0.5b-smoke",
    family=DENSE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=256,
    head_dim=16,
    qkv_bias=True,
    tie_embeddings=True,
    act="silu",
)
