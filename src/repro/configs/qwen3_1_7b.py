"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family; hf] — qk_norm, GQA kv=8."""
from repro.configs.base import ModelConfig, DENSE

FULL = ModelConfig(
    name="qwen3-1.7b",
    family=DENSE,
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    rope_theta=1e6,
    qk_norm=True,
    tie_embeddings=True,
    act="silu",
)

SMOKE = ModelConfig(
    name="qwen3-1.7b-smoke",
    family=DENSE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    head_dim=16,
    qk_norm=True,
    tie_embeddings=True,
    act="silu",
)
