"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf] — enc-dec, audio stub.

The w2v-BERT speech frontend is a STUB per spec: ``input_specs()`` feeds
precomputed frame embeddings (B, S_src, d_model). Backbone: 24L encoder +
24L text decoder, d=1024, 16H MHA, d_ff=8192, vocab 256206.
"""
from repro.configs.base import ModelConfig, ENCDEC

FULL = ModelConfig(
    name="seamless-m4t-large-v2",
    family=ENCDEC,
    n_layers=24,               # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    cross_attention=True,
    frontend="w2vbert_stub",
    act="gelu",
)

SMOKE = ModelConfig(
    name="seamless-m4t-large-v2-smoke",
    family=ENCDEC,
    n_layers=2,
    n_encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=256,
    head_dim=16,
    cross_attention=True,
    frontend="w2vbert_stub",
    act="gelu",
)
