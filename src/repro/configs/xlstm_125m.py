"""xLSTM 125M [arXiv:2405.04517; unverified] — alternating sLSTM/mLSTM."""
from repro.configs.base import ModelConfig, XLSTMConfig, SSM

FULL = ModelConfig(
    name="xlstm-125m",
    family=SSM,
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                       # xLSTM blocks carry their own projections
    vocab_size=50304,
    head_dim=192,
    tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_every=2),
)

SMOKE = ModelConfig(
    name="xlstm-125m-smoke",
    family=SSM,
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    head_dim=32,
    tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_every=2),
)
