"""Yi-6B [arXiv:2403.04652; hf] — llama-arch GQA kv=4."""
from repro.configs.base import ModelConfig, DENSE

FULL = ModelConfig(
    name="yi-6b",
    family=DENSE,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5e6,
    act="silu",
)

SMOKE = ModelConfig(
    name="yi-6b-smoke",
    family=DENSE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    head_dim=16,
    act="silu",
)
