"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attn.

54 Mamba2 layers (d_model 2560, ssm_state 64); one *shared* full
attention+MLP block (32 heads, d_ff 10240) interleaved every 6 layers.
Zamba2's per-invocation LoRA on the shared block is omitted (noted in
DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig, SSMConfig, HYBRID

FULL = ModelConfig(
    name="zamba2-2.7b",
    family=HYBRID,
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    act="gelu",
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke",
    family=HYBRID,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    shared_attn_every=2,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk_size=32),
    act="gelu",
)
