"""The paper's primary contribution: AMB-DG — anytime (fixed-time,
variable-size) minibatches + delayed gradients + dual averaging, plus
the AMB and K-batch-async baselines and the Sec.-V consensus variant.

All variants implement the ``Strategy`` protocol (``core.strategy``)
and are constructed by name through ``repro.api.build(model, rc)``;
``make_train_step`` survives as a deprecated alias for the "ambdg"
strategy."""
from repro.core import (amb, anytime, consensus, delayed,  # noqa: F401
                        dual_averaging, kbatch, staleness, strategy)
from repro.core.ambdg import TrainState, make_train_step  # noqa: F401
from repro.core.strategy import Strategy  # noqa: F401
