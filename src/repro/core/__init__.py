"""The paper's primary contribution: AMB-DG — anytime (fixed-time,
variable-size) minibatches + delayed gradients + dual averaging, plus
the AMB and K-batch-async baselines and the Sec.-V consensus variant."""
from repro.core import (amb, anytime, consensus, delayed,  # noqa: F401
                        dual_averaging, kbatch, staleness)
from repro.core.ambdg import TrainState, make_train_step  # noqa: F401
