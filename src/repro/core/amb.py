"""AMB baseline (Ferdinand et al., ICLR'19) — the paper's primary
comparison.

Identical anytime aggregation and dual-averaging update, but
*synchronous*: the master's update uses the current epoch's gradients
(no staleness) and workers idle for the full round trip T_c after every
transmission. On-device this is simply the AMB-DG step with tau = 0;
the wall-clock penalty (epoch duration T_p + T_c instead of T_p) is
modeled by the cluster simulator / timeline (core.staleness).
"""
from __future__ import annotations

from repro.configs.base import RunConfig
from repro.models.api import Model


def make_amb_train_step(model: Model, rc: RunConfig):
    """Deprecated alias — ``repro.api.build(model,
    rc.replace(strategy="amb"))`` is the Strategy-registry spelling."""
    from repro import api
    s = api.build(model, rc.replace(strategy="amb"))
    return s.init_state, s.train_step
