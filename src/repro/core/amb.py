"""AMB baseline (Ferdinand et al., ICLR'19) — the paper's primary
comparison.

Identical anytime aggregation and dual-averaging update, but
*synchronous*: the master's update uses the current epoch's gradients
(no staleness) and workers idle for the full round trip T_c after every
transmission. On-device this is simply the AMB-DG step with tau = 0;
the wall-clock penalty (epoch duration T_p + T_c instead of T_p) is
modeled by the cluster simulator / timeline (core.staleness).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import RunConfig
from repro.core.ambdg import make_train_step
from repro.models.api import Model


def make_amb_train_step(model: Model, rc: RunConfig):
    rc_sync = rc.replace(ambdg=dataclasses.replace(rc.ambdg, tau=0))
    return make_train_step(model, rc_sync)
