"""AMB-DG composed train step: anytime accumulation -> (delayed) pod
exchange -> dual-averaging update.

``make_train_step(model, rc)`` returns ``(init_state, train_step)``:

    state = init_state(rng)
    state, metrics = train_step(state, batch)

Semantics (paper Sec. III, adapted per DESIGN.md §2):
  * batch leaves are globally-shaped, sharded (pod, data) on dim 0;
    per-sample ``weights`` carry the anytime mask (b_i(t)).
  * gradients are summed per pod chunk (vmap over a pod-stacked view,
    so no cross-pod communication happens in the backward pass), then
    pushed into the tau-deep delay buffer; the popped tau-old entry is
    reduced across pods and fed to dual averaging — the master's
    z(t+1) = z(t) + g(t - tau) pipeline with deterministic staleness.
  * tau = 0 (or a single pod) collapses to the synchronous AMB update.

Two master-pipeline implementations, selected by ``rc.master_impl``:

  "arena"   (default) the delay ring, dual variable, int8 residual and
            popped gradient all live in one persistent lane-aligned
            (rows, 128) arena (see ``core.arena`` / docs/arena.md).
            Parameters are flattened ONCE at init to build the static
            layout; per step the pod gradients are scattered into the
            arena (no tree concatenate) and the ring rotation + dual
            update run as two fused passes (Pallas on TPU).
  "pytree"  the per-leaf reference path (``core.delayed`` +
            tree-mapped optimizers) — kept as the bit-exact oracle and
            for ablations.

The optimizer is pluggable (``rc.optimizer``): "dual_averaging" is the
paper; "sgd"/"adam" compose the same delayed anytime gradients with
standard optimizers (beyond-paper comparisons).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.core import anytime, delayed
from repro.core import arena as arena_mod
from repro.core import dual_averaging as da
from repro.models.api import Model


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    buffer: Optional[delayed.DelayBuffer]    # pytree master path
    arena: Optional[arena_mod.GradArena]     # arena master path
    step: jax.Array


def _loss_with_remat(model: Model, rc: RunConfig):
    # Remat lives at the scanned-block level (ModelConfig.block_remat);
    # a whole-loss checkpoint would still store per-layer scan residuals
    # during the recompute, so rc.remat is only kept for ablations.
    loss = lambda p, b: model.loss(p, b)
    if rc.remat == "whole_loss":
        loss = jax.checkpoint(loss)
    return loss


def arena_master_update(layout, opt, params, opt_state, arena_state,
                        pod_grads, pod_counts, compression: str = "none",
                        b_sched=None):
    """The fused master pipeline on the flat arena: scatter the
    pod-stacked gradient tree into arena form (static update-slices —
    never a full-tree concatenate; asserted by tests/test_arena.py),
    rotate the delay ring, and apply the optimizer to the popped row.
    ``b_sched`` threads an adaptive batch schedule's target b(t) into
    the optimizer (None = the static ``b_bar``).

    Returns (params, opt_state, arena_state, grad_sum_flat, count).
    """
    from repro.dist.context import constrain
    if arena_state is not None:
        grad_sum, count, arena_state = arena_mod.push_pop(
            layout, arena_state, pod_grads, pod_counts, compression)
    else:  # tau = 0: synchronous exchange, then one flat scatter
        summed = jax.tree.map(delayed.pod_sum, pod_grads)
        grad_sum = arena_mod.flatten_tree(layout, summed)
        count = jnp.sum(pod_counts)
    grad_sum = constrain(grad_sum, ("flat", None))
    params, opt_state = opt.update(opt_state, params, grad_sum, count,
                                   b_sched=b_sched)
    return params, opt_state, arena_state, grad_sum, count


def make_train_step(model: Model, rc: RunConfig):
    """Deprecated alias — construct through the Strategy registry
    (``repro.api.build(model, rc)``) instead. Kept so pre-Strategy
    call sites (and the golden traces they pinned) keep working."""
    from repro import api
    s = api.build(model, rc if rc.strategy == "ambdg"
                  else rc.replace(strategy="ambdg"))
    return s.init_state, s.train_step


def build_step_fns(model: Model, rc: RunConfig):
    """The AMB-DG step factory: returns ``(init_state, train_step)``.
    Internal to the Strategy layer — ``AmbdgStrategy`` (and the
    strategies composing it) wrap this; user code goes through
    ``repro.api.build``.

    ``rc.delay`` selects the staleness process: the default "fixed"
    runs the static-phase master path unchanged (bit-identical to the
    pre-delay-process code — pinned by the regression suites); a
    stochastic process runs the delay-tolerant arena ring
    (``arena.push_pop_variable``) on a per-step ``batch["delay"]``
    scalar the host loop draws from ``core.delay_process``, with the
    Agarwal-Duchi delay-adaptive dual-averaging step
    (``rc.delay.adaptive_alpha``).

    ``rc.batch_schedule`` selects the minibatch-target schedule: the
    default "fixed" keeps the timing-driven anytime target and the
    static ``b_bar`` inside alpha (bit-identical to the pre-schedule
    code); an adaptive schedule ships the controller's per-step target
    as a ``batch["b_sched"]`` scalar, which replaces ``b_bar`` in the
    dual-averaging step size (sgd/adam ignore it)."""
    from repro.core.batch_schedule import resolve_targets
    from repro.core.delay_process import resolve_bounds
    from repro.optim import make_arena_optimizer, make_optimizer
    n_pods = rc.mesh.n_pods
    tau = rc.ambdg.tau
    n_mb = rc.ambdg.n_microbatches
    compression = rc.ambdg.pod_compression
    if rc.master_impl not in ("arena", "pytree"):
        raise ValueError(f"unknown master_impl {rc.master_impl!r}; "
                         "expected 'arena' or 'pytree'")
    use_arena = rc.master_impl == "arena"
    variable_delay = rc.delay.process != "fixed"
    if variable_delay:
        if not use_arena:
            raise ValueError(
                "stochastic delay processes run on the arena master "
                "pipeline only (rc.master_impl='arena'); the pytree "
                "reference path keeps the paper's fixed tau")
        _, tau_max = resolve_bounds(rc.delay, tau)
        ring_tau = tau_max
    else:
        resolve_bounds(rc.delay, tau)       # validate tau_max vs tau
        ring_tau = tau
    variable_batch = rc.batch_schedule.schedule != "fixed"
    if variable_batch:
        resolve_targets(rc.batch_schedule, rc.ambdg.b_bar)  # raise early
        if not use_arena:
            raise ValueError(
                "adaptive batch schedules run on the arena master "
                "pipeline only (rc.master_impl='arena'); the pytree "
                "reference path keeps the paper's static b_bar")
    loss_fn = _loss_with_remat(model, rc)

    if use_arena:
        # flatten ONCE: the layout (treedef + row offsets) is static
        # metadata computed from abstract shapes at build time
        params_shapes = jax.eval_shape(lambda k: model.init(k)[0],
                                       jax.random.PRNGKey(0))
        layout = arena_mod.make_layout(params_shapes)
        opt = make_arena_optimizer(rc, layout)
    else:
        layout = None
        opt = make_optimizer(rc)

    params_axes = None
    if compression == "int8" and not use_arena:
        from repro.dist import shapes_and_axes
        _, params_axes = shapes_and_axes(model.init, jax.random.PRNGKey(0))

    def init_state(key) -> TrainState:
        params, _ = model.init(key)
        if use_arena:
            return TrainState(
                params=params, opt_state=opt.init(), buffer=None,
                arena=arena_mod.init_arena(layout, ring_tau, n_pods,
                                           compression,
                                           variable=variable_delay),
                step=jnp.zeros((), jnp.int32))
        return TrainState(
            params=params,
            opt_state=opt.init(params),
            buffer=delayed.init_buffer(params, tau, n_pods, compression),
            arena=None,
            step=jnp.zeros((), jnp.int32),
        )

    anytime_impl = rc.ambdg.anytime_impl

    def _pod_chunk_grads(params, batch):
        """Returns pod-stacked (grads (n_pods, ...), counts (n_pods,),
        loss sums (n_pods,)). No cross-pod reduction."""
        def one_chunk(chunk):
            n_active = chunk.get("n_active", jnp.int32(n_mb))
            chunk = {k: v for k, v in chunk.items() if k != "n_active"}
            if anytime_impl == "while_dynamic":
                return anytime.accumulate_while(
                    loss_fn, params, chunk, n_mb, n_active)
            return anytime.accumulate_scan(loss_fn, params, chunk, n_mb)

        if n_pods == 1:
            g, c, m = one_chunk(batch)
            stack = lambda x: x[None]
            return (jax.tree.map(stack, g), c[None], m["loss_sum"][None])

        # reshape (B, ...) -> (n_pods, B/n_pods, ...); dim 0 is sharded
        # over the 'pod' mesh axis so each chunk computes on its own pod
        chunked = jax.tree.map(
            lambda x: x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]),
            batch)
        g, c, m = jax.vmap(one_chunk, in_axes=(0,))(chunked)
        return g, c, m["loss_sum"]

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        from repro.dist.context import sharding_profile
        with sharding_profile(rc.mesh if rc.mesh.n_devices > 1 else None):
            return _train_step_inner(state, batch)

    def _train_step_inner(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        from repro.dist.context import constrain
        tau_obs = None
        if variable_delay:
            if "delay" not in batch:
                raise ValueError(
                    f"rc.delay.process={rc.delay.process!r} needs a "
                    "per-step batch['delay'] scalar (the host loop "
                    "draws it from core.delay_process)")
            delay = batch["delay"]
            batch = {k: v for k, v in batch.items() if k != "delay"}
        b_sched = None
        if variable_batch:
            if "b_sched" not in batch:
                raise ValueError(
                    f"rc.batch_schedule.schedule="
                    f"{rc.batch_schedule.schedule!r} needs a per-step "
                    "batch['b_sched'] scalar (the host loop draws it "
                    "from core.batch_schedule)")
            b_sched = jnp.asarray(batch["b_sched"], jnp.float32)
            batch = {k: v for k, v in batch.items() if k != "b_sched"}
        pod_grads, pod_counts, pod_loss = _pod_chunk_grads(
            state.params, batch)

        if use_arena and variable_delay:
            grad_sum_flat, count, tau_obs, arena_state = \
                arena_mod.push_pop_variable(layout, state.arena,
                                            pod_grads, pod_counts,
                                            delay, compression)
            grad_sum_flat = constrain(grad_sum_flat, ("flat", None))
            # zero-arrival contract: the ring reports tau_obs = 0 when
            # nothing lands, but 0 would tell the Agarwal-Duchi
            # adaptive alpha the stall step was perfectly FRESH and
            # inflate the step size exactly when the network stalled —
            # fall back to the ring cap (the worst case the
            # non-adaptive schedule already uses)
            tau_obs = jnp.where(count > 0.0, tau_obs,
                                jnp.float32(ring_tau))
            # adaptive: observed staleness of THIS update; otherwise
            # the static worst case is the ring cap tau_max (ring_tau)
            # — NOT the nominal cfg.tau a stochastic process exceeds
            params, opt_state = opt.update(
                state.opt_state, state.params, grad_sum_flat, count,
                tau_obs=(tau_obs if rc.delay.adaptive_alpha
                         else float(ring_tau)),
                b_sched=b_sched)
            buffer = None
            g_norm = (jnp.sqrt(jnp.sum(jnp.square(grad_sum_flat)))
                      / jnp.maximum(count, 1e-12))
        elif use_arena:
            params, opt_state, arena_state, grad_sum_flat, count = \
                arena_master_update(layout, opt, state.params,
                                    state.opt_state, state.arena,
                                    pod_grads, pod_counts, compression,
                                    b_sched=b_sched)
            buffer = None
            # scalar divide after the reduce: same value as norm(g/c),
            # without a params-sized elementwise divide for a metric
            g_norm = (jnp.sqrt(jnp.sum(jnp.square(grad_sum_flat)))
                      / jnp.maximum(count, 1e-12))
        else:
            arena_state = None
            if state.buffer is not None:
                grad_sum, count, buffer = delayed.push_pop(
                    state.buffer, pod_grads, pod_counts, compression,
                    params_axes=params_axes)
            else:
                grad_sum = jax.tree.map(delayed.pod_sum, pod_grads)
                count = jnp.sum(pod_counts)
                buffer = None
            g = anytime.normalize(grad_sum, count)
            params, opt_state = opt.update(state.opt_state, state.params, g)
            g_norm = optax_global_norm(g)

        metrics = {
            "loss": jnp.sum(pod_loss) / jnp.maximum(jnp.sum(pod_counts), 1e-12),
            "applied_count": count,
            "local_count": jnp.sum(pod_counts),
            "grad_norm": g_norm,
            "step": state.step + 1,
        }
        if tau_obs is not None:
            # observed staleness of the gradients applied this step
            # (count-weighted). Zero-arrival steps report the ring-cap
            # FALLBACK staleness — the value the step size actually
            # used — never 0 (indistinguishable from genuinely-fresh
            # delivery); ``applied_count == 0`` is the stall signal.
            metrics["tau_applied"] = tau_obs
        return TrainState(params=params, opt_state=opt_state,
                          buffer=buffer, arena=arena_state,
                          step=state.step + 1), metrics

    return init_state, train_step


def optax_global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
