"""AMB-DG composed train step: anytime accumulation -> (delayed) pod
exchange -> dual-averaging update.

``make_train_step(model, rc)`` returns ``(init_state, train_step)``:

    state = init_state(rng)
    state, metrics = train_step(state, batch)

Semantics (paper Sec. III, adapted per DESIGN.md §2):
  * batch leaves are globally-shaped, sharded (pod, data) on dim 0;
    per-sample ``weights`` carry the anytime mask (b_i(t)).
  * gradients are summed per pod chunk (vmap over a pod-stacked view,
    so no cross-pod communication happens in the backward pass), then
    pushed into the tau-deep delay buffer; the popped tau-old entry is
    reduced across pods and fed to dual averaging — the master's
    z(t+1) = z(t) + g(t - tau) pipeline with deterministic staleness.
  * tau = 0 (or a single pod) collapses to the synchronous AMB update.

The optimizer is pluggable (``rc.optimizer``): "dual_averaging" is the
paper; "sgd"/"adam" compose the same delayed anytime gradients with
standard optimizers (beyond-paper comparisons).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.core import anytime, delayed
from repro.core import dual_averaging as da
from repro.models.api import Model


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    buffer: Optional[delayed.DelayBuffer]
    step: jax.Array


def _loss_with_remat(model: Model, rc: RunConfig):
    # Remat lives at the scanned-block level (ModelConfig.block_remat);
    # a whole-loss checkpoint would still store per-layer scan residuals
    # during the recompute, so rc.remat is only kept for ablations.
    loss = lambda p, b: model.loss(p, b)
    if rc.remat == "whole_loss":
        loss = jax.checkpoint(loss)
    return loss


def make_train_step(model: Model, rc: RunConfig):
    from repro.optim import make_optimizer  # lazy: optim imports core
    n_pods = rc.mesh.n_pods
    tau = rc.ambdg.tau
    n_mb = rc.ambdg.n_microbatches
    compression = rc.ambdg.pod_compression
    opt = make_optimizer(rc)
    loss_fn = _loss_with_remat(model, rc)
    params_axes = None
    if compression == "int8":
        from repro.dist import shapes_and_axes
        _, params_axes = shapes_and_axes(model.init, jax.random.PRNGKey(0))

    def init_state(key) -> TrainState:
        params, _ = model.init(key)
        return TrainState(
            params=params,
            opt_state=opt.init(params),
            buffer=delayed.init_buffer(params, tau, n_pods, compression),
            step=jnp.zeros((), jnp.int32),
        )

    anytime_impl = rc.ambdg.anytime_impl

    def _pod_chunk_grads(params, batch):
        """Returns pod-stacked (grads (n_pods, ...), counts (n_pods,),
        loss sums (n_pods,)). No cross-pod reduction."""
        def one_chunk(chunk):
            n_active = chunk.get("n_active", jnp.int32(n_mb))
            chunk = {k: v for k, v in chunk.items() if k != "n_active"}
            if anytime_impl == "while_dynamic":
                return anytime.accumulate_while(
                    loss_fn, params, chunk, n_mb, n_active)
            return anytime.accumulate_scan(loss_fn, params, chunk, n_mb)

        if n_pods == 1:
            g, c, m = one_chunk(batch)
            stack = lambda x: x[None]
            return (jax.tree.map(stack, g), c[None], m["loss_sum"][None])

        # reshape (B, ...) -> (n_pods, B/n_pods, ...); dim 0 is sharded
        # over the 'pod' mesh axis so each chunk computes on its own pod
        chunked = jax.tree.map(
            lambda x: x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]),
            batch)
        g, c, m = jax.vmap(one_chunk, in_axes=(0,))(chunked)
        return g, c, m["loss_sum"]

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        from repro.dist.context import sharding_profile
        with sharding_profile(rc.mesh if rc.mesh.n_devices > 1 else None):
            return _train_step_inner(state, batch)

    def _train_step_inner(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        pod_grads, pod_counts, pod_loss = _pod_chunk_grads(
            state.params, batch)

        if state.buffer is not None:
            grad_sum, count, buffer = delayed.push_pop(
                state.buffer, pod_grads, pod_counts, compression,
                params_axes=params_axes)
        else:
            grad_sum = jax.tree.map(lambda g: jnp.sum(g, axis=0), pod_grads)
            count = jnp.sum(pod_counts)
            buffer = None

        g = anytime.normalize(grad_sum, count)
        params, opt_state = opt.update(state.opt_state, state.params, g)

        metrics = {
            "loss": jnp.sum(pod_loss) / jnp.maximum(jnp.sum(pod_counts), 1e-12),
            "applied_count": count,
            "local_count": jnp.sum(pod_counts),
            "grad_norm": optax_global_norm(g),
            "step": state.step + 1,
        }
        return TrainState(params=params, opt_state=opt_state,
                          buffer=buffer, step=state.step + 1), metrics

    return init_state, train_step


def optax_global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
