"""Anytime (variable-size) minibatch gradient accumulation.

The paper's workers compute gradients for a fixed time T_p and ship
(sum_of_gradients, count). On an SPMD TPU program we express this as
accumulation over a budget of ``n_microbatches`` scanned microbatches
with per-sample 0/1 ``weights`` carrying the anytime mask — a shard that
"finished" only b_i of its samples contributes exactly the paper's
(g_i(t), b_i(t)). Aggregation across shards then normalizes by the
*global* count (paper eq. (5)): g(t) = sum_i g_i / sum_i b_i.

Two implementations:
  * ``scan_masked``  — lax.scan over the full microbatch budget, masked.
    Deterministic FLOPs (used for dry-run/roofline); wasted compute on
    masked samples is the SPMD price of staying bulk-synchronous.
  * ``while_dynamic`` — lax.while_loop with a *per-shard dynamic trip
    count* (no collectives inside the body, so devices may genuinely run
    different iteration counts and re-sync only at the reduction). Zero
    wasted FLOPs on stragglers; the deployment mode on real hardware.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

LossFn = Callable[[Any, Dict], Tuple[jax.Array, Dict]]


def _split_batch(batch: Dict, n_mb: int) -> Dict:
    """Reshape every leaf (B, ...) -> (n_mb, B//n_mb, ...), keeping the
    *second* dim batch-sharded (GSPMD would otherwise try to shard the
    small n_mb dim and replicate the rest — see dist.context)."""
    from repro.dist.context import constrain

    def r(x):
        b = x.shape[0]
        assert b % n_mb == 0, f"batch {b} not divisible by {n_mb} microbatches"
        out = x.reshape((n_mb, b // n_mb) + x.shape[1:])
        return constrain(out, (None, "batch") + (None,) * (out.ndim - 2))
    return jax.tree.map(r, batch)


def accumulate_scan(loss_fn: LossFn, params, batch: Dict, n_mb: int):
    """Masked scan accumulation.

    Returns (grad_sum, count, metrics) where grad_sum is the *sum* of
    per-sample gradients (weighted), count the weighted sample/token
    count — exactly the worker message m_i(t) = (g_i(t), b_i(t)).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if n_mb == 1:  # no scan: keeps roofline measurement loop-free
        (loss_sum, aux), g = grad_fn(params, batch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        return g, aux["count"], {"loss_sum": aux["loss_sum"]}
    mbs = _split_batch(batch, n_mb)

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    carry0 = (zeros, jnp.float32(0.0), jnp.float32(0.0))

    def body(carry, mb):
        gsum, csum, lsum = carry
        (loss_sum, aux), g = grad_fn(params, mb)
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        return (gsum, csum + aux["count"], lsum + aux["loss_sum"]), None

    (gsum, count, loss_sum), _ = jax.lax.scan(body, carry0, mbs)
    return gsum, count, {"loss_sum": loss_sum}


def accumulate_while(loss_fn: LossFn, params, batch: Dict, n_mb: int,
                     n_active):
    """Dynamic-trip-count accumulation: runs ``n_active`` (<= n_mb)
    microbatches. ``n_active`` may differ across shards — there are no
    collectives in the body, so each device runs its own count and the
    program re-synchronizes at the first cross-device reduction after.
    """
    mbs = _split_batch(batch, n_mb)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def cond(state):
        i, *_ = state
        return i < n_active

    def body(state):
        i, gsum, csum, lsum = state
        mb = jax.tree.map(lambda x: x[i], mbs)
        (loss_sum, aux), g = grad_fn(params, mb)
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        return (i + 1, gsum, csum + aux["count"], lsum + aux["loss_sum"])

    _, gsum, count, loss_sum = jax.lax.while_loop(
        cond, body, (jnp.int32(0), zeros, jnp.float32(0.0), jnp.float32(0.0)))
    return gsum, count, {"loss_sum": loss_sum}


def normalize(grad_sum, count):
    """g(t) = (sum of gradients) / (total count), guarding count=0 (a
    fully-failed epoch contributes a zero update, not NaNs). A plain
    division: the downstream dual add cannot FMA-contract with it, so
    no pinning is needed for pytree/arena bit-equality."""
    denom = jnp.maximum(count, 1e-12)
    return jax.tree.map(lambda g: g / denom, grad_sum)
