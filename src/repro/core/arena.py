"""Persistent, lane-aligned flat gradient arena — the master pipeline's
memory layout.

Every leaf of the parameter pytree is flattened and padded up to a
whole number of 128-lane rows; leaves are laid out back to back in one
``(rows, 128)`` f32 buffer (rows padded to a multiple of the kernel
block). The layout is computed ONCE at ``init_state`` and carried as a
static closure constant (``ArenaLayout``); per-step work never
re-flattens the tree with ``jnp.concatenate`` — the gradient is
scattered into a preallocated buffer with static-offset update-slices,
and the dual variable ``z``, the tau-deep delay ring, and the int8
error-feedback residual live in arena form permanently.

Row alignment is what makes int8 compression cheap here: every row
belongs to exactly one leaf, so the pytree path's *per-tensor* scales
become *per-row* vectors through a static row->leaf map — elementwise
multiplies in the kernel, no gathers — while staying bit-identical to
the per-tensor reference (a max is a max regardless of reduction
order).

See docs/arena.md for the full memory-layout and donation contract.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LANES = 128
BLOCK_ROWS = 256  # kernel grid block; total rows padded to a multiple


class ArenaLayout:
    """Static flatten metadata (plain Python: safe to close over)."""

    def __init__(self, treedef, shapes, dtypes):
        self.treedef = treedef
        self.shapes = tuple(tuple(s) for s in shapes)
        self.dtypes = tuple(dtypes)
        self.sizes = tuple(int(np.prod(s)) if s else 1 for s in self.shapes)
        self.row_counts = tuple(-(-s // LANES) for s in self.sizes)
        offs, o = [], 0
        for rc in self.row_counts:
            offs.append(o)
            o += rc
        self.row_offsets = tuple(offs)
        self.n_leaves = len(self.sizes)
        self.rows = -(-o // BLOCK_ROWS) * BLOCK_ROWS
        # static row -> leaf map; tail-pad rows get the sentinel segment
        # ``n_leaves`` (their scale is pinned to 1, their data to 0)
        r2l = np.full((self.rows,), self.n_leaves, np.int32)
        for i, (ro, rc) in enumerate(zip(self.row_offsets, self.row_counts)):
            r2l[ro:ro + rc] = i
        self.row_to_leaf = r2l

    @property
    def numel(self) -> int:
        return self.rows * LANES


def make_layout(params) -> ArenaLayout:
    """Build the layout from a parameter pytree (arrays or
    ShapeDtypeStructs). Called once at init — never per step."""
    leaves, treedef = jax.tree.flatten(params)
    return ArenaLayout(treedef, [l.shape for l in leaves],
                       [l.dtype for l in leaves])


def flatten_tree(layout: ArenaLayout, tree, leading: int = 0, out=None):
    """Scatter a pytree into arena form: ``(*lead, rows, 128)`` f32.

    ``leading`` counts extra leading dims shared by every leaf (the
    pod-stacked gradient uses leading=1). Uses static-offset
    dynamic-update-slices — no ``concatenate`` (asserted by
    tests/test_arena.py). Pass the arena's persistent ``staging``
    buffer as ``out`` to make the whole scatter in-place under
    donation (an order of magnitude faster than materializing a fresh
    buffer: no zero-fill, no allocation, just the leaf writes).
    """
    leaves = layout.treedef.flatten_up_to(tree)
    lead = leaves[0].shape[:leading] if leaves else ()
    if out is None:
        out = jnp.zeros(lead + (layout.rows, LANES), jnp.float32)
    # NB: never reshape ``out`` — reshaping the donated accumulator
    # breaks XLA's in-place update-slice chain (measured 10x on CPU);
    # scatter along the row axis instead.
    for leaf, ofs, size, rc in zip(leaves, layout.row_offsets,
                                   layout.sizes, layout.row_counts):
        x = _padded_leaf(leaf, size, rc, leading)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, x.reshape(lead + (rc, LANES)), ofs, axis=leading)
    return out


def _padded_leaf(leaf, size: int, rc: int, leading: int):
    """One leaf as a (*lead, rc*128) f32 row-aligned strip."""
    lead = leaf.shape[:leading]
    x = leaf.reshape(lead + (size,)).astype(jnp.float32)
    pad = rc * LANES - size
    if pad:
        x = jnp.pad(x, [(0, 0)] * leading + [(0, pad)])
    return x


def scatter_fed(layout: ArenaLayout, tree, residual, out):
    """int8 error feedback: build fed = g + residual in arena form with
    one scatter pass (leaf read + residual-row read + in-place write
    into the staging buffer), instead of flatten-then-add."""
    n_pods = residual.shape[0]
    leaves = layout.treedef.flatten_up_to(tree)
    for leaf, ofs, size, rc in zip(leaves, layout.row_offsets,
                                   layout.sizes, layout.row_counts):
        r = jax.lax.dynamic_slice(residual, (0, ofs, 0),
                                  (n_pods, rc, LANES))
        x = _padded_leaf(leaf, size, rc, 1).reshape(n_pods, rc, LANES) + r
        out = jax.lax.dynamic_update_slice(out, x, (0, ofs, 0))
    return out


def unflatten_tree(layout: ArenaLayout, mat, cast: bool = True, scale=None):
    """Gather arena rows back into the pytree (static slices — reads,
    not copies-of-everything). ``cast=False`` keeps every leaf f32
    (the dual-averaging ``w`` convention); ``cast=True`` restores the
    layout dtypes. ``scale`` multiplies each slice on the way out —
    the dual-averaging prox (w = -alpha z) rides the gather for free
    instead of materializing a separate w buffer."""
    lead = mat.shape[:-2]
    flat = mat.reshape(lead + (layout.numel,))
    out = []
    for ofs, size, shape, dtype in zip(layout.row_offsets, layout.sizes,
                                       layout.shapes, layout.dtypes):
        x = jax.lax.slice_in_dim(flat, ofs * LANES, ofs * LANES + size,
                                 axis=len(lead))
        if scale is not None:
            x = scale * x
        x = x.reshape(lead + shape)
        out.append(x.astype(dtype) if cast else x)
    return layout.treedef.unflatten(out)


def _scatter_slot(layout: ArenaLayout, ring, tree, head):
    """Per-leaf scatter straight into ring[head]. A ``lax.switch`` over
    the (static, small) tau slots keeps every update-slice STATICALLY
    indexed — XLA:CPU then writes in place, where a dynamic head index
    degrades every chained update into a full ring copy."""
    tau, n_pods = ring.shape[:2]
    leaves = layout.treedef.flatten_up_to(tree)
    strips = [
        _padded_leaf(leaf, size, rc, 1).reshape(n_pods, rc, LANES)
        for leaf, size, rc in zip(leaves, layout.sizes, layout.row_counts)]

    def branch(k):
        def push(r):
            for strip, ofs in zip(strips, layout.row_offsets):
                r = jax.lax.dynamic_update_slice(
                    r, strip[None].astype(r.dtype), (k, 0, ofs, 0))
            return r
        return push

    return jax.lax.switch(head, [branch(k) for k in range(tau)], ring)


def _update_slot_int8(ring, scales, q, scale_new, head):
    """Write the quantized slot + its per-row scales with static slot
    indices (same lax.switch trick as _scatter_slot)."""
    tau = ring.shape[0]

    def branch(k):
        def push(r, s):
            r = jax.lax.dynamic_update_slice(r, q[None], (k, 0, 0, 0))
            s = jax.lax.dynamic_update_slice(s, scale_new[None], (k, 0, 0))
            return r, s
        return push

    return jax.lax.switch(head, [branch(k) for k in range(tau)],
                          ring, scales)


# ---------------------------------------------------------------------------
# Delay state in arena form
# ---------------------------------------------------------------------------
class GradArena(NamedTuple):
    """The tau-deep delay ring + int8 error feedback, all contiguous.
    ``ring`` is f32 (compression="none") or int8; per-row scales and
    the residual exist only under int8. The pod dim is preserved so
    GSPMD can keep the ring pod-sharded (the pop's pod-sum is the DCN
    all-reduce, exactly as in the pytree path).

    ``staging`` is the persistent scratch the per-step gradient tree is
    scattered into (int8's fed buffer, and the Pallas path's
    contiguous kernel operand): because it lives in the (donated)
    train state, the scatter is a chain of in-place static-offset
    writes — no per-step allocation or zero-fill. The uncompressed
    XLA path scatters straight into the ring slot and carries no
    staging at all (a params-sized x n_pods buffer of dead memory and
    checkpoint bytes otherwise). Staging contents are scratch
    (rewritten in full every step) but checkpointed when present:
    exactness of restore is easier to audit than to argue about."""
    ring: jax.Array                 # (tau, n_pods, rows, 128) f32|int8
    scales: Optional[jax.Array]     # (tau, n_pods, rows) f32 — int8 only
    residual: Optional[jax.Array]   # (n_pods, rows, 128) f32 — int8 only
    staging: Optional[jax.Array]    # (n_pods, rows, 128) f32 scratch
    counts: jax.Array               # (tau, n_pods) f32
    head: jax.Array                 # () i32: next slot = oldest entry


def init_arena(layout: ArenaLayout, tau: int, n_pods: int,
               compression: str = "none") -> Optional[GradArena]:
    if tau == 0:
        return None
    R = layout.rows
    # staging presence depends only on the CONFIG (int8), never on the
    # backend: TrainState structure and the checkpoint key-set must be
    # identical across hosts (a CPU-saved checkpoint restores on TPU).
    # The Pallas "none" path simply allocates its kernel operand fresh.
    staging = None
    if compression == "int8":
        ring = jnp.zeros((tau, n_pods, R, LANES), jnp.int8)
        scales = jnp.ones((tau, n_pods, R), jnp.float32)
        residual = jnp.zeros((n_pods, R, LANES), jnp.float32)
        staging = jnp.zeros((n_pods, R, LANES), jnp.float32)
    else:
        ring = jnp.zeros((tau, n_pods, R, LANES), jnp.float32)
        scales = residual = None
    return GradArena(ring=ring, scales=scales, residual=residual,
                     staging=staging,
                     counts=jnp.zeros((tau, n_pods), jnp.float32),
                     head=jnp.zeros((), jnp.int32))


def arena_logical_axes(arena: GradArena) -> GradArena:
    """Logical axes per arena field (None fields stay None). Rows shard
    over the intra-pod slice ("flat"); slots replicated; pods on 'pod'."""
    return GradArena(
        ring=(None, "pod", "flat", None),
        scales=None if arena.scales is None else (None, "pod", "flat"),
        residual=None if arena.residual is None else ("pod", "flat", None),
        staging=None if arena.staging is None else ("pod", "flat", None),
        counts=(None, "pod"),
        head=(),
    )


def row_scales(layout: ArenaLayout, fed) -> jax.Array:
    """Per-row int8 scales reproducing the pytree path's per-(pod,leaf)
    symmetric scales bit-exactly. fed: (n_pods, rows, 128) f32 — the
    error-fed gradient. One elementwise pass + a segment-max over the
    static row->leaf map; no per-leaf kernel launches."""
    rowmax = jnp.max(jnp.abs(fed), axis=-1)                 # (n_pods, rows)
    amax = jax.ops.segment_max(rowmax.T, layout.row_to_leaf,
                               num_segments=layout.n_leaves + 1,
                               indices_are_sorted=True)     # (leaves+1, pods)
    # sentinel segment (tail pad rows / empty) -> scale 1: pads are zero
    amax = amax.at[layout.n_leaves].set(127.0)
    scales = jnp.maximum(amax, 1e-12) / 127.0               # pytree formula
    return scales[layout.row_to_leaf].T                     # (n_pods, rows)


def _pop_sum(ring, head, scales=None):
    """Pod-sum of ring[head] (dequantized), mesh-aware.

    Under an active multi-pod sharding profile: pop the whole slot,
    pin the *compressed* payload across the pod axis (int8 — those are
    the actual DCN bytes, mirroring the pytree path's pop_leaf),
    dequantize locally, and reduce with one pod-axis ``jnp.sum`` — the
    reduce GSPMD lowers to the DCN all-reduce.

    Off-mesh: unrolled per-pod slice adds WITHOUT materializing the
    (n_pods, rows, 128) popped buffer — XLA:CPU's axis-0 reduce of a
    dynamic slice is ~4x slower than chained adds."""
    from repro.dist.context import active_mesh, constrain
    _, n_pods, rows, _ = ring.shape
    head = jnp.asarray(head, jnp.int32)

    mesh = active_mesh()
    if mesh is not None and mesh.n_pods > 1:
        popped = jax.lax.dynamic_index_in_dim(ring, head, 0,
                                              keepdims=False)
        if scales is not None:
            # pod-REPLICATE the int8 payload (as the pytree pop_leaf
            # does): the gather of the compressed bytes is the actual
            # DCN transfer; dequantization happens after, locally
            popped = constrain(popped, (None, "flat", None))
            s = jax.lax.dynamic_index_in_dim(scales, head, 0,
                                             keepdims=False)
            s = constrain(s, (None, "flat"))
            popped = jax.lax.optimization_barrier(
                popped.astype(jnp.float32) * s[..., None])
        return jnp.sum(popped, axis=0)

    acc = None
    for p in range(n_pods):
        x = jax.lax.dynamic_slice(
            ring, (head, jnp.int32(p), jnp.int32(0), jnp.int32(0)),
            (1, 1, rows, LANES)).reshape(rows, LANES)
        if scales is not None:
            s = jax.lax.dynamic_slice(
                scales, (head, jnp.int32(p), jnp.int32(0)),
                (1, 1, rows)).reshape(rows)
            # barrier mirrors delayed._dequantize: without it the
            # accumulate contracts to fma(q, s, acc) and drifts a ULP
            # off the pytree reference
            x = jax.lax.optimization_barrier(
                x.astype(jnp.float32) * s[:, None])
        acc = x if acc is None else acc + x
    return acc


def push_pop(layout: ArenaLayout, arena: GradArena, pod_grads, pod_counts,
             compression: str = "none", impl: str = "auto",
             interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array, GradArena]:
    """Arena twin of ``delayed.push_pop``: insert this step's
    pod-stacked gradient *tree*, return the tau-old entry summed over
    pods (the DCN collective) and the updated arena.

    pod_grads: pytree, leaves (n_pods, *shape). Returns
    (grad_sum (rows, 128) f32, count (), new_arena).

    impl="auto" picks the Pallas kernel on single-pod TPU (the
    gradient is flattened into one contiguous kernel operand there — a
    single HBM pass) and the scatter/XLA path elsewhere (leaves land
    straight in the ring slot / fed buffer, skipping that pass: on CPU
    the standalone flatten is the single most expensive piece of the
    step). Multi-pod meshes also resolve to the XLA path: a bare
    pallas_call on the pod-sharded ring would make GSPMD gather the
    whole ring — the kernel needs a shard_map wrapper first (ROADMAP
    open item).
    """
    from repro.kernels import resolve_impl
    from repro.kernels.delay_ring.ops import ring_push_pop

    impl = resolve_impl(impl)
    head = arena.head
    old_count = arena.counts[head]

    if impl == "pallas":
        g_flat = flatten_tree(layout, pod_grads, leading=1,
                              out=arena.staging)
        if compression == "int8":
            # form fed once: the scale pass needs it, and the kernel
            # consumes it directly (writing the new residual into its
            # buffer) — no second g + residual add on the TPU path
            fed = g_flat + arena.residual
            scale_new = row_scales(layout, fed)
            popped, ring, scales, residual = ring_push_pop(
                arena.ring, fed, head, scales=arena.scales,
                scale_new=scale_new, impl="pallas", interpret=interpret)
            # buffer swap: the old residual becomes next step's scratch
            staging = arena.residual
        else:
            popped, ring, scales, residual = ring_push_pop(
                arena.ring, g_flat, head, impl="pallas",
                interpret=interpret)
            # "none" carries no staging (g_flat was a fresh temp) —
            # keep the state structure identical to init_arena's
            staging = arena.staging
        from repro.core.delayed import pod_sum
        grad_sum = pod_sum(popped)          # pod sum = DCN all-reduce
    elif compression == "int8":
        fed = scatter_fed(layout, pod_grads, arena.residual,
                          out=arena.staging)
        scale_new = row_scales(layout, fed)
        grad_sum = _pop_sum(arena.ring, head, arena.scales)
        s = scale_new[..., None]
        q = jnp.clip(jnp.round(fed / s), -127, 127)
        # XLA sequences the slot read above ahead of this in-place
        # overwrite itself (copy-protection where it must)
        ring, scales = _update_slot_int8(arena.ring, arena.scales,
                                         q.astype(jnp.int8), scale_new,
                                         head)
        # barrier mirrors delayed._dequantize: no FMA contraction, so
        # the residual stays bit-identical to the pytree reference
        residual = fed - jax.lax.optimization_barrier(q * s)
        staging = fed
    else:
        grad_sum = _pop_sum(arena.ring, head)
        ring = _scatter_slot(layout, arena.ring, pod_grads, head)
        staging = arena.staging    # untouched pass-through (zero cost)
        scales = residual = None

    count = jnp.sum(old_count)
    new_arena = GradArena(
        ring=ring, scales=scales, residual=residual, staging=staging,
        counts=arena.counts.at[head].set(pod_counts),
        head=(head + 1) % arena.counts.shape[0])
    return grad_sum, count, new_arena
