"""Persistent, lane-aligned flat gradient arena — the master pipeline's
memory layout.

Every leaf of the parameter pytree is flattened and padded up to a
whole number of 128-lane rows; leaves are laid out back to back in one
``(rows, 128)`` f32 buffer (rows padded to a multiple of the kernel
block). The layout is computed ONCE at ``init_state`` and carried as a
static closure constant (``ArenaLayout``); per-step work never
re-flattens the tree with ``jnp.concatenate`` — the gradient is
scattered into a preallocated buffer with static-offset update-slices,
and the dual variable ``z``, the delay ring, and the int8
error-feedback residual live in arena form permanently.

Row alignment is what makes int8 compression cheap here: every row
belongs to exactly one leaf, so the pytree path's *per-tensor* scales
become *per-row* vectors through a static row->leaf map — elementwise
multiplies in the kernel, no gathers — while staying bit-identical to
the per-tensor reference (a max is a max regardless of reduction
order).

The delay ring has three layouts (see ``GradArena``): the default v2
stores one buffer per slot (tau+1 of them) and selects slots with
STATIC indices from a phase counter carried as static pytree aux data,
which is what removes XLA:CPU's copy-protection entirely; v1 is the
single stacked (tau, ...) buffer, kept for migration and as a layout
oracle; v3 is the delay-tolerant (variable per-step delay) ring — one
STACKED (n_slots, ...) buffer like v1, but still pushed at the v2
phase schedule's static slot index (so the writes stay in-place), with
per-slot due/stale metadata driving a masked pop that can read the
whole ring in a single pass (gather the due slots on CPU; one Pallas
kernel launch + one cross-pod reduce on TPU meshes).

See docs/arena.md for the full memory-layout and donation contract.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LANES = 128
BLOCK_ROWS = 256  # kernel grid block; total rows padded to a multiple


class ArenaLayout:
    """Static flatten metadata (plain Python: safe to close over)."""

    def __init__(self, treedef, shapes, dtypes):
        self.treedef = treedef
        self.shapes = tuple(tuple(s) for s in shapes)
        self.dtypes = tuple(dtypes)
        self.sizes = tuple(int(np.prod(s)) if s else 1 for s in self.shapes)
        self.row_counts = tuple(-(-s // LANES) for s in self.sizes)
        offs, o = [], 0
        for rc in self.row_counts:
            offs.append(o)
            o += rc
        self.row_offsets = tuple(offs)
        self.n_leaves = len(self.sizes)
        self.rows = -(-o // BLOCK_ROWS) * BLOCK_ROWS
        # static row -> leaf map; tail-pad rows get the sentinel segment
        # ``n_leaves`` (their scale is pinned to 1, their data to 0)
        r2l = np.full((self.rows,), self.n_leaves, np.int32)
        for i, (ro, rc) in enumerate(zip(self.row_offsets, self.row_counts)):
            r2l[ro:ro + rc] = i
        self.row_to_leaf = r2l

    @property
    def numel(self) -> int:
        return self.rows * LANES


def make_layout(params) -> ArenaLayout:
    """Build the layout from a parameter pytree (arrays or
    ShapeDtypeStructs). Called once at init — never per step."""
    leaves, treedef = jax.tree.flatten(params)
    return ArenaLayout(treedef, [l.shape for l in leaves],
                       [l.dtype for l in leaves])


def flatten_tree(layout: ArenaLayout, tree, leading: int = 0, out=None):
    """Scatter a pytree into arena form: ``(*lead, rows, 128)`` f32.

    ``leading`` counts extra leading dims shared by every leaf (the
    pod-stacked gradient uses leading=1). Uses static-offset
    dynamic-update-slices — no ``concatenate`` (asserted by
    tests/test_arena.py). Pass a persistent donated buffer (the
    arena's ``staging``, or the ring slot being overwritten) as
    ``out`` to make the whole scatter in-place (an order of magnitude
    faster than materializing a fresh buffer: no zero-fill, no
    allocation, just the leaf writes).
    """
    leaves = layout.treedef.flatten_up_to(tree)
    lead = leaves[0].shape[:leading] if leaves else ()
    if out is None:
        out = jnp.zeros(lead + (layout.rows, LANES), jnp.float32)
    # NB: never reshape ``out`` — reshaping the donated accumulator
    # breaks XLA's in-place update-slice chain (measured 10x on CPU);
    # scatter along the row axis instead.
    for leaf, ofs, size, rc in zip(leaves, layout.row_offsets,
                                   layout.sizes, layout.row_counts):
        x = _padded_leaf(leaf, size, rc, leading)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, x.reshape(lead + (rc, LANES)), ofs, axis=leading)
    return out


def _padded_leaf(leaf, size: int, rc: int, leading: int):
    """One leaf as a (*lead, rc*128) f32 row-aligned strip."""
    lead = leaf.shape[:leading]
    x = leaf.reshape(lead + (size,)).astype(jnp.float32)
    pad = rc * LANES - size
    if pad:
        x = jnp.pad(x, [(0, 0)] * leading + [(0, pad)])
    return x


def scatter_fed(layout: ArenaLayout, tree, residual, out):
    """int8 error feedback: build fed = g + residual in arena form with
    one scatter pass (leaf read + residual-row read + in-place write
    into the staging buffer), instead of flatten-then-add."""
    n_pods = residual.shape[0]
    leaves = layout.treedef.flatten_up_to(tree)
    for leaf, ofs, size, rc in zip(leaves, layout.row_offsets,
                                   layout.sizes, layout.row_counts):
        r = jax.lax.dynamic_slice(residual, (0, ofs, 0),
                                  (n_pods, rc, LANES))
        x = _padded_leaf(leaf, size, rc, 1).reshape(n_pods, rc, LANES) + r
        out = jax.lax.dynamic_update_slice(out, x, (0, ofs, 0))
    return out


def unflatten_tree(layout: ArenaLayout, mat, cast: bool = True, scale=None):
    """Gather arena rows back into the pytree (static slices — reads,
    not copies-of-everything). ``cast=False`` keeps every leaf f32
    (the dual-averaging ``w`` convention); ``cast=True`` restores the
    layout dtypes. ``scale`` multiplies each slice on the way out —
    the dual-averaging prox (w = -alpha z) rides the gather for free
    instead of materializing a separate w buffer."""
    lead = mat.shape[:-2]
    flat = mat.reshape(lead + (layout.numel,))
    out = []
    for ofs, size, shape, dtype in zip(layout.row_offsets, layout.sizes,
                                       layout.shapes, layout.dtypes):
        x = jax.lax.slice_in_dim(flat, ofs * LANES, ofs * LANES + size,
                                 axis=len(lead))
        if scale is not None:
            x = scale * x
        x = x.reshape(lead + shape)
        out.append(x.astype(dtype) if cast else x)
    return layout.treedef.unflatten(out)


def _scatter_slot(layout: ArenaLayout, ring, tree, head):
    """v1: per-leaf scatter straight into ring[head]. A ``lax.switch``
    over the (static, small) tau slots keeps every update-slice
    STATICALLY indexed — XLA:CPU then writes in place, where a dynamic
    head index degrades every chained update into a full ring copy."""
    tau, n_pods = ring.shape[:2]
    leaves = layout.treedef.flatten_up_to(tree)
    strips = [
        _padded_leaf(leaf, size, rc, 1).reshape(n_pods, rc, LANES)
        for leaf, size, rc in zip(leaves, layout.sizes, layout.row_counts)]

    def branch(k):
        def push(r):
            for strip, ofs in zip(strips, layout.row_offsets):
                r = jax.lax.dynamic_update_slice(
                    r, strip[None].astype(r.dtype), (k, 0, ofs, 0))
            return r
        return push

    return jax.lax.switch(head, [branch(k) for k in range(tau)], ring)


def _update_slot_int8(ring, scales, q, scale_new, head):
    """v1: write the quantized slot + its per-row scales with static
    slot indices (same lax.switch trick as _scatter_slot)."""
    tau = ring.shape[0]

    def branch(k):
        def push(r, s):
            r = jax.lax.dynamic_update_slice(r, q[None], (k, 0, 0, 0))
            s = jax.lax.dynamic_update_slice(s, scale_new[None], (k, 0, 0))
            return r, s
        return push

    return jax.lax.switch(head, [branch(k) for k in range(tau)],
                          ring, scales)


# ---------------------------------------------------------------------------
# Delay state in arena form
# ---------------------------------------------------------------------------
_ARENA_FIELDS = ("ring", "scales", "residual", "staging", "counts", "head",
                 "due", "stale")


@jax.tree_util.register_pytree_with_keys_class
class GradArena:
    """The delay ring + int8 error feedback, all contiguous. ``ring``
    is f32 (compression="none") or int8; per-row scales and the
    residual exist only under int8. The pod dim is preserved so GSPMD
    can keep the ring pod-sharded (the pop's pod-sum is the DCN
    all-reduce, exactly as in the pytree path).

    Three ring layouts:

      v2 (default)  ``ring`` is a TUPLE of tau+1 per-slot (n_pods,
                    rows, 128) buffers (``scales`` a tuple of (n_pods,
                    rows); ``counts`` (tau+1, n_pods)). The slot
                    schedule lives in ``phase`` — static pytree AUX
                    data, not a traced array — so each step pops slot
                    ``(phase+1) % (tau+1)`` and overwrites slot
                    ``phase`` with fully STATIC indices on two
                    *different* donated buffers. XLA:CPU then inserts
                    NO copy-protection at all (a same-buffer pop/push
                    costs 2 slot copies; any dynamic slot choice —
                    ``lax.switch`` or a dynamic index — costs 2-3
                    whole-ring copies per step, measured). The price is
                    one spare slot of memory and one retrace per phase
                    (jit sees tau+1 input structures, then cycles).
      v1            one (tau, n_pods, rows, 128) buffer; the slot is a
                    dynamic head index (lax.switch on CPU, scalar-
                    prefetched Pallas kernel on TPU); ``phase`` stays
                    0 and is unused. Kept constructible for the
                    bit-exactness matrix and checkpoint migration
                    (restore() splits a v1 ring into v2 slots).
      v3            the delay-tolerant (variable-delay) ring: STACKED
                    (n_slots, n_pods, rows, 128) like v1, but pushed at
                    the v2 phase schedule's STATIC slot index (a
                    static-index update-slice — in-place on the donated
                    buffer, no copy-protection), with per-slot ``due``/
                    ``stale`` metadata driving the masked pop. Stacking
                    is what makes the pop a SINGLE pass: a
                    data-dependent gather of the O(arrivals) due slots
                    on CPU, one Pallas kernel launch streaming all
                    slots on TPU (impossible on a tuple of slots).

    ``head`` stays an array leaf in BOTH layouts: under v2 it mirrors
    ``phase`` (a trace-time constant) so checkpoints record where the
    schedule stood — restore re-derives the static phase from it.

    ``staging`` is the persistent scratch the per-step gradient tree is
    scattered into (int8's fed buffer): because it lives in the
    (donated) train state, the scatter is a chain of in-place
    static-offset writes — no per-step allocation or zero-fill. The
    uncompressed path scatters straight into the ring's push slot and
    carries no staging at all (a params-sized x n_pods buffer of dead
    memory and checkpoint bytes otherwise). Staging contents are
    scratch (rewritten in full every step) but checkpointed when
    present: exactness of restore is easier to audit than to argue
    about.

    Delay-tolerant (variable-delay) rings additionally carry ``due``
    and ``stale`` — per-slot i32 vectors recording the absolute step a
    slot's entry is to be applied at and the delay it was pushed with
    (see ``push_pop_variable``). Both are None on fixed-tau rings, so
    the fixed-mode state structure (and its checkpoints) is unchanged;
    ``head`` doubles as the absolute step counter in variable mode
    (``phase`` still mirrors ``head % n_slots``, so
    ``sync_ring_phase`` restores the schedule unchanged)."""

    __slots__ = _ARENA_FIELDS + ("phase",)

    def __init__(self, ring, scales, residual, staging, counts, head,
                 due=None, stale=None, phase: int = 0):
        self.ring = ring            # v2: tuple of (n_pods, rows, 128);
                                    # v1/v3: stacked (n_slots, ...)
        self.scales = scales        # v2: tuple of (n_pods, rows) — int8
        self.residual = residual    # (n_pods, rows, 128) f32 — int8 only
        self.staging = staging      # (n_pods, rows, 128) f32 scratch
        self.counts = counts        # (tau+1, n_pods) f32 (v1: (tau, ...))
        self.head = head            # () i32: next slot to overwrite
        self.due = due              # (n_slots,) i32 — variable rings only
        self.stale = stale          # (n_slots,) i32 — variable rings only
        self.phase = int(phase)     # STATIC slot schedule position (v2)

    def _replace(self, **kw) -> "GradArena":
        vals = {f: getattr(self, f) for f in self.__slots__}
        vals.update(kw)
        return GradArena(**vals)

    def tree_flatten_with_keys(self):
        children = tuple((jax.tree_util.GetAttrKey(f), getattr(self, f))
                         for f in _ARENA_FIELDS)
        return children, self.phase

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, phase=aux)

    def __repr__(self):
        return (f"GradArena(phase={self.phase}, " +
                ", ".join(f"{f}={getattr(self, f)!r}"
                          for f in _ARENA_FIELDS) + ")")


RING_VERSION = 2  # layout written by init_arena (v1 kept for tests/migration)


def init_arena(layout: ArenaLayout, tau: int, n_pods: int,
               compression: str = "none",
               ring_version: int = RING_VERSION,
               variable: bool = False) -> Optional[GradArena]:
    """Allocate the delay state. ``tau`` is the staleness depth; with
    ``variable=True`` it is the CAP ``tau_max`` of a stochastic delay
    process and the ring becomes delay-tolerant: the v2 static-phase
    schedule over tau+1 slots plus per-slot ``due``/``stale`` metadata
    (``push_pop_variable`` consumes it), stored STACKED as one
    (tau+1, n_pods, rows, 128) buffer — layout v3 — so the pop can
    dynamically gather the due slots (CPU) or stream them through one
    Pallas kernel (TPU) instead of reading tau+1 separate buffers."""
    if tau == 0:
        return None
    if ring_version not in (1, 2):
        raise ValueError(f"unknown ring_version {ring_version!r}")
    if variable and ring_version != 2:
        raise ValueError("the delay-tolerant (variable-delay) ring "
                         "extends the default v2 schedule (stored "
                         "stacked as layout v3); ring_version=1 has no "
                         "delay-tolerant form")
    R = layout.rows
    v2 = ring_version == 2
    n_slots = tau + 1 if v2 else tau
    stacked = variable or not v2   # v1 and v3 share the stacked shape
    # staging presence depends only on the CONFIG (int8), never on the
    # backend: TrainState structure and the checkpoint key-set must be
    # identical across hosts (a CPU-saved checkpoint restores on TPU).
    staging = None
    if compression == "int8":
        if stacked:
            ring = jnp.zeros((n_slots, n_pods, R, LANES), jnp.int8)
            scales = jnp.ones((n_slots, n_pods, R), jnp.float32)
        else:
            ring = tuple(jnp.zeros((n_pods, R, LANES), jnp.int8)
                         for _ in range(n_slots))
            scales = tuple(jnp.ones((n_pods, R), jnp.float32)
                           for _ in range(n_slots))
        residual = jnp.zeros((n_pods, R, LANES), jnp.float32)
        staging = jnp.zeros((n_pods, R, LANES), jnp.float32)
    else:
        if stacked:
            ring = jnp.zeros((n_slots, n_pods, R, LANES), jnp.float32)
        else:
            ring = tuple(jnp.zeros((n_pods, R, LANES), jnp.float32)
                         for _ in range(n_slots))
        scales = residual = None
    due = stale = None
    if variable:
        # due = -1: never applied (matches no step counter, which
        # starts at 0); stale = 0 until a real push tags the slot
        due = jnp.full((n_slots,), -1, jnp.int32)
        stale = jnp.zeros((n_slots,), jnp.int32)
    return GradArena(ring=ring, scales=scales, residual=residual,
                     staging=staging,
                     counts=jnp.zeros((n_slots, n_pods), jnp.float32),
                     head=jnp.zeros((), jnp.int32), due=due, stale=stale,
                     phase=0)


def ring_version(arena: GradArena) -> int:
    """2 when the ring is the per-slot tuple layout (fixed rings, the
    default); 3 for the stacked delay-tolerant ring (one (n_slots, ...)
    buffer plus due/stale metadata); 1 for the legacy stacked fixed
    ring."""
    if isinstance(arena.ring, tuple):
        return 2
    return 3 if is_variable(arena) else 1


def is_variable(arena: GradArena) -> bool:
    """True for delay-tolerant rings (per-slot due/stale metadata)."""
    return arena.due is not None


def arena_tau(arena: GradArena) -> int:
    """The staleness depth tau this arena implements (v2/v3 carry one
    spare slot beyond tau)."""
    v = ring_version(arena)
    if v == 2:
        return len(arena.ring) - 1
    if v == 3:
        return int(arena.ring.shape[0]) - 1
    return int(arena.ring.shape[0])


def convert_ring(arena: GradArena, version: int) -> GradArena:
    """Convert between ring layouts. v1 slot ``(head+i) % tau`` (the
    i-th oldest entry) becomes v2 slot ``1+i`` with phase/head reset to
    0 (v2 pops slot phase+1 first, so slot 1 must hold the oldest
    entry; slot 0 — the first push target — is dead and zeroed).
    Requires a concrete (non-traced) head. Checkpoint restore performs
    the same permutation at the numpy level."""
    if ring_version(arena) == version:
        return arena
    if is_variable(arena):
        raise ValueError("variable-delay rings have no v1 layout and "
                         "no per-slot v2 form (they are always the "
                         "stacked v3 layout, which carries the "
                         "due/stale metadata)")
    if version == 2:
        tau = int(arena.ring.shape[0])
        h = int(arena.head)
        perm = [(h + i) % tau for i in range(tau)]
        ring = ((jnp.zeros_like(arena.ring[0]),)
                + tuple(arena.ring[k] for k in perm))
        scales = None
        if arena.scales is not None:
            scales = ((jnp.ones_like(arena.scales[0]),)
                      + tuple(arena.scales[k] for k in perm))
        counts = jnp.concatenate(
            [jnp.zeros_like(arena.counts[:1]), arena.counts[perm]])
        return arena._replace(ring=ring, scales=scales, counts=counts,
                              head=jnp.zeros((), jnp.int32), phase=0)
    if version == 1:
        tau = len(arena.ring) - 1
        p = arena.phase
        perm = [(p + 1 + i) % (tau + 1) for i in range(tau)]
        ring = jnp.stack([arena.ring[k] for k in perm])
        scales = None
        if arena.scales is not None:
            scales = jnp.stack([arena.scales[k] for k in perm])
        counts = jnp.stack([arena.counts[k] for k in perm])
        return arena._replace(ring=ring, scales=scales, counts=counts,
                              head=jnp.zeros((), jnp.int32), phase=0)
    raise ValueError(f"unknown ring_version {version!r}")


def sync_ring_phase(tree):
    """Re-derive every v2/v3 arena's static ``phase`` from its
    (restored) ``head`` leaf. Checkpoint restore rebuilds state with
    the template's phase; the saved schedule position lives in the head
    array, so this runs once after every restore (heads are concrete
    there)."""
    def fix(a):
        if isinstance(a, GradArena) and ring_version(a) in (2, 3):
            return a._replace(phase=int(a.head) % len(a.ring))
        return a
    return jax.tree_util.tree_map(
        fix, tree, is_leaf=lambda x: isinstance(x, GradArena))


def arena_logical_axes(arena: GradArena) -> GradArena:
    """Logical axes per arena field (None fields stay None). Rows shard
    over the intra-pod slice ("flat"); slots replicated; pods on 'pod'.
    v2 rings get one (pod, flat, None) entry per slot buffer; the
    stacked layouts (v1 fixed, v3 delay-tolerant) one entry with a
    replicated leading slot dim."""
    if ring_version(arena) == 2:
        ring_ax = tuple(("pod", "flat", None) for _ in arena.ring)
        scales_ax = (None if arena.scales is None
                     else tuple(("pod", "flat") for _ in arena.scales))
    else:
        ring_ax = (None, "pod", "flat", None)
        scales_ax = None if arena.scales is None else (None, "pod", "flat")
    return GradArena(
        ring=ring_ax,
        scales=scales_ax,
        residual=None if arena.residual is None else ("pod", "flat", None),
        staging=None if arena.staging is None else ("pod", "flat", None),
        counts=(None, "pod"),
        head=(),
        due=None if arena.due is None else (None,),      # replicated
        stale=None if arena.stale is None else (None,),  # replicated
        phase=arena.phase,   # aux must match for tree.maps over both
    )


def row_scales(layout: ArenaLayout, fed) -> jax.Array:
    """Per-row int8 scales reproducing the pytree path's per-(pod,leaf)
    symmetric scales bit-exactly. fed: (n_pods, rows, 128) f32 — the
    error-fed gradient. One elementwise pass + a segment-max over the
    static row->leaf map; no per-leaf kernel launches."""
    rowmax = jnp.max(jnp.abs(fed), axis=-1)                 # (n_pods, rows)
    amax = jax.ops.segment_max(rowmax.T, layout.row_to_leaf,
                               num_segments=layout.n_leaves + 1,
                               indices_are_sorted=True)     # (leaves+1, pods)
    # sentinel segment (tail pad rows / empty) -> scale 1: pads are zero
    amax = amax.at[layout.n_leaves].set(127.0)
    scales = jnp.maximum(amax, 1e-12) / 127.0               # pytree formula
    return scales[layout.row_to_leaf].T                     # (n_pods, rows)


def _pop_sum(ring, head, scales=None):
    """v1: pod-sum of ring[head] (dequantized), mesh-aware.

    Under an active multi-pod sharding profile: pop the whole slot,
    pin the *compressed* payload across the pod axis (int8 — those are
    the actual DCN bytes, mirroring the pytree path's pop_leaf),
    dequantize locally, and reduce with one pod-axis ``jnp.sum`` — the
    reduce GSPMD lowers to the DCN all-reduce.

    Off-mesh: unrolled per-pod slice adds WITHOUT materializing the
    (n_pods, rows, 128) popped buffer — XLA:CPU's axis-0 reduce of a
    dynamic slice is ~4x slower than chained adds."""
    from repro.dist.context import active_mesh, constrain
    _, n_pods, rows, _ = ring.shape
    head = jnp.asarray(head, jnp.int32)

    mesh = active_mesh()
    if mesh is not None and mesh.n_pods > 1:
        popped = jax.lax.dynamic_index_in_dim(ring, head, 0,
                                              keepdims=False)
        if scales is not None:
            # pod-REPLICATE the int8 payload (as the pytree pop_leaf
            # does): the gather of the compressed bytes is the actual
            # DCN transfer; dequantization happens after, locally
            popped = constrain(popped, (None, "flat", None))
            s = jax.lax.dynamic_index_in_dim(scales, head, 0,
                                             keepdims=False)
            s = constrain(s, (None, "flat"))
            popped = jax.lax.optimization_barrier(
                popped.astype(jnp.float32) * s[..., None])
        return jnp.sum(popped, axis=0)

    acc = None
    for p in range(n_pods):
        x = jax.lax.dynamic_slice(
            ring, (head, jnp.int32(p), jnp.int32(0), jnp.int32(0)),
            (1, 1, rows, LANES)).reshape(rows, LANES)
        if scales is not None:
            s = jax.lax.dynamic_slice(
                scales, (head, jnp.int32(p), jnp.int32(0)),
                (1, 1, rows)).reshape(rows)
            # barrier mirrors delayed._dequantize: without it the
            # accumulate contracts to fma(q, s, acc) and drifts a ULP
            # off the pytree reference
            x = jax.lax.optimization_barrier(
                x.astype(jnp.float32) * s[:, None])
        acc = x if acc is None else acc + x
    return acc


def _slot_pop_sum(slot, scales_slot=None):
    """Pod-sum of ONE v2 slot (dequantized), mesh-aware — the per-slot
    twin of ``_pop_sum``: the slot was selected by a static phase
    index, so no dynamic slicing remains at all.

    Under an active multi-pod sharding profile: pin the *compressed*
    payload across the pod axis (int8 — those are the actual DCN
    bytes), dequantize locally, reduce with one pod-axis ``jnp.sum``
    (GSPMD lowers the reduce to the DCN all-reduce). Off-mesh: the
    deterministic left fold shared with the pytree path."""
    from repro.dist.context import active_mesh, constrain
    n_pods = slot.shape[0]

    mesh = active_mesh()
    if mesh is not None and mesh.n_pods > 1:
        if scales_slot is not None:
            q = constrain(slot, (None, "flat", None))
            s = constrain(scales_slot, (None, "flat"))
            slot = jax.lax.optimization_barrier(
                q.astype(jnp.float32) * s[..., None])
        return jnp.sum(slot, axis=0)

    acc = None
    for p in range(n_pods):
        x = slot[p]
        if scales_slot is not None:
            # barrier mirrors delayed._dequantize (see _pop_sum)
            x = jax.lax.optimization_barrier(
                x.astype(jnp.float32) * scales_slot[p][:, None])
        acc = x if acc is None else acc + x
    return acc


def _replace_slot(slots: tuple, k: int, new):
    return slots[:k] + (new,) + slots[k + 1:]


def _int8_quantize(layout: ArenaLayout, arena: GradArena, pod_grads):
    """The int8 push arithmetic shared by every ring layout: scatter
    fed = g + residual into staging, per-row scales, quantize,
    error-feedback residual. ONE definition keeps the fixed and
    delay-tolerant schedules byte-for-byte by construction — the
    fixed/variable bit-exactness suites ride on this arithmetic being
    literally shared. Returns (q f32, scale_new, residual, fed)."""
    fed = scatter_fed(layout, pod_grads, arena.residual,
                      out=arena.staging)
    scale_new = row_scales(layout, fed)
    s = scale_new[..., None]
    q = jnp.clip(jnp.round(fed / s), -127, 127)
    # barrier mirrors delayed._dequantize: no FMA contraction, so the
    # residual stays bit-identical to the pytree path
    residual = fed - jax.lax.optimization_barrier(q * s)
    return q, scale_new, residual, fed


def _int8_slot_push(layout: ArenaLayout, arena: GradArena, k: int,
                    pod_grads):
    """v2 int8 push into per-slot buffer ``k``.
    Returns (slot_new, scales_new, residual, staging)."""
    q, scale_new, residual, fed = _int8_quantize(layout, arena, pod_grads)
    # write the quantized slot through a (full-shape) update-slice on
    # the donated slot: a plain value assignment makes XLA:CPU
    # materialize q in a fresh buffer and COPY it into the aliased
    # slot (2 slot copies, measured); the update-slice writes in place
    slot_new = jax.lax.dynamic_update_slice(
        arena.ring[k], q.astype(jnp.int8), (0, 0, 0))
    sc_new = jax.lax.dynamic_update_slice(
        arena.scales[k], scale_new, (0, 0))
    return slot_new, sc_new, residual, fed


def _push_pop_v2(layout: ArenaLayout, arena: GradArena, pod_grads,
                 pod_counts, compression: str, impl: str,
                 interpret: Optional[bool]):
    """One v2 rotation: pop slot (phase+1) % (tau+1), push slot phase —
    two different buffers, both statically indexed, so the pop read
    and the in-place push write can never alias (zero copy-protection;
    see GradArena). The spare slot is exactly the one whose entry was
    consumed LAST step, so its contents are dead by construction."""
    n_slots = len(arena.ring)
    push_i = arena.phase
    pop_i = (arena.phase + 1) % n_slots
    old_count = arena.counts[pop_i]       # static index

    if compression == "int8":
        if impl in ("pallas", "pallas_sharded"):
            # flatten into staging, form fed once: the scale pass needs
            # it, and the kernel consumes it directly (writing the new
            # residual into its buffer)
            g_flat = flatten_tree(layout, pod_grads, leading=1,
                                  out=arena.staging)
            fed = g_flat + arena.residual
            # buffer swap: the old residual becomes next step's scratch
            staging = arena.residual
            scale_new = row_scales(layout, fed)
            if impl == "pallas_sharded":
                from repro.dist.context import active_mesh
                from repro.kernels.delay_ring.ops import \
                    ring_slot_rotate_int8_sharded
                grad_sum, slot_new, sc_new, residual = \
                    ring_slot_rotate_int8_sharded(
                        arena.ring[pop_i], arena.scales[pop_i],
                        arena.ring[push_i], arena.scales[push_i],
                        fed, scale_new, mesh_cfg=active_mesh(),
                        interpret=interpret)
            else:
                from repro.kernels.delay_ring.ops import \
                    ring_slot_rotate_int8
                popped, slot_new, sc_new, residual = \
                    ring_slot_rotate_int8(
                        arena.ring[pop_i], arena.scales[pop_i],
                        arena.ring[push_i], arena.scales[push_i],
                        fed, scale_new, interpret=interpret)
                grad_sum = _pod_fold(popped)  # pod sum = DCN all-reduce
        else:
            grad_sum = _slot_pop_sum(arena.ring[pop_i],
                                     arena.scales[pop_i])
            slot_new, sc_new, residual, staging = _int8_slot_push(
                layout, arena, push_i, pod_grads)
        ring = _replace_slot(arena.ring, push_i, slot_new)
        scales = _replace_slot(arena.scales, push_i, sc_new)
    else:
        # No kernel at all: the pop is a read of one statically-chosen
        # slot, the push scatters the per-leaf strips straight into the
        # (donated) spare slot's buffer — under v2 the f32 ring
        # rotation IS just those two XLA ops, on every backend.
        grad_sum = _slot_pop_sum(arena.ring[pop_i])
        slot_new = flatten_tree(layout, pod_grads, leading=1,
                                out=arena.ring[push_i])
        ring = _replace_slot(arena.ring, push_i, slot_new)
        scales, residual = None, None
        staging = arena.staging    # untouched pass-through (zero cost)

    count = jnp.sum(old_count)
    next_phase = (arena.phase + 1) % n_slots
    new_arena = GradArena(
        ring=ring, scales=scales, residual=residual, staging=staging,
        counts=arena.counts.at[push_i].set(pod_counts),
        head=jnp.full((), next_phase, jnp.int32),   # trace-time constant
        phase=next_phase)
    return grad_sum, count, new_arena


def _pod_fold(popped):
    """Deterministic left fold over the pod axis of an already-
    dequantized popped slot (the kernel path's pod reduction)."""
    from repro.core.delayed import pod_sum
    return pod_sum(popped)


def push_pop(layout: ArenaLayout, arena: GradArena, pod_grads, pod_counts,
             compression: str = "none", impl: str = "auto",
             interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array, GradArena]:
    """Arena twin of ``delayed.push_pop``: insert this step's
    pod-stacked gradient *tree*, return the tau-old entry summed over
    pods (the DCN collective) and the updated arena.

    pod_grads: pytree, leaves (n_pods, *shape). Returns
    (grad_sum (rows, 128) f32, count (), new_arena).

    v2 rings rotate with fully static slot indices (see
    ``_push_pop_v2``); the only kernel left is the int8 rotate —
    impl="auto" picks Pallas for it on TPU, the XLA elementwise chain
    elsewhere, and the shard_map-wrapped kernel on a multi-pod mesh
    (requires an ambient physical mesh; the pop's pod reduction then
    happens inside the wrapper, int8 payload crossing the DCN
    compressed). v1 rings keep the stacked-buffer paths: lax.switch
    scatter + dynamic pop on XLA, scalar-prefetched-head kernel on
    single-pod TPU.
    """
    from repro.kernels import resolve_impl
    from repro.kernels.delay_ring.ops import ring_push_pop

    if is_variable(arena):
        raise ValueError("delay-tolerant arenas rotate via "
                         "push_pop_variable (per-step tau_t), not the "
                         "fixed-tau push_pop")
    # only v2 has the shard_map wrapper: a v1 arena on a multi-pod
    # mesh must keep auto-resolving to the XLA ref path
    impl = resolve_impl(impl, pod_shard_map=ring_version(arena) == 2)
    if ring_version(arena) == 2:
        return _push_pop_v2(layout, arena, pod_grads, pod_counts,
                            compression, impl, interpret)
    if impl == "pallas_sharded":   # only reachable when forced explicitly
        raise ValueError("the shard_map'd delay-ring path needs ring "
                         "layout v2 (per-slot buffers); migrate the "
                         "arena with convert_ring(arena, 2)")
    head = arena.head
    old_count = arena.counts[head]

    if impl == "pallas":
        g_flat = flatten_tree(layout, pod_grads, leading=1,
                              out=arena.staging)
        if compression == "int8":
            fed = g_flat + arena.residual
            scale_new = row_scales(layout, fed)
            popped, ring, scales, residual = ring_push_pop(
                arena.ring, fed, head, scales=arena.scales,
                scale_new=scale_new, impl="pallas", interpret=interpret)
            staging = arena.residual
        else:
            popped, ring, scales, residual = ring_push_pop(
                arena.ring, g_flat, head, impl="pallas",
                interpret=interpret)
            # "none" carries no staging (g_flat was a fresh temp) —
            # keep the state structure identical to init_arena's
            staging = arena.staging
        grad_sum = _pod_fold(popped)        # pod sum = DCN all-reduce
    elif compression == "int8":
        fed = scatter_fed(layout, pod_grads, arena.residual,
                          out=arena.staging)
        scale_new = row_scales(layout, fed)
        grad_sum = _pop_sum(arena.ring, head, arena.scales)
        s = scale_new[..., None]
        q = jnp.clip(jnp.round(fed / s), -127, 127)
        # XLA sequences the slot read above ahead of this in-place
        # overwrite itself (copy-protection where it must)
        ring, scales = _update_slot_int8(arena.ring, arena.scales,
                                         q.astype(jnp.int8), scale_new,
                                         head)
        # barrier mirrors delayed._dequantize: no FMA contraction, so
        # the residual stays bit-identical to the pytree reference
        residual = fed - jax.lax.optimization_barrier(q * s)
        staging = fed
    else:
        grad_sum = _pop_sum(arena.ring, head)
        ring = _scatter_slot(layout, arena.ring, pod_grads, head)
        staging = arena.staging    # untouched pass-through (zero cost)
        scales = residual = None

    count = jnp.sum(old_count)
    new_arena = GradArena(
        ring=ring, scales=scales, residual=residual, staging=staging,
        counts=arena.counts.at[head].set(pod_counts),
        head=(head + 1) % arena.counts.shape[0], phase=0)
    return grad_sum, count, new_arena


def _scatter_slot_stacked(layout: ArenaLayout, ring, tree, k: int):
    """Per-leaf scatter straight into stacked slot ``ring[k]`` — every
    index (slot AND row offset) is static, so XLA:CPU chains in-place
    update-slices on the donated buffer, exactly like the v2 per-slot
    scatter (no temp slot, no copy-protection)."""
    n_pods = ring.shape[1]
    leaves = layout.treedef.flatten_up_to(tree)
    for leaf, ofs, size, rc in zip(leaves, layout.row_offsets,
                                   layout.sizes, layout.row_counts):
        x = _padded_leaf(leaf, size, rc, 1).reshape(n_pods, rc, LANES)
        ring = jax.lax.dynamic_update_slice(
            ring, x[None].astype(ring.dtype), (k, 0, ofs, 0))
    return ring


def _variable_pop_ref(ring, scales, mask):
    """Reference pop of the stacked delay-tolerant ring: fold the due
    slots, mesh-aware.

    Off-mesh (the CPU fast path): a data-dependent GATHER — sort the
    due slot indices to the front and branch on the arrival count H, so
    the step reads O(arrivals) slots instead of all tau_max+1 (the
    3-4x read amplification the old full masked fold paid; arrivals
    average ~1/step because the delay process conserves pushes). H = 1,
    by far the common case, is a single dynamic-slice read folded
    exactly like the static path's ``_slot_pop_sum`` — which is what
    keeps the constant-sequence degeneration bit-identical.

    Under an active multi-pod sharding profile: masks are elementwise,
    so each pod shard folds its own due slots LOCALLY (dequantizing in
    place) and ONE pod-axis ``jnp.sum`` — a single f32 DCN all-reduce —
    replaces the per-slot reduces the old fold issued n_slots times."""
    from repro.dist.context import active_mesh, constrain
    n_slots, n_pods, rows, _ = ring.shape

    mesh = active_mesh()
    if mesh is not None and mesh.n_pods > 1:
        x = constrain(ring, (None, "pod", "flat", None))
        if scales is not None:
            s = constrain(scales, (None, "pod", "flat"))
            # barrier mirrors delayed._dequantize (see _slot_pop_sum)
            x = jax.lax.optimization_barrier(
                x.astype(jnp.float32) * s[..., None])
        m = mask.astype(jnp.float32)[:, None, None, None]
        local = jnp.sum(m * x, axis=0)       # per-pod masked fold, local
        return jnp.sum(local, axis=0)        # ONE pod-axis DCN reduce

    def slot_pod_sum(j):
        q = jax.lax.dynamic_index_in_dim(ring, j, 0, keepdims=False)
        s = (None if scales is None else
             jax.lax.dynamic_index_in_dim(scales, j, 0, keepdims=False))
        acc = None
        for p in range(n_pods):
            x = q[p]
            if s is not None:
                # barrier mirrors delayed._dequantize (see _slot_pop_sum)
                x = jax.lax.optimization_barrier(
                    x.astype(jnp.float32) * s[p][:, None])
            acc = x if acc is None else acc + x
        return acc.astype(jnp.float32)

    # due slots sorted to the front (ascending j — the canonical fold
    # order), padded with n_slots
    order = jnp.sort(jnp.where(mask,
                               jnp.arange(n_slots, dtype=jnp.int32),
                               jnp.int32(n_slots)))
    H = jnp.sum(mask.astype(jnp.int32))
    zeros = jnp.zeros((rows, LANES), jnp.float32)
    return jax.lax.switch(
        jnp.minimum(H, 2),
        [lambda o: zeros,                    # H = 0: exact zero pop
         lambda o: slot_pod_sum(o[0]),       # H = 1: one slot, exactly
                                             #   the static single pop
         lambda o: jax.lax.fori_loop(        # H > 1: fold the H due
             0, H,                           #   slots in ascending j
             lambda i, acc: acc + slot_pod_sum(o[i]), zeros)],
        order)


def push_pop_variable(layout: ArenaLayout, arena: GradArena, pod_grads,
                      pod_counts, delay,
                      compression: str = "none", impl: str = "auto",
                      interpret: Optional[bool] = None
                      ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                 GradArena]:
    """Delay-tolerant rotation for a stochastic per-step delay process
    (``core.delay_process``): this step's gradient is pushed with a
    TRACED delay ``tau_t = delay`` (the host draws it; clipped to the
    ring cap) and applied ``tau_t`` steps later; the pop folds every
    slot whose entry is due exactly now.

    Generalizes the static v2 phase schedule, keeping every WRITE
    statically indexed (the copy-protection-free property the v2
    layout exists for):

      * ``head`` is the absolute step counter t; the push target is
        still slot ``phase = t % (tau_max+1)`` — a static index — whose
        previous entry was pushed at t - (tau_max+1) and therefore due
        at latest t-1: dead by construction, so no unread slot is ever
        overwritten (the property suite's first invariant);
      * the push tags its slot ``due[k] = t + tau_t`` and
        ``stale[k] = tau_t`` (the only delay-dependent state — i32
        metadata, not a dynamic slot index);
      * the pop folds ``(due[j] == t) * slot_j`` — late and
        out-of-order arrivals from different push epochs land in the
        one step they are due, zero-arrival steps pop an exact zero,
        and a constant sequence reduces to the static path's
        single-slot pop (pinned value-identical by
        tests/test_delay_process.py). The ring is STACKED (layout v3)
        so the fold can be a single pass: ``impl`` dispatches via
        ``resolve_impl`` — "ref" (auto off-TPU) is the gather fold of
        ``_variable_pop_ref`` (reads O(arrivals) slots, not tau_max+1),
        "pallas" streams all slots once through the
        ``ring_variable_pop`` kernel with the masked fold in registers,
        and "pallas_sharded" (auto on a multi-pod TPU mesh) runs the
        kernel per pod shard under shard_map and crosses the DCN with
        ONE reduce instead of n_slots of them.

    int8 compression keeps the fixed path's per-push quantization +
    error-feedback residual byte-for-byte (each slot still holds one
    compressed push and its per-row scales; the wire payload stays
    int8), only the pop-side fold widens.

    Also returns ``tau_obs`` — the count-weighted mean staleness of the
    gradients applied this step. On zero-arrival steps it is 0 by
    convention; consumers feeding a delay-ADAPTIVE step size must fall
    back to the ring cap on ``count == 0`` (see ``ambdg``) — 0 would
    claim a stall step is perfectly fresh.

    pod_grads: pytree, leaves (n_pods, *shape); delay: () i32.
    Returns (grad_sum (rows, 128) f32, count (), tau_obs () f32,
    new_arena).
    """
    from repro.kernels import resolve_impl

    if not is_variable(arena):
        raise ValueError("push_pop_variable needs a delay-tolerant "
                         "arena (init_arena(..., variable=True)); "
                         "fixed-tau rings rotate via push_pop")
    impl = resolve_impl(impl, pod_shard_map=True)
    n_slots = int(arena.ring.shape[0])
    k = arena.phase                      # static push slot: t % n_slots
    t = arena.head                       # traced absolute step counter
    delay = jnp.clip(jnp.asarray(delay, jnp.int32), 0, n_slots - 1)
    due = arena.due.at[k].set(t + delay)
    stale = arena.stale.at[k].set(delay)
    counts = arena.counts.at[k].set(pod_counts)

    if compression == "int8":
        # literally the fixed ref path's push arithmetic (shared
        # helper): per-push quantization + EF residual, byte-for-byte;
        # the slot index k is STATIC, so the stacked update-slices
        # write in place on the donated ring
        q, scale_new, residual, staging = _int8_quantize(
            layout, arena, pod_grads)
        ring = jax.lax.dynamic_update_slice(
            arena.ring, q.astype(jnp.int8)[None], (k, 0, 0, 0))
        scales = jax.lax.dynamic_update_slice(
            arena.scales, scale_new[None], (k, 0, 0))
    else:
        from repro.dist.context import active_mesh
        mesh = active_mesh()
        if mesh is not None and mesh.n_devices > 1:
            # GSPMD cannot keep the per-leaf unaligned row-offset
            # update-slices of _scatter_slot_stacked sharded — each one
            # rematerializes the WHOLE stacked ring through layout
            # copies (flagged by the matrix harness's ring-copy
            # invariant). Build the slot in a temp instead ("none"
            # carries no staging buffer — state structure is config-
            # determined) and land it with ONE row-aligned update,
            # exactly like the int8 branch above; the per-leaf traffic
            # stays on the temp.
            fed = flatten_tree(layout, pod_grads, leading=1)
            ring = jax.lax.dynamic_update_slice(
                arena.ring, fed[None], (k, 0, 0, 0))
        else:
            ring = _scatter_slot_stacked(layout, arena.ring, pod_grads, k)
        staging = arena.staging       # untouched pass-through (zero cost)
        scales, residual = None, None

    # ---- single-pass pop: every slot due exactly at t ----
    # (reads the post-push ring, so a tau_t = 0 push delivers
    # synchronously through the same quantize/dequantize it would
    # cross the wire with)
    mask = due == t
    # per-slot metadata for the fused scalar epilogue: pod-summed
    # counts stacked over tagged staleness. Both rows are
    # small-integer-valued floats, so every fold order sums them
    # exactly — count/tau_obs stay bitwise impl-independent whether
    # the fold runs in the kernel epilogue (pallas impls, SMEM
    # output: no separate O(n_slots) metadata pass) or in the jnp
    # form below (ref impl — also the oracle pinned by
    # tests/test_delay_ring_interpret.py)
    cs = jnp.stack([jnp.sum(counts, axis=1),
                    stale.astype(jnp.float32)])
    if impl == "pallas_sharded":
        from repro.dist.context import active_mesh
        from repro.kernels.delay_ring.ops import ring_variable_pop_sharded
        grad_sum, meta = ring_variable_pop_sharded(
            ring, mask, scales=scales, counts_stale=cs,
            mesh_cfg=active_mesh(), interpret=interpret)
        count, stale_sum = meta[0], meta[1]
    elif impl == "pallas":
        from repro.kernels.delay_ring.ops import ring_variable_pop
        partial, meta = ring_variable_pop(
            ring, mask, scales=scales, counts_stale=cs, impl="pallas",
            interpret=interpret)
        grad_sum = _pod_fold(partial)   # pod sum = DCN all-reduce
        count, stale_sum = meta[0], meta[1]
    else:
        grad_sum = _variable_pop_ref(ring, scales, mask)
        mf = mask.astype(jnp.float32)
        count = jnp.sum(mf * cs[0])
        stale_sum = jnp.sum(mf * cs[0] * cs[1])
    tau_obs = stale_sum / jnp.maximum(count, 1.0)

    new_arena = GradArena(
        ring=ring, scales=scales, residual=residual, staging=staging,
        counts=counts, head=t + 1, due=due, stale=stale,
        phase=(arena.phase + 1) % n_slots)
    return grad_sum, count, tau_obs, new_arena
