"""Adaptive minibatch schedules: a batch-size controller b(t).

The paper's per-epoch minibatch b(t) is *anytime* — whatever the
workers finished inside T_p — so its size is driven purely by the
timeline model. Two lines of follow-up work argue the target itself
should adapt: AdaDamp-style controllers grow b(t) to damp gradient
noise as the loss decreases (small noisy batches early, large precise
ones near the optimum), and Attia, Gaash & Koren ("Faster Stochastic
Optimization with Arbitrary Delays via Asynchronous Mini-Batching")
scale the accumulated minibatch with the observed delay so stale
updates carry proportionally more signal. This module is the single
source of those targets for every layer:

  * the HOST training loop draws one target per step, folds it into
    the anytime weights mask, and ships it to the device step as
    ``batch["b_sched"]`` — where it replaces the static ``b_bar``
    inside the dual-averaging step size
    alpha(t)^-1 = L + sqrt((t + tau) / b(t));
  * the cluster simulator draws the same seeded sequence per epoch
    (anytime) or per job (k-batch) via ``Strategy.batch_schedule()``
    and ``api.simulate``, so golden traces pin the targets exactly;
  * ``observe(loss=..., tau_obs=...)`` feeds the training signal back
    after each update (closed loop for adadamp / delay_aware; a no-op
    for the open-loop schedules).

Every schedule is seeded (``numpy.random.default_rng``), emits integer
targets in ``[b_min, b_cap]``, and checkpoints its full state
(``state_dict``/``load_state_dict``) so restarts reproduce the exact
remaining sequence — the same restart-exactness contract the delay and
worker processes keep.

Four schedules (``BatchScheduleConfig.schedule``):

  fixed        b(t) = b0. The degenerate case: strategies return no
               controller and every consumer routes to the
               pre-existing timing-driven path, pinned bit-identical
               by the regression suites.
  linear       b(t) = b0 + floor(growth_rate * (t - 1)): a
               deterministic warmup ramp.
  adadamp      b(t) = b0 * loss(1) / ema_loss(t), monotone
               non-decreasing with per-step growth capped at
               growth_factor: batch grows inversely with the
               (EMA-smoothed) loss, damping gradient noise exactly
               when it starts to dominate the signal.
  delay_aware  b(t) = b0 * (1 + ema_tau(t)) / (1 + tau_ref): batch
               scales with the observed staleness of applied
               gradients, composing with the Agarwal-Duchi
               delay-adaptive alpha (``rc.delay.adaptive_alpha``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

import numpy as np

from repro.configs.base import BatchScheduleConfig


def resolve_targets(cfg: BatchScheduleConfig, b_bar: float) -> Tuple[int, int, int]:
    """Validate ``cfg`` against the nominal minibatch ``b_bar`` and
    return the resolved ``(b0, b_min, b_cap)``. ``b0=0`` resolves to
    ``round(b_bar)`` (the static target alpha already assumes);
    ``b_cap=0`` resolves to ``16 * b0``."""
    if cfg.schedule not in BATCH_SCHEDULES:
        raise ValueError(f"unknown batch schedule {cfg.schedule!r}; "
                         f"registered: {sorted(BATCH_SCHEDULES)}")
    b0 = cfg.b0 or int(round(b_bar))
    if b0 < 1:
        raise ValueError(f"batch schedule base b0 must be >= 1, got {b0} "
                         f"(b0={cfg.b0}, b_bar={b_bar})")
    if cfg.b_min < 1:
        raise ValueError(f"b_min must be >= 1, got {cfg.b_min}")
    b_cap = cfg.b_cap or 16 * b0
    if b_cap < b0 or cfg.b_min > b_cap:
        raise ValueError(f"need b_min <= b0 <= b_cap, got b_min={cfg.b_min}, "
                         f"b0={b0}, b_cap={b_cap}")
    if cfg.schedule == "linear" and cfg.growth_rate < 0.0:
        raise ValueError(f"growth_rate must be >= 0, got {cfg.growth_rate}")
    if cfg.schedule == "adadamp" and cfg.growth_factor <= 1.0:
        raise ValueError(f"adadamp growth_factor must be > 1, "
                         f"got {cfg.growth_factor}")
    if not 0.0 < cfg.ema <= 1.0:
        raise ValueError(f"ema weight must be in (0, 1], got {cfg.ema}")
    return b0, cfg.b_min, b_cap


class BatchSchedule:
    """One seeded per-step minibatch-target sequence. Subclasses
    implement ``_draw()`` -> int (reading any feedback recorded by
    ``observe``); the base class owns seeding, clipping to
    ``[b_min, b_cap]``, the step counter, and checkpointable state."""

    name: str = "?"

    def __init__(self, cfg: BatchScheduleConfig, b_bar: float, tau: int):
        self.cfg = cfg
        self.b_bar = float(b_bar)
        self.tau = int(tau)
        self.b0, self.b_min, self.b_cap = resolve_targets(cfg, b_bar)
        self._rng = np.random.default_rng(cfg.seed)
        self._t = 0          # steps drawn so far
        self._last = self.b0  # most recent emitted target

    def _draw(self) -> int:
        raise NotImplementedError

    def target(self) -> int:
        """Draw the next target b(t) (advances the step counter)."""
        self._t += 1
        self._last = int(np.clip(self._draw(), self.b_min, self.b_cap))
        return self._last

    def observe(self, *, loss: Optional[float] = None,
                tau_obs: Optional[float] = None):
        """Feed back the post-update training signal (the loss and the
        observed staleness ``metrics["tau_applied"]``). Open-loop
        schedules ignore it."""

    def sequence(self, n: int) -> np.ndarray:
        """The next ``n`` targets as an int64 array (advances state;
        no feedback, so closed-loop schedules hold their base)."""
        return np.asarray([self.target() for _ in range(n)], np.int64)

    # -- restart exactness -------------------------------------------------
    def state_dict(self) -> Dict:
        return {"rng": self._rng.bit_generator.state,
                "t": self._t, "last": self._last}

    def load_state_dict(self, s: Dict):
        self._rng.bit_generator.state = s["rng"]
        self._t = int(s["t"])
        self._last = int(s["last"])

    def __repr__(self):
        return (f"{type(self).__name__}(b0={self.b0}, "
                f"bounds=[{self.b_min}, {self.b_cap}], "
                f"seed={self.cfg.seed})")


class FixedBatch(BatchSchedule):
    """The static target — the degenerate schedule every consumer
    routes to the pre-existing timing-driven path."""

    name = "fixed"

    def _draw(self) -> int:
        return self.b0


class LinearBatch(BatchSchedule):
    """Deterministic warmup ramp: b0 + floor(growth_rate * (t-1))."""

    name = "linear"

    def _draw(self) -> int:
        return self.b0 + int(np.floor(self.cfg.growth_rate * (self._t - 1)))


class AdadampBatch(BatchSchedule):
    """Grow the batch inversely with the (EMA-smoothed) loss: early
    steps run small noisy batches (cheap progress while the signal
    dominates), late steps run large ones (noise damping when the
    gradient shrinks). b(t) = b0 * loss(1)/ema_loss(t), monotone
    non-decreasing, per-step growth capped at ``growth_factor``x so a
    lucky loss spike down can't explode the target."""

    name = "adadamp"

    def __init__(self, cfg: BatchScheduleConfig, b_bar: float, tau: int):
        super().__init__(cfg, b_bar, tau)
        self._loss0: Optional[float] = None   # first observed loss
        self._ema_loss: Optional[float] = None

    def observe(self, *, loss: Optional[float] = None,
                tau_obs: Optional[float] = None):
        if loss is None or not np.isfinite(loss) or loss <= 0.0:
            return
        if self._loss0 is None:
            self._loss0 = float(loss)
            self._ema_loss = float(loss)
        else:
            w = self.cfg.ema
            self._ema_loss = (1.0 - w) * self._ema_loss + w * float(loss)

    def _draw(self) -> int:
        if self._loss0 is None:
            return self.b0
        want = self.b0 * self._loss0 / max(self._ema_loss, 1e-12)
        capped = min(want, self._last * self.cfg.growth_factor)
        return max(int(np.floor(capped)), self._last)  # monotone

    def state_dict(self) -> Dict:
        s = super().state_dict()
        s["loss0"] = self._loss0
        s["ema_loss"] = self._ema_loss
        return s

    def load_state_dict(self, s: Dict):
        super().load_state_dict(s)
        self._loss0 = None if s.get("loss0") is None else float(s["loss0"])
        self._ema_loss = (None if s.get("ema_loss") is None
                          else float(s["ema_loss"]))


class DelayAwareBatch(BatchSchedule):
    """Scale the batch with the observed staleness (Attia-Gaash-Koren:
    an update that waited tau steps should carry ~tau steps' worth of
    samples). b(t) = b0 * (1 + ema_tau(t)) / (1 + tau_ref), where
    tau_ref is the nominal staleness the base b0 was sized for and
    ema_tau tracks ``observe(tau_obs=...)`` — the same tau_applied the
    delay-adaptive alpha consumes, so the two adaptations compose."""

    name = "delay_aware"

    def __init__(self, cfg: BatchScheduleConfig, b_bar: float, tau: int):
        super().__init__(cfg, b_bar, tau)
        self._ema_tau = float(tau)

    def observe(self, *, loss: Optional[float] = None,
                tau_obs: Optional[float] = None):
        if tau_obs is None or not np.isfinite(tau_obs) or tau_obs < 0.0:
            return
        w = self.cfg.ema
        self._ema_tau = (1.0 - w) * self._ema_tau + w * float(tau_obs)

    def _draw(self) -> int:
        return int(round(self.b0 * (1.0 + self._ema_tau)
                         / (1.0 + self.tau)))

    def state_dict(self) -> Dict:
        s = super().state_dict()
        s["ema_tau"] = self._ema_tau
        return s

    def load_state_dict(self, s: Dict):
        super().load_state_dict(s)
        self._ema_tau = float(s["ema_tau"])


BATCH_SCHEDULES: Dict[str, Type[BatchSchedule]] = {
    c.name: c for c in (FixedBatch, LinearBatch, AdadampBatch,
                        DelayAwareBatch)}


def make_batch_schedule(cfg: BatchScheduleConfig, b_bar: float,
                        tau: int) -> BatchSchedule:
    """Construct the schedule named by ``cfg.schedule`` (validates the
    config — every consumer goes through here)."""
    resolve_targets(cfg, b_bar)   # raise early with the full message
    return BATCH_SCHEDULES[cfg.schedule](cfg, b_bar, tau)
