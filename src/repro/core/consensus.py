"""Decentralized AMB-DG (paper Sec. V): gossip consensus instead of a
master.

Workers exchange ``m_i^(0)(t) = n * b_i(t) * (z_i(t) + g_i(t))`` with
neighbours for r rounds through a doubly-stochastic communication
matrix Q; after enough rounds every worker holds ~ b(t) [z-bar + g(t)].
Eq. (24) lower-bounds the rounds needed for consensus error delta:

    r >= ceil( log(2 sqrt(n) (1 + 2J/delta)) / (1 - lambda_2(Q)) )

Two realizations:
  * dense matrix powers (numpy/jax) for the simulator and tests;
  * a ``lax.ppermute`` ring for on-device decentralized execution under
    ``shard_map`` (each mesh index = one worker).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Communication matrices
# ---------------------------------------------------------------------------
def gossip_matrix(topology: str, n: int) -> np.ndarray:
    """Doubly-stochastic, symmetric (hence PSD ordering on eigenvalues),
    with Q_ij > 0 iff i=j or (i,j) is an edge."""
    if topology == "complete":
        Q = np.full((n, n), 1.0 / n)
    elif topology == "ring":
        Q = np.zeros((n, n))
        for i in range(n):
            Q[i, i] = 0.5
            Q[i, (i - 1) % n] += 0.25
            Q[i, (i + 1) % n] += 0.25
    elif topology == "torus":
        side = int(round(math.sqrt(n)))
        if side * side != n:
            raise ValueError(f"torus needs a square n, got {n}")
        Q = np.zeros((n, n))
        for i in range(n):
            r, c = divmod(i, side)
            Q[i, i] = 1.0 / 3.0
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % side) * side + (c + dc) % side
                Q[i, j] += 1.0 / 6.0
    else:
        raise ValueError(topology)
    assert np.allclose(Q.sum(0), 1.0) and np.allclose(Q.sum(1), 1.0)
    return Q


def lambda2(Q: np.ndarray) -> float:
    """Second-largest eigenvalue magnitude (spectral gap driver)."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(Q)))[::-1]
    return float(ev[1])


def min_rounds(delta: float, n: int, J: float, lam2: float) -> int:
    """Paper eq. (24)."""
    if lam2 >= 1.0:
        raise ValueError("graph not connected (lambda2 >= 1)")
    num = math.log(2.0 * math.sqrt(n) * (1.0 + 2.0 * J / delta))
    return int(math.ceil(num / (1.0 - lam2)))


def run_consensus(values: jax.Array, Q, r: int) -> jax.Array:
    """values: (n, d) per-worker messages -> r gossip rounds Q^r @ values."""
    Qj = jnp.asarray(Q, values.dtype)

    def body(v, _):
        return Qj @ v, None

    out, _ = jax.lax.scan(body, values, None, length=r)
    return out


def consensus_error(values: jax.Array) -> jax.Array:
    """Max deviation from the true mean across workers (the paper's
    ||z_i - z||; delta bound target)."""
    mean = jnp.mean(values, axis=0, keepdims=True)
    return jnp.max(jnp.linalg.norm(values - mean, axis=-1))


# ---------------------------------------------------------------------------
# Ordered-fold gossip: ONE definition of a round, two executions
# ---------------------------------------------------------------------------
# A gossip round is an ordered list of (neighbour-index array, weight)
# terms folded left to right:
#
#     x_i' = w_0 * x_{nbr_0[i]} + w_1 * x_{nbr_1[i]} + ...
#
# Both realizations consume the SAME stencil with the SAME fold order —
# the dense oracle gathers neighbours by indexing the stacked (n, ...)
# value array, the on-device path gathers them with ``lax.ppermute``
# under shard_map — so they are bit-identical by construction (same
# multiplies, same adds, same order). The stencil weights are exactly
# the rows of ``gossip_matrix`` (asserted below), so the dense fold IS
# the gossip-matrix power oracle applied term by term.


def topology_stencil(topology: str, n: int):
    """Ordered (nbr, weight) terms for one gossip round; ``nbr`` is an
    (n,) int array, term k contributes ``weight * x[nbr[i]]`` to worker
    i. Ring/torus lead with the identity (self) term; the complete
    graph folds plainly over workers 0..n-1."""
    idx = np.arange(n)
    if topology == "complete":
        # n cyclic-shift terms, each weighted 1/n: worker i folds
        # x_i, x_{i+1}, ..., x_{i+n-1} (wrapping). Every term is a
        # true permutation — ppermute requires one — and the dense
        # gather applies the identical per-worker order.
        terms = [((idx + d) % n, 1.0 / n) for d in range(n)]
    elif topology == "ring":
        terms = [(idx, 0.5), ((idx - 1) % n, 0.25), ((idx + 1) % n, 0.25)]
    elif topology == "torus":
        side = int(round(math.sqrt(n)))
        if side * side != n:
            raise ValueError(f"torus needs a square n, got {n}")
        r, c = np.divmod(idx, side)
        terms = [(idx, 1.0 / 3.0)]
        for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            terms.append((((r + dr) % side) * side + (c + dc) % side,
                          1.0 / 6.0))
    else:
        raise ValueError(topology)
    # merge duplicate neighbour terms (torus side=2 / ring n=2: the +1
    # and -1 shifts coincide), first-occurrence order. Duplicates MUST
    # not reach the fold: XLA rewrites the repeated ``acc + t + t``
    # into ``acc + 2t`` differently in the dense and shard_map
    # programs, which is exactly the ULP drift the shared stencil
    # exists to prevent.
    merged = []
    for nbr, w in terms:
        nbr = np.asarray(nbr, np.int32)
        for k, (prev, pw) in enumerate(merged):
            if np.array_equal(prev, nbr):
                merged[k] = (prev, pw + w)
                break
        else:
            merged.append((nbr, float(w)))
    return [(nbr, float(w)) for nbr, w in merged]


def _stencil_matrix(topology: str, n: int) -> np.ndarray:
    """The doubly-stochastic matrix the stencil fold applies per round."""
    Q = np.zeros((n, n))
    for nbr, w in topology_stencil(topology, n):
        Q[np.arange(n), nbr] += w
    return Q


def _assert_stencil_matches_matrix(topology: str, n: int):
    np.testing.assert_allclose(_stencil_matrix(topology, n),
                               gossip_matrix(topology, n), atol=1e-12)


def _fold_round(x, terms, gather):
    """Shared fold body: ``gather(x, nbr)`` returns per-worker
    neighbour values; identity terms skip the gather entirely. Each
    weighted term is pinned with an ``optimization_barrier`` (the
    delayed._dequantize precedent): without it XLA contracts
    ``acc + w * v`` into an FMA differently in the dense and shard_map
    programs and the two executions drift a ULP apart."""
    acc = None
    for nbr, w in terms:
        v = x if (nbr == np.arange(nbr.shape[0])).all() else gather(x, nbr)
        term = jax.lax.optimization_barrier(w * v)
        acc = term if acc is None else acc + term
    return acc


def gossip_round_dense(values: jax.Array, topology: str) -> jax.Array:
    """One stencil-fold round on stacked (n, ...) per-worker values —
    the dense gossip-matrix oracle, applied term by term."""
    n = values.shape[0]
    terms = topology_stencil(topology, n)
    return _fold_round(values, terms, lambda v, nbr: v[nbr])


def run_consensus_fold(values: jax.Array, topology: str, r: int
                       ) -> jax.Array:
    """r stencil-fold rounds on stacked (n, ...) values. Bit-identical
    to ``gossip_rounds_shard`` under shard_map; equal to
    ``run_consensus(values, gossip_matrix(topology, n), r)`` up to the
    matmul's reduction order."""
    def body(v, _):
        return gossip_round_dense(v, topology), None
    out, _ = jax.lax.scan(body, values, None, length=r)
    return out


def gossip_round_shard(x, axis_name: str, topology: str, n: int):
    """One stencil-fold round for the per-worker shard ``x`` inside
    shard_map (mesh index along ``axis_name`` = worker index; ``n``
    workers — passed statically, the perm tables need it at trace
    time). The neighbour gather is a ``lax.ppermute``: receiver i
    takes term k's value from worker nbr_k[i]."""
    terms = topology_stencil(topology, n)

    def gather(v, nbr):
        # every non-identity stencil term is a true permutation
        # (identity terms are skipped by _fold_round), so the gather
        # is exactly one ppermute: receiver i's source is nbr[i]
        return jax.lax.ppermute(
            v, axis_name, [(int(nbr[i]), i) for i in range(n)])

    return _fold_round(x, terms, gather)


def gossip_rounds_shard(x, axis_name: str, topology: str, n: int,
                        rounds: int):
    """r gossip rounds under shard_map (scan keeps one HLO body, like
    the dense fold — same op sequence, bit-identical results)."""
    def body(v, _):
        return gossip_round_shard(v, axis_name, topology, n), None
    out, _ = jax.lax.scan(body, x, None, length=rounds)
    return out
