"""Decentralized AMB-DG (paper Sec. V): gossip consensus instead of a
master.

Workers exchange ``m_i^(0)(t) = n * b_i(t) * (z_i(t) + g_i(t))`` with
neighbours for r rounds through a doubly-stochastic communication
matrix Q; after enough rounds every worker holds ~ b(t) [z-bar + g(t)].
Eq. (24) lower-bounds the rounds needed for consensus error delta:

    r >= ceil( log(2 sqrt(n) (1 + 2J/delta)) / (1 - lambda_2(Q)) )

Two realizations:
  * dense matrix powers (numpy/jax) for the simulator and tests;
  * a ``lax.ppermute`` ring for on-device decentralized execution under
    ``shard_map`` (each mesh index = one worker).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Communication matrices
# ---------------------------------------------------------------------------
def gossip_matrix(topology: str, n: int) -> np.ndarray:
    """Doubly-stochastic, symmetric (hence PSD ordering on eigenvalues),
    with Q_ij > 0 iff i=j or (i,j) is an edge."""
    if topology == "complete":
        Q = np.full((n, n), 1.0 / n)
    elif topology == "ring":
        Q = np.zeros((n, n))
        for i in range(n):
            Q[i, i] = 0.5
            Q[i, (i - 1) % n] += 0.25
            Q[i, (i + 1) % n] += 0.25
    elif topology == "torus":
        side = int(round(math.sqrt(n)))
        if side * side != n:
            raise ValueError(f"torus needs a square n, got {n}")
        Q = np.zeros((n, n))
        for i in range(n):
            r, c = divmod(i, side)
            Q[i, i] = 1.0 / 3.0
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % side) * side + (c + dc) % side
                Q[i, j] += 1.0 / 6.0
    else:
        raise ValueError(topology)
    assert np.allclose(Q.sum(0), 1.0) and np.allclose(Q.sum(1), 1.0)
    return Q


def lambda2(Q: np.ndarray) -> float:
    """Second-largest eigenvalue magnitude (spectral gap driver)."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(Q)))[::-1]
    return float(ev[1])


def min_rounds(delta: float, n: int, J: float, lam2: float) -> int:
    """Paper eq. (24)."""
    if lam2 >= 1.0:
        raise ValueError("graph not connected (lambda2 >= 1)")
    num = math.log(2.0 * math.sqrt(n) * (1.0 + 2.0 * J / delta))
    return int(math.ceil(num / (1.0 - lam2)))


def run_consensus(values: jax.Array, Q, r: int) -> jax.Array:
    """values: (n, d) per-worker messages -> r gossip rounds Q^r @ values."""
    Qj = jnp.asarray(Q, values.dtype)

    def body(v, _):
        return Qj @ v, None

    out, _ = jax.lax.scan(body, values, None, length=r)
    return out


def consensus_error(values: jax.Array) -> jax.Array:
    """Max deviation from the true mean across workers (the paper's
    ||z_i - z||; delta bound target)."""
    mean = jnp.mean(values, axis=0, keepdims=True)
    return jnp.max(jnp.linalg.norm(values - mean, axis=-1))


# ---------------------------------------------------------------------------
# On-device ring gossip (shard_map body)
# ---------------------------------------------------------------------------
def ring_gossip_step(x, axis_name: str):
    """One ring-gossip round for the per-device shard ``x``:
    x <- 0.5 x + 0.25 (left + right). Use inside shard_map."""
    left = jax.lax.ppermute(
        x, axis_name,
        [(i, (i + 1) % jax.lax.axis_size(axis_name))
         for i in range(jax.lax.axis_size(axis_name))])
    right = jax.lax.ppermute(
        x, axis_name,
        [(i, (i - 1) % jax.lax.axis_size(axis_name))
         for i in range(jax.lax.axis_size(axis_name))])
    return 0.5 * x + 0.25 * (left + right)


def ring_gossip(x, axis_name: str, rounds: int):
    def body(v, _):
        return ring_gossip_step(v, axis_name), None
    out, _ = jax.lax.scan(body, x, None, length=rounds)
    return out
