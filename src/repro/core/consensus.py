"""Decentralized AMB-DG (paper Sec. V): gossip consensus instead of a
master.

Workers exchange ``m_i^(0)(t) = n * b_i(t) * (z_i(t) + g_i(t))`` with
neighbours for r rounds through a doubly-stochastic communication
matrix Q; after enough rounds every worker holds ~ b(t) [z-bar + g(t)].
Eq. (24) lower-bounds the rounds needed for consensus error delta:

    r >= ceil( log(2 sqrt(n) (1 + 2J/delta)) / (1 - lambda_2(Q)) )

Two realizations:
  * dense matrix powers (numpy/jax) for the simulator and tests;
  * a ``lax.ppermute`` ring for on-device decentralized execution under
    ``shard_map`` (each mesh index = one worker).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Communication matrices
# ---------------------------------------------------------------------------
def gossip_matrix(topology: str, n: int) -> np.ndarray:
    """Doubly-stochastic, symmetric (hence PSD ordering on eigenvalues),
    with Q_ij > 0 iff i=j or (i,j) is an edge."""
    if topology == "complete":
        Q = np.full((n, n), 1.0 / n)
    elif topology == "ring":
        Q = np.zeros((n, n))
        for i in range(n):
            Q[i, i] = 0.5
            Q[i, (i - 1) % n] += 0.25
            Q[i, (i + 1) % n] += 0.25
    elif topology == "torus":
        side = int(round(math.sqrt(n)))
        if side * side != n:
            raise ValueError(f"torus needs a square n, got {n}")
        Q = np.zeros((n, n))
        for i in range(n):
            r, c = divmod(i, side)
            Q[i, i] = 1.0 / 3.0
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % side) * side + (c + dc) % side
                Q[i, j] += 1.0 / 6.0
    else:
        raise ValueError(topology)
    assert np.allclose(Q.sum(0), 1.0) and np.allclose(Q.sum(1), 1.0)
    return Q


def lambda2(Q: np.ndarray) -> float:
    """Second-largest eigenvalue magnitude (spectral gap driver)."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(Q)))[::-1]
    return float(ev[1])


def min_rounds(delta: float, n: int, J: float, lam2: float) -> int:
    """Paper eq. (24). Never returns fewer than 1 round: eq. (24) is a
    lower bound on the rounds needed to REACH delta, and zero rounds
    reaches nothing. (For the current formula the argument of the log
    is >= 2 for any n >= 1, so the ceil was already >= 1; the max is a
    defensive floor pinning that contract against future reworks of
    the bound — see test_min_rounds_never_zero.)"""
    if lam2 >= 1.0:
        raise ValueError("graph not connected (lambda2 >= 1)")
    num = math.log(2.0 * math.sqrt(n) * (1.0 + 2.0 * J / delta))
    return max(int(math.ceil(num / (1.0 - lam2))), 1)


def run_consensus(values: jax.Array, Q, r: int) -> jax.Array:
    """values: (n, d) per-worker messages -> r gossip rounds Q^r @ values."""
    Qj = jnp.asarray(Q, values.dtype)

    def body(v, _):
        return Qj @ v, None

    out, _ = jax.lax.scan(body, values, None, length=r)
    return out


def consensus_error(values: jax.Array) -> jax.Array:
    """Max deviation from the true mean across workers (the paper's
    ||z_i - z||; delta bound target)."""
    mean = jnp.mean(values, axis=0, keepdims=True)
    return jnp.max(jnp.linalg.norm(values - mean, axis=-1))


# ---------------------------------------------------------------------------
# Ordered-fold gossip: ONE definition of a round, two executions
# ---------------------------------------------------------------------------
# A gossip round is an ordered list of (neighbour-index array, weight)
# terms folded left to right:
#
#     x_i' = w_0 * x_{nbr_0[i]} + w_1 * x_{nbr_1[i]} + ...
#
# Both realizations consume the SAME stencil with the SAME fold order —
# the dense oracle gathers neighbours by indexing the stacked (n, ...)
# value array, the on-device path gathers them with ``lax.ppermute``
# under shard_map — so they are bit-identical by construction (same
# multiplies, same adds, same order). The stencil weights are exactly
# the rows of ``gossip_matrix`` (asserted below), so the dense fold IS
# the gossip-matrix power oracle applied term by term.


def topology_stencil(topology: str, n: int):
    """Ordered (nbr, weight) terms for one gossip round; ``nbr`` is an
    (n,) int array, term k contributes ``weight * x[nbr[i]]`` to worker
    i. Ring/torus lead with the identity (self) term; the complete
    graph folds plainly over workers 0..n-1."""
    idx = np.arange(n)
    if topology == "complete":
        # n cyclic-shift terms, each weighted 1/n: worker i folds
        # x_i, x_{i+1}, ..., x_{i+n-1} (wrapping). Every term is a
        # true permutation — ppermute requires one — and the dense
        # gather applies the identical per-worker order.
        terms = [((idx + d) % n, 1.0 / n) for d in range(n)]
    elif topology == "ring":
        terms = [(idx, 0.5), ((idx - 1) % n, 0.25), ((idx + 1) % n, 0.25)]
    elif topology == "torus":
        side = int(round(math.sqrt(n)))
        if side * side != n:
            raise ValueError(f"torus needs a square n, got {n}")
        r, c = np.divmod(idx, side)
        terms = [(idx, 1.0 / 3.0)]
        for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            terms.append((((r + dr) % side) * side + (c + dc) % side,
                          1.0 / 6.0))
    else:
        raise ValueError(topology)
    # merge duplicate neighbour terms (torus side=2 / ring n=2: the +1
    # and -1 shifts coincide), first-occurrence order. Duplicates MUST
    # not reach the fold: XLA rewrites the repeated ``acc + t + t``
    # into ``acc + 2t`` differently in the dense and shard_map
    # programs, which is exactly the ULP drift the shared stencil
    # exists to prevent.
    merged = []
    for nbr, w in terms:
        nbr = np.asarray(nbr, np.int32)
        for k, (prev, pw) in enumerate(merged):
            if np.array_equal(prev, nbr):
                merged[k] = (prev, pw + w)
                break
        else:
            merged.append((nbr, float(w)))
    return [(nbr, float(w)) for nbr, w in merged]


def _stencil_matrix(topology: str, n: int) -> np.ndarray:
    """The doubly-stochastic matrix the stencil fold applies per round."""
    Q = np.zeros((n, n))
    for nbr, w in topology_stencil(topology, n):
        Q[np.arange(n), nbr] += w
    return Q


def _assert_stencil_matches_matrix(topology: str, n: int):
    np.testing.assert_allclose(_stencil_matrix(topology, n),
                               gossip_matrix(topology, n), atol=1e-12)


def _is_self_term(nbr: np.ndarray) -> bool:
    """Is this stencil term the identity (worker i reads worker i)?
    The one definition shared by both fold bodies and the payload
    model — the self term skips the gather/ppermute entirely, so all
    three must agree on what counts as one."""
    return bool((nbr == np.arange(nbr.shape[0])).all())


def _fold_round(x, terms, gather):
    """Shared fold body: ``gather(x, nbr)`` returns per-worker
    neighbour values; identity terms skip the gather entirely. Each
    weighted term is pinned with an ``optimization_barrier`` (the
    delayed._dequantize precedent): without it XLA contracts
    ``acc + w * v`` into an FMA differently in the dense and shard_map
    programs and the two executions drift a ULP apart."""
    acc = None
    for nbr, w in terms:
        v = x if _is_self_term(nbr) else gather(x, nbr)
        term = jax.lax.optimization_barrier(w * v)
        acc = term if acc is None else acc + term
    return acc


def gossip_round_dense(values: jax.Array, topology: str) -> jax.Array:
    """One stencil-fold round on stacked (n, ...) per-worker values —
    the dense gossip-matrix oracle, applied term by term."""
    n = values.shape[0]
    terms = topology_stencil(topology, n)
    return _fold_round(values, terms, lambda v, nbr: v[nbr])


def run_consensus_fold(values: jax.Array, topology: str, r: int
                       ) -> jax.Array:
    """r stencil-fold rounds on stacked (n, ...) values. Bit-identical
    to ``gossip_rounds_shard`` under shard_map; equal to
    ``run_consensus(values, gossip_matrix(topology, n), r)`` up to the
    matmul's reduction order."""
    def body(v, _):
        return gossip_round_dense(v, topology), None
    out, _ = jax.lax.scan(body, values, None, length=r)
    return out


def gossip_round_shard(x, axis_name: str, topology: str, n: int):
    """One stencil-fold round for the per-worker shard ``x`` inside
    shard_map (mesh index along ``axis_name`` = worker index; ``n``
    workers — passed statically, the perm tables need it at trace
    time). The neighbour gather is a ``lax.ppermute``: receiver i
    takes term k's value from worker nbr_k[i]."""
    terms = topology_stencil(topology, n)

    def gather(v, nbr):
        # every non-identity stencil term is a true permutation
        # (identity terms are skipped by _fold_round), so the gather
        # is exactly one ppermute: receiver i's source is nbr[i]
        return jax.lax.ppermute(
            v, axis_name, [(int(nbr[i]), i) for i in range(n)])

    return _fold_round(x, terms, gather)


def gossip_rounds_shard(x, axis_name: str, topology: str, n: int,
                        rounds: int):
    """r gossip rounds under shard_map (scan keeps one HLO body, like
    the dense fold — same op sequence, bit-identical results)."""
    def body(v, _):
        return gossip_round_shard(v, axis_name, topology, n), None
    out, _ = jax.lax.scan(body, x, None, length=rounds)
    return out


# ---------------------------------------------------------------------------
# Mask-aware gossip: elastic worker sets (core.worker_process)
# ---------------------------------------------------------------------------
# When the active set varies over time (ElasticConfig), a round must
# not average in dead neighbours' stale values. The masked fold
# reroutes each dead source's weight to the receiver's SELF term
# ("dead neighbours contribute identity weight"):
#
#     w_eff_k[i]    = w_k * active[nbr_k[i]]          (non-self terms)
#     w_eff_self[i] = w_self + sum_k w_k * (1 - active[nbr_k[i]])
#
# Each receiver's effective row still sums to 1, and because Q is
# symmetric the effective matrix restricted to the alive block stays
# doubly stochastic (column mass lost to dead receivers returns
# through their own rerouted self terms) — so the alive workers'
# consensus target is exactly the mean over ALIVE messages, the
# renormalized stencil. Dead workers' own rows degenerate to the
# identity; the strategy freezes their state with a jnp.where anyway.
# Under the all-alive mask every w_eff reduces to w + exact-zero
# residues, so the masked fold degenerates to the unmasked one — the
# static ≡ no-churn contract the elastic suite pins.


def _masked_term_weights(terms, a_of):
    """Per-term effective weights for one receiver set. ``a_of(nbr)``
    returns the term's source activity per receiver (f32 0/1 — an (n,)
    vector for the dense fold, this worker's scalar under shard_map).
    Residues accumulate in stencil order in BOTH executions, so the
    float algebra is shared (the _fold_round bit-identity discipline)."""
    self_k = [k for k, (nbr, _) in enumerate(terms)
              if _is_self_term(nbr)]
    if not self_k:
        raise ValueError("masked gossip needs a self term to absorb "
                         "dead neighbours' weight (every registered "
                         "topology has one: Q_ii > 0)")
    w_effs = [None] * len(terms)
    extra = None
    for k, (nbr, w) in enumerate(terms):
        if k == self_k[0]:
            continue
        a = a_of(nbr)
        w_effs[k] = jnp.float32(w) * a
        residue = jnp.float32(w) * (1.0 - a)
        extra = residue if extra is None else extra + residue
    w_self = jnp.float32(terms[self_k[0]][1])
    w_effs[self_k[0]] = w_self if extra is None else w_self + extra
    return w_effs


def _masked_fold_round(x, terms, w_effs, gather):
    """Masked twin of ``_fold_round``: identical gather/ppermute
    structure, with each term's python-float weight replaced by its
    per-receiver effective weight (broadcast over the value's trailing
    dims). The same per-term optimization_barrier pins the products
    against cross-program FMA contraction."""
    acc = None
    for (nbr, _), w_eff in zip(terms, w_effs):
        v = x if _is_self_term(nbr) else gather(x, nbr)
        w = jnp.reshape(w_eff, jnp.shape(w_eff)
                        + (1,) * (v.ndim - jnp.ndim(w_eff)))
        term = jax.lax.optimization_barrier(w * v)
        acc = term if acc is None else acc + term
    return acc


def gossip_round_dense_masked(values: jax.Array, topology: str,
                              active: jax.Array) -> jax.Array:
    """One masked stencil-fold round on stacked (n, ...) per-worker
    values; ``active`` is the (n,) 0/1 mask (f32 or bool)."""
    n = values.shape[0]
    terms = topology_stencil(topology, n)
    a = jnp.asarray(active, values.dtype)
    w_effs = _masked_term_weights(
        terms, lambda nbr: a[jnp.asarray(nbr)])
    return _masked_fold_round(values, terms, w_effs,
                              lambda v, nbr: v[nbr])


def run_consensus_fold_masked(values: jax.Array, topology: str, r: int,
                              active: jax.Array) -> jax.Array:
    """r masked rounds (the mask is per-epoch: constant across the
    rounds of one exchange). Bit-identical to
    ``gossip_rounds_shard_masked`` under shard_map; degenerates to
    ``run_consensus_fold`` bit-for-bit under the all-alive mask."""
    def body(v, _):
        return gossip_round_dense_masked(v, topology, active), None
    out, _ = jax.lax.scan(body, values, None, length=r)
    return out


def gossip_round_shard_masked(x, axis_name: str, topology: str, n: int,
                              active: jax.Array):
    """One masked round for the per-worker shard ``x`` inside
    shard_map. ``active`` is the full replicated (n,) mask (spec P());
    each worker resolves its own per-term source activity through the
    static neighbour tables + its axis index, so the weight algebra
    matches the dense fold receiver by receiver."""
    terms = topology_stencil(topology, n)
    i = jax.lax.axis_index(axis_name)
    a = jnp.asarray(active, x.dtype)

    def gather(v, nbr):
        return jax.lax.ppermute(
            v, axis_name, [(int(nbr[j]), j) for j in range(n)])

    w_effs = _masked_term_weights(
        terms, lambda nbr: a[jnp.asarray(nbr)[i]])
    return _masked_fold_round(x, terms, w_effs, gather)


def gossip_rounds_shard_masked(x, axis_name: str, topology: str,
                               n: int, rounds: int, active: jax.Array):
    """r masked gossip rounds under shard_map (scan keeps one HLO
    body, like every other fold here)."""
    def body(v, _):
        return gossip_round_shard_masked(v, axis_name, topology, n,
                                         active), None
    out, _ = jax.lax.scan(body, x, None, length=rounds)
    return out


def consensus_error_masked(values: jax.Array, active: jax.Array
                           ) -> jax.Array:
    """Max deviation from the ALIVE mean across alive workers (dead
    workers are frozen spectators — including them would report their
    drift from a consensus they never joined). All-dead epochs report
    exact 0."""
    a = jnp.asarray(active, values.dtype).reshape(-1, 1)
    n_alive = jnp.maximum(jnp.sum(a), 1.0)
    mean = jnp.sum(values * a, axis=0, keepdims=True) / n_alive
    dev = jnp.linalg.norm((values - mean) * a, axis=-1)
    return jnp.max(dev)


# ---------------------------------------------------------------------------
# int8-compressed gossip with per-round error feedback
# ---------------------------------------------------------------------------
# Each round every worker sends its CURRENT value quantized to int8
# with per-row bf16 scales (``optim.compression.quantize_int8_rows``,
# the delay-ring scheme with the scale rounded to an 8-bit mantissa);
# the quantization error is kept in a per-worker residual and fed into
# the next round's message, so the sent stream telescopes:
#
#     fed_k = v_k + r_k;  d_k = dequant(quant(fed_k));  r_{k+1} = fed_k - d_k
#     =>  sum_k d_k + r_final = sum_k v_k + r_initial     (exactly)
#
# The stencil fold runs on the DEQUANTIZED messages d — the self term
# included, so each round still applies the doubly-stochastic matrix
# to the values actually on the wire and value+residual mass is
# conserved.
#
# Dense/shard_map bit-identity here CANNOT lean on the uncompressed
# fold's optimization barriers: on XLA:CPU the barriers are elided by
# the time LLVM contracts multiplies into the fold's adds, and the
# dense and shard_map programs contract DIFFERENT operands (observed:
# a ULP apart wherever a stencil weight is not a power of two —
# torus's 1/3). Instead the compressed round is built so that every
# f32 product feeding an add/subtract is EXACTLY representable:
#
#   * scales are bf16-rounded, so q (7-bit integer) x scale (8-bit
#     mantissa) and q x (w*scale bf16-rounded) fit in < 24 mantissa
#     bits — FMA contraction of these products is value-invisible;
#   * the per-term weight rides IN the scale (one product per term,
#     the same shape as the uncompressed fold's terms).
#
# With every contractible product exact, any contraction choice the
# emitter makes yields the same bits in both executions — and the
# bf16 scales halve the scale wire payload as a side effect.


def _ef_compress_round_int8(v, res):
    """Shared per-round compression body: (value, residual) ->
    (q int8, scales bf16, new residual). The residual ``fed - q*s``
    may be FMS-contracted freely: q*s is exact by construction (see
    the block comment above), so contraction cannot change it."""
    from repro.optim.compression import (dequantize_int8_rows,
                                         quantize_int8_rows)
    fed = v + res
    q, scales = quantize_int8_rows(fed, scale_dtype=jnp.bfloat16)
    return q, scales, fed - dequantize_int8_rows(q, scales)


def _weighted_scale(w: float, scales: jax.Array) -> jax.Array:
    """bf16-rounded weighted dequantization scale ``bf16(w * s)`` as
    f32: the one shared definition of a compressed term's scale, so
    dense and shard_map quantize/dequantize identically AND the
    ensuing ``q * ws`` product stays exactly representable."""
    ws = jnp.float32(w) * scales.astype(jnp.float32)
    return ws.astype(jnp.bfloat16).astype(jnp.float32)


def _fold_round_compressed(q, scales, terms, gather):
    """Compressed twin of ``_fold_round``: the gather moves the WIRE
    payload (q int8 + per-row bf16 scales) and every receiver
    dequantizes after gathering — in BOTH executions — with the
    stencil weight folded into the gathered per-row scale:

        term_k = q_{nbr_k}.f32 * bf16(w_k * s_{nbr_k})[..., None]

    This is the round's DEFINITION (the compressed dense oracle
    applies it too, so dense and shard_map agree bit for bit by
    construction: every term is one exact product, see the block
    comment above). The identity term goes through the same q/scales
    path as the neighbours."""
    acc = None
    for nbr, w in terms:
        if _is_self_term(nbr):
            qn, sn = q, scales
        else:
            qn, sn = gather(q, scales, nbr)
        term = qn.astype(jnp.float32) * _weighted_scale(w, sn)[..., None]
        acc = term if acc is None else acc + term
    return acc


def gossip_round_dense_int8(values: jax.Array, residual: jax.Array,
                            topology: str):
    """One compressed stencil-fold round on stacked (n, rows, lanes)
    per-worker values — the compressed dense oracle. Returns
    (values', residual')."""
    n = values.shape[0]
    q, scales, res_new = _ef_compress_round_int8(values, residual)
    out = _fold_round_compressed(
        q, scales, topology_stencil(topology, n),
        lambda qq, ss, nbr: (qq[nbr], ss[nbr]))
    return out, res_new


def run_consensus_fold_int8(values: jax.Array, residual: jax.Array,
                            topology: str, r: int):
    """r compressed rounds on stacked values; r=0 is the identity (no
    message is quantized, the residual is untouched). Bit-identical to
    ``gossip_rounds_shard_int8`` under shard_map on the same (values,
    residual)."""
    def body(carry, _):
        v, res = carry
        return gossip_round_dense_int8(v, res, topology), None
    (out, res), _ = jax.lax.scan(body, (values, residual), None, length=r)
    return out, res


def gossip_round_shard_int8(x, res, axis_name: str, topology: str,
                            n: int):
    """One compressed round for the per-worker shard ``x`` inside
    shard_map: the wire payload per non-self stencil term is the int8
    tensor + the bf16 per-row scales (~1/3.9 of the f32 message);
    receivers dequantize locally. Returns (x', res')."""
    q, scales, res_new = _ef_compress_round_int8(x, res)

    def gather(qq, ss, nbr):
        perm = [(int(nbr[i]), i) for i in range(n)]
        # the scales cross the wire as their u16 BITS: permuting the
        # bf16 array directly lets XLA hoist the bf16->f32 dequant
        # convert above the collective-permute (value-identical, so
        # legal) and the wire silently carries f32 — 2x the scale
        # payload. An integer bitcast cannot be folded with the
        # convert, and round-trips the bits exactly.
        s_wire = jax.lax.ppermute(
            jax.lax.bitcast_convert_type(ss, jnp.uint16),
            axis_name, perm)
        return (jax.lax.ppermute(qq, axis_name, perm),
                jax.lax.bitcast_convert_type(s_wire, jnp.bfloat16))

    out = _fold_round_compressed(q, scales,
                                 topology_stencil(topology, n), gather)
    return out, res_new


def gossip_rounds_shard_int8(x, res, axis_name: str, topology: str,
                             n: int, rounds: int):
    """r compressed gossip rounds under shard_map, carrying the
    error-feedback residual across rounds (and, through the strategy
    state, across train steps)."""
    def body(carry, _):
        v, r_ = carry
        return gossip_round_shard_int8(v, r_, axis_name, topology, n), None
    (out, res_out), _ = jax.lax.scan(body, (x, res), None, length=rounds)
    return out, res_out


# which gossip message-compression modes exist (ConsensusConfig.compression)
COMPRESSION_MODES = ("none", "int8")


def payload_bytes_per_round(topology: str, n: int, rows: int,
                            lanes: int = 128, compression: str = "none"
                            ) -> int:
    """Analytic per-worker wire bytes of ONE gossip round: every
    non-self stencil term moves a full per-worker message. f32 sends
    rows*lanes*4; int8 sends rows*lanes int8 + rows bf16 scales."""
    n_terms = sum(1 for nbr, _ in topology_stencil(topology, n)
                  if not _is_self_term(nbr))
    per_msg = (rows * lanes + rows * 2 if compression == "int8"
               else rows * lanes * 4)
    return n_terms * per_msg
