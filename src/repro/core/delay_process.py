"""Stochastic delay processes: time-varying staleness ``tau_t``.

The paper fixes ``tau = ceil(T_c / T_p)``; its whole point, though, is
wall-clock robustness on real networks, where round trips jitter,
burst, and heavy-tail. Agarwal & Duchi ("Distributed Delayed
Stochastic Optimization") and Attia et al. ("Faster Stochastic
Optimization with Arbitrary Delays") show the interesting regime is
exactly time-varying ``tau_t`` with delay-adaptive step sizes. This
module is the single source of those sequences for every layer:

  * the HOST training loop draws one delay per step and ships it to
    the device step as ``batch["delay"]`` (the delay-tolerant arena
    ring consumes it — ``core.arena.push_pop_variable``);
  * the cluster simulator draws per-epoch (anytime) or per-message
    (k-batch) delays from the same seeded processes, so golden traces
    pin the sequences exactly;
  * the property suite replays a process against a pure-python ring
    oracle (``tests/test_delay_process.py``).

Every process is seeded (``numpy.random.default_rng``), emits integer
delays in ``[delay_min, tau_max]``, and checkpoints its full state
(``state_dict``/``load_state_dict``) so restarts reproduce the exact
remaining sequence — the same restart-exactness contract the data
pipeline keeps.

Four processes (``DelayConfig.process``):

  fixed        tau_t = tau. The degenerate case: strategies route it
               to the pre-existing static-phase master path, pinned
               bit-identical by the regression suites.
  jitter       tau_t = clip(tau + U{-jitter..+jitter}): bounded
               symmetric wobble around the nominal round trip.
  heavy_tail   tau_t = clip(delay_min + floor(Pareto(tail_alpha))):
               mostly-fast with rare very-late stragglers (the
               Agarwal-Duchi regime; smaller alpha = fatter tail).
  bursty       2-state Gilbert-Elliott chain: ``delay_min`` .. nominal
               tau in the normal state, ``tau_max`` inside a burst
               (congestion episodes with geometric dwell times).
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

import numpy as np

from repro.configs.base import DelayConfig


def resolve_bounds(cfg: DelayConfig, tau: int) -> Tuple[int, int]:
    """Validate ``cfg`` against the nominal staleness ``tau`` and
    return the resolved ``(delay_min, tau_max)`` bounds. ``tau_max=0``
    resolves to ``tau`` for the fixed process (the ring depth the
    static schedule already uses); stochastic processes must set an
    explicit cap — the ring allocates tau_max+1 slots."""
    if cfg.process not in DELAY_PROCESSES:
        raise ValueError(f"unknown delay process {cfg.process!r}; "
                         f"registered: {sorted(DELAY_PROCESSES)}")
    if cfg.delay_min < 0:
        raise ValueError(f"delay_min must be >= 0, got {cfg.delay_min}")
    tau_max = cfg.tau_max
    if cfg.process == "fixed":
        tau_max = tau_max or tau
        if tau_max < tau:
            raise ValueError(f"fixed process: tau_max={tau_max} < "
                             f"tau={tau}")
        return min(cfg.delay_min, tau), tau_max
    if tau_max < 1:
        raise ValueError(
            f"stochastic delay process {cfg.process!r} needs an explicit "
            f"tau_max >= 1 (the staleness cap sizing the ring), got "
            f"{cfg.tau_max}")
    if cfg.delay_min > tau_max:
        raise ValueError(f"delay_min={cfg.delay_min} > tau_max={tau_max}")
    if not 0.0 <= cfg.p_burst <= 1.0 or not 0.0 <= cfg.p_exit <= 1.0:
        raise ValueError("bursty transition probabilities must be in "
                         f"[0, 1], got p_burst={cfg.p_burst}, "
                         f"p_exit={cfg.p_exit}")
    if cfg.tail_alpha <= 0.0:
        raise ValueError(f"tail_alpha must be > 0, got {cfg.tail_alpha}")
    if cfg.jitter < 0:
        raise ValueError(f"jitter must be >= 0, got {cfg.jitter}")
    return cfg.delay_min, tau_max


class DelayProcess:
    """One seeded per-step delay sequence. Subclasses implement
    ``_draw()`` -> int; the base class owns seeding, clipping to
    ``[delay_min, tau_max]``, and checkpointable state."""

    name: str = "?"

    def __init__(self, cfg: DelayConfig, tau: int):
        self.cfg = cfg
        self.tau = int(tau)
        self.delay_min, self.tau_max = resolve_bounds(cfg, tau)
        self._rng = np.random.default_rng(cfg.seed)

    def _draw(self) -> int:
        raise NotImplementedError

    def next(self) -> int:
        """Draw the next delay (advances the seeded state)."""
        return int(np.clip(self._draw(), self.delay_min, self.tau_max))

    def sequence(self, n: int) -> np.ndarray:
        """The next ``n`` delays as an int64 array (advances state)."""
        return np.asarray([self.next() for _ in range(n)], np.int64)

    # -- restart exactness -------------------------------------------------
    def state_dict(self) -> Dict:
        return {"rng": self._rng.bit_generator.state}

    def load_state_dict(self, s: Dict):
        self._rng.bit_generator.state = s["rng"]

    def __repr__(self):
        return (f"{type(self).__name__}(tau={self.tau}, "
                f"bounds=[{self.delay_min}, {self.tau_max}], "
                f"seed={self.cfg.seed})")


class FixedDelay(DelayProcess):
    """The paper's constant staleness — the degenerate process every
    strategy routes to the pre-existing static-phase path."""

    name = "fixed"

    def _draw(self) -> int:
        return self.tau


class JitterDelay(DelayProcess):
    """Symmetric integer wobble: tau + U{-jitter..+jitter}, clipped."""

    name = "jitter"

    def _draw(self) -> int:
        j = self.cfg.jitter
        return self.tau + int(self._rng.integers(-j, j + 1))


class HeavyTailDelay(DelayProcess):
    """delay_min + floor(Pareto(tail_alpha)), clipped to tau_max:
    mostly delay_min with rare very-late stragglers. tail_alpha <= 1
    has infinite mean before clipping — the cap is what keeps the ring
    finite, exactly the role tau_max plays on device."""

    name = "heavy_tail"

    def _draw(self) -> int:
        return self.delay_min + int(np.floor(
            self._rng.pareto(self.cfg.tail_alpha)))


class BurstyDelay(DelayProcess):
    """Gilbert-Elliott congestion: a 2-state Markov chain with
    geometric dwell times. Normal state emits the nominal delay
    (clip(tau)), burst state pins the cap tau_max. Transitions are
    drawn BEFORE the emission, so a burst entered at step t already
    delays step t's gradient."""

    name = "bursty"

    def __init__(self, cfg: DelayConfig, tau: int):
        super().__init__(cfg, tau)
        self._in_burst = False

    def _draw(self) -> int:
        u = float(self._rng.random())
        if self._in_burst:
            self._in_burst = u >= self.cfg.p_exit
        else:
            self._in_burst = u < self.cfg.p_burst
        return self.tau_max if self._in_burst else self.tau

    def state_dict(self) -> Dict:
        s = super().state_dict()
        s["in_burst"] = bool(self._in_burst)
        return s

    def load_state_dict(self, s: Dict):
        super().load_state_dict(s)
        self._in_burst = bool(s.get("in_burst", False))


DELAY_PROCESSES: Dict[str, Type[DelayProcess]] = {
    c.name: c for c in (FixedDelay, JitterDelay, HeavyTailDelay,
                        BurstyDelay)}


def make_delay_process(cfg: DelayConfig, tau: int) -> DelayProcess:
    """Construct the process named by ``cfg.process`` (validates the
    config — every consumer goes through here)."""
    resolve_bounds(cfg, tau)      # raise early with the full message
    return DELAY_PROCESSES[cfg.process](cfg, tau)
