"""Delayed cross-pod gradient exchange — the "DG" in AMB-DG on a TPU
pod mesh.

The cross-pod (DCN) all-reduce is the slow link; AMB-DG's insight is to
keep computing while it completes. We model the in-flight reductions
with a circular buffer of ``tau`` slots in the train state:

    push  : this step's *pod-local* (grad_sum, count), stacked per pod
            (leading dim = n_pods, sharded over the 'pod' mesh axis so
            no cross-pod bytes move at push time)
    pop   : the entry from ``tau`` steps ago; summing its pod dimension
            is what GSPMD lowers to the DCN all-reduce. Because the
            popped value has no data dependency on the current step's
            compute, XLA is free to overlap the collective with the
            forward/backward of the current step.

tau = 0 degenerates to a synchronous (blocking) reduction = plain AMB.

Optional int8 compression (QSGD-flavored, per-tensor scale) quarters the
DCN payload. Error feedback keeps the quantization bias out of the
update: the residual (g - dequant(quant(g))) is carried in the buffer
and added back into the next push, so quantization noise telescopes
instead of accumulating.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import _is_axes_leaf  # single shared definition


class DelayBuffer(NamedTuple):
    grads: Any         # pytree; leaves (tau, n_pods, *shape) f32/int8
    scales: Any        # pytree; leaves (tau, n_pods) f32 (int8) or None
    residual: Any      # pytree; leaves (n_pods, *shape) f32 (int8) or None
    counts: jax.Array  # (tau, n_pods) f32
    head: jax.Array    # i32, next slot to overwrite (= oldest entry)


def init_buffer(params, tau: int, n_pods: int,
                compression: str = "none") -> Optional[DelayBuffer]:
    if tau == 0:
        return None
    if compression == "int8":
        grads = jax.tree.map(
            lambda p: jnp.zeros((tau, n_pods) + p.shape, jnp.int8), params)
        scales = jax.tree.map(
            lambda p: jnp.zeros((tau, n_pods), jnp.float32), params)
        residual = jax.tree.map(
            lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
    else:
        grads = jax.tree.map(
            lambda p: jnp.zeros((tau, n_pods) + p.shape, jnp.float32), params)
        scales = None
        residual = None
    return DelayBuffer(grads=grads, scales=scales, residual=residual,
                       counts=jnp.zeros((tau, n_pods), jnp.float32),
                       head=jnp.zeros((), jnp.int32))


def pod_sum(x):
    """Sum over the leading pod dim, mesh-aware.

    Under an active multi-pod sharding profile this is a single
    ``jnp.sum`` over the pod-sharded axis — the one reduce GSPMD
    lowers to the DCN all-reduce the whole AMB-DG pipeline is built
    around. Off-mesh (CPU tests/benchmarks) it is an explicit left
    fold: ~4x faster than XLA:CPU's axis-0 reduce of a slice, and a
    deterministic order shared by both master pipelines (XLA's
    ``reduce`` accumulation order is unspecified, which would break
    their bit-for-bit agreement once n_pods > 2)."""
    from repro.dist.context import active_mesh
    mesh = active_mesh()
    if mesh is not None and mesh.n_pods > 1:
        return jnp.sum(x, axis=0)
    acc = x[0]
    for p in range(1, x.shape[0]):
        acc = acc + x[p]
    return acc


def _quantize(g):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    s = scale.reshape((-1,) + (1,) * (q.ndim - 1))
    # barrier: stops XLA/LLVM from contracting the later ``fed - deq``
    # into an FMA — contraction decisions are shape/fusion dependent,
    # which would let the pytree and arena paths drift by 1 ULP per
    # step (and quantization then amplifies the drift)
    return jax.lax.optimization_barrier(q.astype(jnp.float32) * s)


def push_pop(buffer: DelayBuffer, pod_grads, pod_counts,
             compression: str = "none", params_axes=None
             ) -> Tuple[Any, jax.Array, DelayBuffer]:
    """Insert this step's pod-stacked (grads, counts); return the entry
    from tau steps ago summed over pods (-> the DCN collective), plus
    the updated buffer.

    pod_grads: pytree, leaves (n_pods, *shape) f32, sharded over 'pod'.
    pod_counts: (n_pods,) f32.
    params_axes: optional logical-axes tree matching pod_grads' inner
    dims — required for int8 so the *compressed* payload crosses the
    pod axis (the gather is forced on the int8 leaves; dequantization
    happens after, locally). Without it GSPMD would dequantize first
    and put f32 on the DCN wire.
    Returns (grad_sum_global, count_global, new_buffer).
    """
    slot = buffer.head

    # ---- pop the oldest entry (about to be overwritten) ----
    if compression == "int8":
        from repro.dist.context import constrain

        def pop_leaf(q, s, ax):
            q, s = q[slot], s[slot]
            if ax is not None:
                # pod-replicate the INT8 tensor (the actual DCN bytes),
                # keeping the data/model sharding of the inner dims
                q = constrain(q, (None,) + tuple(ax))
                s = constrain(s, (None,))
            return _dequantize(q, s)

        if params_axes is not None:
            # flatten_up_to hands each leaf its (whole) axes tuple
            old = jax.tree.map(
                lambda q, s, ax: pop_leaf(q, s, tuple(ax)),
                buffer.grads, buffer.scales, params_axes)
        else:
            old = jax.tree.map(
                lambda q, s: _dequantize(q[slot], s[slot]),
                buffer.grads, buffer.scales)
    else:
        old = jax.tree.map(lambda b: b[slot], buffer.grads)
    old_count = buffer.counts[slot]

    # the pod-dimension sum is the (delayed) DCN all-reduce
    grad_sum = jax.tree.map(pod_sum, old)
    count_sum = jnp.sum(old_count)

    # ---- push the new entry ----
    if compression == "int8":
        fed = jax.tree.map(lambda g, r: g + r, pod_grads, buffer.residual)
        leaves, treedef = jax.tree.flatten(fed)
        pairs = [jax.vmap(_quantize)(g) for g in leaves]
        q_tree = jax.tree.unflatten(treedef, [q for q, _ in pairs])
        s_tree = jax.tree.unflatten(treedef, [s for _, s in pairs])
        new_g = jax.tree.map(lambda b, q: b.at[slot].set(q),
                             buffer.grads, q_tree)
        new_s = jax.tree.map(lambda b, s: b.at[slot].set(s),
                             buffer.scales, s_tree)
        new_r = jax.tree.map(lambda f, q, s: f - _dequantize(q, s),
                             fed, q_tree, s_tree)
    else:
        new_g = jax.tree.map(lambda b, g: b.at[slot].set(g),
                             buffer.grads, pod_grads)
        new_s, new_r = buffer.scales, buffer.residual
    new_c = buffer.counts.at[slot].set(pod_counts)
    new_head = (slot + 1) % buffer.counts.shape[0]

    return grad_sum, count_sum, DelayBuffer(
        grads=new_g, scales=new_s, residual=new_r,
        counts=new_c, head=new_head)


def buffer_logical_axes(params_axes, tau: int, compression: str = "none"):
    """Logical axes for the buffer pytree (leading (tau, pod) dims)."""
    if tau == 0:
        return None
    g_axes = jax.tree.map(lambda ax: (None, "pod") + tuple(ax),
                          params_axes, is_leaf=_is_axes_leaf)
    if compression == "int8":
        s_axes = jax.tree.map(lambda ax: (None, "pod"),
                              params_axes, is_leaf=_is_axes_leaf)
        r_axes = jax.tree.map(lambda ax: ("pod",) + tuple(ax),
                              params_axes, is_leaf=_is_axes_leaf)
    else:
        s_axes, r_axes = None, None
    return DelayBuffer(grads=g_axes, scales=s_axes, residual=r_axes,
                       counts=(None, "pod"), head=())
