"""Dual averaging (Nesterov'09 / Xiao'09), the paper's workhorse.

    z(t+1) = z(t) + g(t)
    w(t+1) = argmin_w  <z(t+1), w> + psi(w) / alpha(t+1)

With psi(w) = 0.5 ||w||^2 (the paper's choice in Euclidean space) the
argmin is closed-form:  w(t+1) = -alpha(t+1) * z(t+1).
With an L2-ball feasible set of radius C, the argmin is the same point
projected onto the ball.

Step sizes (Theorem IV.1):  alpha(t)^{-1} = L + sqrt((t + tau) / b_bar).

Works on arbitrary pytrees so the same optimizer drives the paper's
linear regression and the billion-parameter LM configs.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AmbdgConfig


class DualAveragingState(NamedTuple):
    z: Any          # dual variable, same pytree as params, f32
    t: jax.Array    # epoch counter (number of updates applied), i32


def alpha(t, cfg: AmbdgConfig):
    """Step size alpha(t) = 1 / (L + sqrt((t + tau) / b_bar))."""
    return 1.0 / (cfg.smoothness_L +
                  jnp.sqrt((t + cfg.tau) / cfg.b_bar))


def init(params) -> DualAveragingState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return DualAveragingState(z=z, t=jnp.zeros((), jnp.int32))


def prox_step(z, a, cfg: AmbdgConfig):
    """w = argmin <z, w> + psi(w)/a for the configured proximal psi."""
    w = jax.tree.map(lambda zi: (-a * zi), z)
    if cfg.proximal == "l2_ball":
        sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(w))
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, cfg.radius_C / jnp.maximum(norm, 1e-12))
        w = jax.tree.map(lambda wi: wi * scale, w)
    return w


def update(state: DualAveragingState, g, cfg: AmbdgConfig
           ) -> Tuple[Any, DualAveragingState]:
    """One dual-averaging update with (already averaged) gradient g.
    Returns (w(t+1), new_state)."""
    t_next = state.t + 1
    z_next = jax.tree.map(lambda zi, gi: zi + gi.astype(jnp.float32),
                          state.z, g)
    w_next = prox_step(z_next, alpha(t_next.astype(jnp.float32) + 1.0, cfg),
                       cfg)
    return w_next, DualAveragingState(z=z_next, t=t_next)
