"""Dual averaging (Nesterov'09 / Xiao'09), the paper's workhorse.

    z(t+1) = z(t) + g(t)
    w(t+1) = argmin_w  <z(t+1), w> + psi(w) / alpha(t+1)

With psi(w) = 0.5 ||w||^2 (the paper's choice in Euclidean space) the
argmin is closed-form:  w(t+1) = -alpha(t+1) * z(t+1).
With an L2-ball feasible set of radius C, the argmin is the same point
projected onto the ball.

Step sizes (Theorem IV.1):  alpha(t)^{-1} = L + sqrt((t + tau) / b_bar).

Works on arbitrary pytrees so the same optimizer drives the paper's
linear regression and the billion-parameter LM configs.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AmbdgConfig


class DualAveragingState(NamedTuple):
    z: Any          # dual variable, same pytree as params, f32
    t: jax.Array    # epoch counter (number of updates applied), i32


def alpha(t, cfg: AmbdgConfig, tau=None, b=None):
    """Step size alpha(t) = 1 / (L + sqrt((t + tau) / b)).

    ``tau`` defaults to the config's static worst case; the
    variable-delay path passes the OBSERVED staleness of the gradients
    applied at t instead (Agarwal-Duchi style delay-adaptive step:
    lighter-than-worst-case steps whenever the network ran ahead of
    the bound, automatic shrinkage through a burst). With a constant
    observed tau == cfg.tau the two are the same arithmetic on the
    same values — bit-identical by construction.

    ``b`` defaults to the static expected minibatch ``cfg.b_bar``; an
    adaptive batch schedule (``rc.batch_schedule``) passes the
    schedule's target b(t) instead, so the step size tracks the batch
    it actually asked for (larger batches = less gradient noise =
    bigger steps — Theorem IV.1's dependence on b_bar, made per-step).

    Zero-arrival contract: alpha is DECREASING in tau, so a stall step
    must never pass tau=0 (the ring's raw tau_obs on an empty pop) —
    that would claim the stalled network was perfectly fresh and
    inflate the step. Callers fall back to the ring cap tau_max on
    ``count == 0`` (see ``ambdg``), matching the worst case the
    non-adaptive schedule uses; z is unchanged on such steps, but the
    recomputed ``w = -alpha z`` is what the fallback keeps honest."""
    tau = cfg.tau if tau is None else tau
    b = cfg.b_bar if b is None else b
    return 1.0 / (cfg.smoothness_L +
                  jnp.sqrt((t + tau) / b))


def init(params) -> DualAveragingState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return DualAveragingState(z=z, t=jnp.zeros((), jnp.int32))


def prox_step(z, a, cfg: AmbdgConfig):
    """w = argmin <z, w> + psi(w)/a for the configured proximal psi."""
    w = jax.tree.map(lambda zi: (-a * zi), z)
    if cfg.proximal == "l2_ball":
        sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(w))
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, cfg.radius_C / jnp.maximum(norm, 1e-12))
        w = jax.tree.map(lambda wi: wi * scale, w)
    return w


def update(state: DualAveragingState, g, cfg: AmbdgConfig, tau=None,
           b=None) -> Tuple[Any, DualAveragingState]:
    """One dual-averaging update with (already averaged) gradient g.
    Returns (w(t+1), new_state). ``tau``/``b`` thread the observed
    staleness and the scheduled batch target into ``alpha`` (both
    default to the static config values — see ``alpha``)."""
    t_next = state.t + 1
    z_next = jax.tree.map(lambda zi, gi: zi + gi.astype(jnp.float32),
                          state.z, g)
    w_next = prox_step(z_next, alpha(t_next.astype(jnp.float32) + 1.0, cfg,
                                     tau=tau, b=b),
                       cfg)
    return w_next, DualAveragingState(z=z_next, t=t_next)


# ---------------------------------------------------------------------------
# Arena-form state: z lives permanently as one (rows, 128) buffer
# ---------------------------------------------------------------------------
class ArenaDualAveragingState(NamedTuple):
    z: jax.Array    # (rows, 128) f32 — the flat dual variable
    t: jax.Array    # epoch counter, i32


def init_arena(layout) -> ArenaDualAveragingState:
    z = jnp.zeros((layout.rows, 128), jnp.float32)
    return ArenaDualAveragingState(z=z, t=jnp.zeros((), jnp.int32))


def update_arena(layout, state: ArenaDualAveragingState, g_sum, count,
                 cfg: AmbdgConfig, impl: str = "auto", tau_obs=None,
                 b_sched=None) -> Tuple[Any, ArenaDualAveragingState]:
    """Arena twin of ``update`` with the count-normalization fused in:
    takes the *un-normalized* popped gradient sum and its count and
    returns (params_tree, new_state) with leaves f32. For the default
    ``proximal="l2"`` the result matches the pytree prox_step bit for
    bit; under ``l2_ball`` the elementwise ops match but the ball
    norm is one flat reduction instead of the pytree path's per-leaf
    sums, so an active projection agrees only to FP-summation-order
    (covered at ULP tolerance by tests/test_arena.py).

    On TPU this is the fused Pallas kernel (one donated pass producing
    z and w); on CPU the same arithmetic is composed in XLA with the
    prox multiply (w = -alpha z) folded into the unflatten gather, so
    no separate w buffer is ever materialized.

    ``tau_obs`` (variable-delay path): observed staleness of the
    applied gradients — switches alpha to the Agarwal-Duchi
    delay-adaptive form (see ``alpha``). ``b_sched`` (adaptive batch
    schedule): the controller's target b(t), replacing the static
    ``cfg.b_bar``. The kernels are untouched: alpha is a scalar
    operand on every impl.
    """
    from repro.core import arena as arena_mod
    from repro.kernels import resolve_impl
    from repro.kernels.dual_update.ops import (dual_update_arena,
                                               dual_update_arena_sharded)
    # the elementwise update has a shard_map wrapper, so multi-pod
    # meshes resolve to the per-shard kernel instead of the XLA ref
    impl = resolve_impl(impl, pod_shard_map=True)
    t_next = state.t + 1
    a = alpha(t_next.astype(jnp.float32) + 1.0, cfg, tau=tau_obs, b=b_sched)
    if impl in ("pallas", "pallas_sharded"):
        if impl == "pallas_sharded":
            from repro.dist.context import active_mesh
            z_next, w = dual_update_arena_sharded(
                state.z, g_sum, count, a, mesh_cfg=active_mesh())
        else:
            z_next, w = dual_update_arena(state.z, g_sum, count, a,
                                          impl="pallas")
        if cfg.proximal == "l2_ball":
            norm = jnp.sqrt(jnp.sum(jnp.square(w)))  # arena pads are zero
            w = w * jnp.minimum(1.0, cfg.radius_C / jnp.maximum(norm, 1e-12))
        params = arena_mod.unflatten_tree(layout, w, cast=False)
    else:
        denom = jnp.maximum(count, 1e-12)
        # div + add cannot FMA-contract, so this fuses freely with the
        # ring pop while staying bit-identical to normalize + update
        z_next = state.z + g_sum.astype(jnp.float32) / denom
        if cfg.proximal == "l2_ball":
            # same elementwise ops as prox_step: w = -a z, then w*proj
            w = -a * z_next
            norm = jnp.sqrt(jnp.sum(jnp.square(w)))  # arena pads are zero
            proj = jnp.minimum(1.0, cfg.radius_C / jnp.maximum(norm, 1e-12))
            params = arena_mod.unflatten_tree(layout, w, cast=False,
                                              scale=proj)
        else:
            params = arena_mod.unflatten_tree(layout, z_next, cast=False,
                                              scale=-a)
    return params, ArenaDualAveragingState(z=z_next, t=t_next)
