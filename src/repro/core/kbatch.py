"""K-batch async baseline (Dutta et al., AISTATS'18; paper Sec. VI).

Fixed per-message minibatch: each worker repeatedly computes exactly
b/K gradients and ships the sum; the master updates as soon as any K
messages have arrived (not necessarily from distinct workers). Staleness
is therefore *random* (Fig. 4 in the paper), unlike AMB-DG's
deterministic tau.

The scheme is inherently event-driven — the interesting behaviour
(message ordering, staleness distribution) lives in the cluster
simulator (``repro.sim``). This module provides the master's update
rule and the staleness bookkeeping used by both the simulator and the
tests.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AmbdgConfig
from repro.core import dual_averaging as da


class Message(NamedTuple):
    grad_sum: Any      # sum of b/K per-sample gradients
    count: float       # = b/K
    ref_epoch: int     # parameter version the gradients were taken at
    worker: int = -1   # sender id: canonical tie-break for same-epoch
    #                    messages (keeps accumulation + staleness
    #                    bookkeeping independent of arrival-heap order)


class KBatchMaster:
    """Collects messages; updates via dual averaging on every K-th.

    Each triggering batch of K messages is processed in the canonical
    ``(ref_epoch, worker)`` order, NOT arrival order: the gradient
    accumulation (a float left fold) and the staleness log entries then
    depend only on which messages arrived — reproducible from the
    simulator's seed — never on how the event heap happened to break
    timestamp ties. (The staleness *multiset*, i.e. the Fig.-4
    histogram, is unchanged by the reordering.)

    ``adaptive_b`` (adaptive batch schedules): the dual-averaging step
    size takes each triggering batch's total count in place of the
    static ``cfg.b_bar`` — under a schedule the K message counts ARE
    the drawn targets, so alpha tracks the batch the controller asked
    for (the k-batch twin of ``batch["b_sched"]``).
    """

    def __init__(self, params, cfg: AmbdgConfig, K: int,
                 adaptive_b: bool = False):
        self.cfg = cfg
        self.K = K
        self.adaptive_b = adaptive_b
        self.state = da.init(params)
        self.params = params
        self.pending: List[Message] = []
        self.update_count = 0
        self.staleness_log: List[int] = []

    def receive(self, msg: Message) -> bool:
        """Returns True if this message triggered a parameter update."""
        self.pending.append(msg)
        if len(self.pending) < self.K:
            return False
        batch = sorted(self.pending,
                       key=lambda m: (m.ref_epoch, m.worker))
        self.pending = []
        total = sum(m.count for m in batch)
        g = batch[0].grad_sum
        for m in batch[1:]:
            g = jax.tree.map(lambda a, b: a + b, g, m.grad_sum)
        g = jax.tree.map(lambda a: a / total, g)
        for m in batch:
            self.staleness_log.append(self.update_count + 1 - m.ref_epoch)
        self.params, self.state = da.update(
            self.state, g, self.cfg,
            b=float(total) if self.adaptive_b else None)
        self.update_count += 1
        return True
