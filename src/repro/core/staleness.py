"""Timeline algebra for AMB-DG (paper Sec. III, Fig. 1).

Pure-Python bookkeeping used by the simulator, the launcher and the
tests. All times in seconds; epochs are 1-indexed like the paper.

Worked example from the paper (T_c = 3*T_p): tau = 3; gradients for
epochs 1..tau+1 are computed w.r.t. w(1); for t >= tau+2 the master's
t-th update uses gradients computed w.r.t. w(t - tau) — e.g. w(6) is
computed from gradients w.r.t. w(2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def staleness(t_c: float, t_p: float) -> int:
    """tau = ceil(T_c / T_p) (paper's staleness parameter)."""
    if t_p <= 0:
        raise ValueError("T_p must be positive")
    return int(math.ceil(t_c / t_p))


def gradient_reference_epoch(t: int, tau: int) -> int:
    """Which parameter version w(r) the gradients of epoch t are computed
    against. Paper: r = 1 for 1 <= t <= tau+1, else r = t - tau."""
    if t < 1:
        raise ValueError("epochs are 1-indexed")
    return max(1, t - tau)


def worker_receives_update_at(t: int, t_p: float, t_c: float) -> float:
    """Time at which workers receive w(t+1) (paper: t*T_p + T_c)."""
    return t * t_p + t_c


def master_update_time(t: int, t_p: float, t_c: float) -> float:
    """Time of the master's t-th update (paper: t*T_p + T_c/2)."""
    return t * t_p + 0.5 * t_c


def amb_epoch_duration(t_p: float, t_c: float) -> float:
    """Synchronous AMB: workers idle through the round trip each epoch."""
    return t_p + t_c


def ambdg_epoch_duration(t_p: float, t_c: float) -> float:
    """AMB-DG: workers never idle — epochs tile at T_p."""
    return t_p


@dataclass(frozen=True)
class Timeline:
    """Convenience bundle used by the simulator & launcher."""
    t_p: float
    t_c: float

    @property
    def tau(self) -> int:
        return staleness(self.t_c, self.t_p)

    def reference(self, t: int) -> int:
        return gradient_reference_epoch(t, self.tau)

    def epochs_until(self, wall_time: float, scheme: str = "ambdg") -> int:
        """Number of master updates completed by ``wall_time``."""
        dur = (ambdg_epoch_duration if scheme == "ambdg"
               else amb_epoch_duration)(self.t_p, self.t_c)
        first = master_update_time(1, self.t_p, self.t_c)
        if wall_time < first:
            return 0
        return 1 + int((wall_time - first) // dur)
