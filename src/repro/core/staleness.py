"""Timeline algebra for AMB-DG (paper Sec. III, Fig. 1).

Pure-Python bookkeeping used by the simulator, the launcher and the
tests. All times in seconds; epochs are 1-indexed like the paper.

Worked example from the paper (T_c = 3*T_p): tau = 3; gradients for
epochs 1..tau+1 are computed w.r.t. w(1); for t >= tau+2 the master's
t-th update uses gradients computed w.r.t. w(t - tau) — e.g. w(6) is
computed from gradients w.r.t. w(2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence


def _as_epoch(t, name: str = "t", minimum: int = 1) -> int:
    """Validate an epoch/staleness index: integral (ints, or floats
    carrying an exact integer — a 2.0 from float timeline algebra is
    fine, a 2.5 is a bug) and >= ``minimum``. These helpers used to
    accept t=2.5 silently and hand back fractional epochs."""
    if isinstance(t, bool):
        raise ValueError(f"{name} must be an integer epoch index, "
                         f"got {t!r}")
    try:
        ti = int(t)
    except (TypeError, ValueError):
        raise ValueError(f"{name} must be an integer epoch index, "
                         f"got {t!r}") from None
    if ti != t:
        raise ValueError(f"{name} must be an integral epoch index, "
                         f"got non-integer {t!r}")
    if ti < minimum:
        raise ValueError(f"{name} must be >= {minimum}, got {t!r}"
                         + (" (epochs are 1-indexed)" if minimum == 1
                            else ""))
    return ti


def staleness(t_c: float, t_p: float) -> int:
    """tau = ceil(T_c / T_p) (paper's staleness parameter)."""
    if t_p <= 0:
        raise ValueError("T_p must be positive")
    if t_c < 0:
        raise ValueError("T_c must be non-negative")
    return int(math.ceil(t_c / t_p))


def gradient_reference_epoch(t: int, tau: int) -> int:
    """Which parameter version w(r) the gradients of epoch t are computed
    against. Paper: r = 1 for 1 <= t <= tau+1, else r = t - tau."""
    t = _as_epoch(t)
    tau = _as_epoch(tau, "tau", minimum=0)
    return max(1, t - tau)


# ---------------------------------------------------------------------------
# Variable-delay (stochastic tau_t) timeline algebra
# ---------------------------------------------------------------------------
def reference_epoch_sequence(delays: Sequence[int]) -> List[int]:
    """Per-update reference epochs under a delay sequence: the
    simulator's downlink model — the master's t-th update applies
    gradients computed w.r.t. w(max(1, t - tau_t)). With a constant
    sequence this is exactly ``gradient_reference_epoch`` per t."""
    return [gradient_reference_epoch(t, d)
            for t, d in enumerate(delays, start=1)]


def delivery_schedule(delays: Sequence[int]) -> Dict[int, List[int]]:
    """The delay-tolerant ring's uplink model: the gradient pushed at
    step s (1-indexed) with delay tau_s is applied at step s + tau_s.
    Returns {applied_step: sorted push steps} — late/out-of-order
    arrivals from different push epochs may share one applied step,
    and some steps receive nothing. The property suite checks the
    on-device ring pops exactly these sets
    (tests/test_delay_process.py)."""
    out: Dict[int, List[int]] = {}
    for s, d in enumerate(delays, start=1):
        d = _as_epoch(d, "delay", minimum=0)
        out.setdefault(s + d, []).append(s)
    return {u: sorted(ss) for u, ss in sorted(out.items())}


def observed_staleness(delays: Sequence[int], horizon: int,
                       empty_fallback: float = 0.0) -> List[float]:
    """Mean staleness of the gradients applied at each step 1..horizon
    under ``delivery_schedule`` (equal per-push weights) — the
    host-side twin of the ring's ``tau_obs`` that feeds the
    delay-adaptive step size.

    ``empty_fallback`` is what a zero-arrival step observes. The
    default 0.0 keeps the raw algebra (nothing arrived, nothing is
    stale); to mirror the DEVICE contract — where a stall step must
    feed the ring cap into the adaptive alpha, never a fresh-looking 0
    (see core/ambdg.py and the zero-arrival section of docs/arena.md)
    — pass ``empty_fallback=tau_max`` and the sequence matches
    ``metrics["tau_applied"]`` step for step."""
    sched = delivery_schedule(delays)
    out = []
    for u in range(1, _as_epoch(horizon, "horizon") + 1):
        pushes = sched.get(u, [])
        out.append(sum(u - s for s in pushes) / len(pushes)
                   if pushes else float(empty_fallback))
    return out


def worker_receives_update_at(t: int, t_p: float, t_c: float) -> float:
    """Time at which workers receive w(t+1) (paper: t*T_p + T_c)."""
    return t * t_p + t_c


def master_update_time(t: int, t_p: float, t_c: float) -> float:
    """Time of the master's t-th update (paper: t*T_p + T_c/2)."""
    return t * t_p + 0.5 * t_c


def amb_epoch_duration(t_p: float, t_c: float) -> float:
    """Synchronous AMB: workers idle through the round trip each epoch."""
    return t_p + t_c


def ambdg_epoch_duration(t_p: float, t_c: float) -> float:
    """AMB-DG: workers never idle — epochs tile at T_p."""
    return t_p


@dataclass(frozen=True)
class Timeline:
    """Convenience bundle used by the simulator & launcher."""
    t_p: float
    t_c: float

    @property
    def tau(self) -> int:
        return staleness(self.t_c, self.t_p)

    def reference(self, t: int) -> int:
        return gradient_reference_epoch(t, self.tau)

    def epochs_until(self, wall_time: float, scheme: str = "ambdg") -> int:
        """Number of master updates completed by ``wall_time``."""
        dur = (ambdg_epoch_duration if scheme == "ambdg"
               else amb_epoch_duration)(self.t_p, self.t_c)
        first = master_update_time(1, self.t_p, self.t_c)
        if wall_time < first:
            return 0
        return 1 + int((wall_time - first) // dur)
