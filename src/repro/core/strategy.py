"""The Strategy protocol: ONE surface for every algorithm variant.

The paper presents four schemes — AMB-DG (Sec. III), the synchronous
AMB baseline, the fixed-minibatch K-batch baseline (Dutta et al.) and
the fully-decentralized gossip extension (Sec. V). Each used to live
behind its own incompatible entry point; they are now classes
implementing one contract, registered by name and constructed through
``repro.api.build(model, rc)`` from ``rc.strategy``:

    strategy = repro.api.build(model, rc)
    state    = strategy.init_state(rng)
    state, metrics = strategy.train_step(state, batch)   # jit/donate-safe
    strategy.staleness_schedule()   # how stale applied gradients are
    Strategy.timeline_model()       # wall-clock algebra for sim/benchmarks

All master-ful strategies share the persistent-arena master pipeline
(``core.ambdg.build_step_fns``); ``DecentralizedStrategy`` is the
on-device promotion of the Sec.-V scheme — per-worker dual variables
held in arena layout, r gossip rounds as ``lax.ppermute`` under
``shard_map`` (bit-identical to the dense gossip-matrix fold oracle;
see ``core.consensus``), with r derived from the paper's eq. (24).

Adding a scenario = one new subclass + ``@register``. See
docs/strategies.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core import ambdg, anytime, consensus
from repro.core import arena as arena_mod
from repro.core import dual_averaging as da
from repro.models.api import Model


class StalenessSchedule(NamedTuple):
    """How stale the gradients applied by each master update are."""
    kind: str          # "delayed" | "sync" | "random" | "gossip"
    tau: int           # deterministic delay in epochs (0 = fresh)
    description: str


class TimelineModel(NamedTuple):
    """Wall-clock algebra of a scheme (paper Sec. III / Fig. 1), used
    by the cluster simulator and the benchmarks. The closed-form
    fields are the EXACT float expressions the golden traces pin —
    refactors must keep them literally.

    ``event_driven`` schemes (k-batch) have no closed form: update
    times come out of the simulator's arrival heap.
    """
    scheme: str
    event_driven: bool
    epoch_duration: Optional[Callable[[float, float], float]] = None
    # (t, t_p, t_c) -> wall time of the master's t-th update
    update_time: Optional[Callable[[int, float, float], float]] = None
    # (total_time, t_p, t_c) -> number of updates fitting the budget
    n_updates: Optional[Callable[[float, float, float], int]] = None


class Strategy:
    """Base class: subclasses assign ``init_state`` / ``train_step``
    as plain closures in ``__init__`` (so ``jax.jit(s.train_step,
    donate_argnums=(0,))`` behaves exactly like the pre-Strategy
    factory functions) and implement the two schedule probes."""

    name: str = "?"
    # one-line schedule summary for registry tables (benchmarks/report)
    schedule_summary: str = "?"
    # which simulator engine runs this scheme, if any: "anytime"
    # (epoch-timeline master), "kbatch" (event-driven arrival heap) or
    # None (on-device only) — dispatched by ``repro.api.simulate``
    sim_engine: Optional[str] = None
    # does this strategy's device step consume the per-epoch elastic
    # active mask as batch["active"]? Master-ful strategies don't (the
    # mask rides the anytime weights — a dead worker's samples carry
    # weight 0 and eq. (5) stays exact); the decentralized gossip does
    # (its stencil renormalizes around dead neighbours). The host loop
    # ships the mask exactly when this is True.
    consumes_active_mask: bool = False

    init_state: Callable[[jax.Array], Any]
    train_step: Callable[[Any, Any], Tuple[Any, Dict]]

    def __init__(self, model: Model, rc: RunConfig):
        from repro.core.batch_schedule import resolve_targets
        from repro.core.worker_process import validate_elastic
        validate_elastic(rc.elastic)   # every strategy reads rc.elastic
        # every strategy reads rc.batch_schedule (raise at build time,
        # not at the first drawn target)
        resolve_targets(rc.batch_schedule, rc.ambdg.b_bar)
        self.model = model
        self.rc = rc

    def staleness_schedule(self) -> StalenessSchedule:
        raise NotImplementedError

    def delay_process(self):
        """The seeded ``core.delay_process`` instance this strategy's
        ``rc.delay`` configures, or None under the fixed process. This
        is what makes the knob live outside the device step:
        ``api.simulate(strategy_instance, ...)`` feeds it to the
        simulator engine (per-epoch staleness for anytime schemes,
        per-message uplink jitter for k-batch)."""
        if self.rc.delay.process == "fixed":
            return None
        from repro.core.delay_process import make_delay_process
        return make_delay_process(self.rc.delay, self.rc.ambdg.tau)

    def worker_process(self, n_workers: int):
        """The seeded ``core.worker_process`` instance this strategy's
        ``rc.elastic`` configures for an ``n_workers``-strong fleet,
        or None under the static process. The elastic twin of
        ``delay_process``: ``api.simulate(strategy_instance, ...)``
        feeds it to the simulator engine (per-epoch active/speed draws
        for anytime schemes, epoch-indexed churn on the k-batch
        arrival heap)."""
        if self.rc.elastic.process == "static":
            return None
        from repro.core.worker_process import make_worker_process
        return make_worker_process(self.rc.elastic, n_workers)

    def batch_schedule(self):
        """The seeded ``core.batch_schedule`` controller this
        strategy's ``rc.batch_schedule`` configures, or None under the
        fixed schedule. The minibatch twin of ``delay_process``: the
        host loop draws one target per step (shipping it to the device
        step as ``batch["b_sched"]``), and
        ``api.simulate(strategy_instance, ...)`` feeds the same seeded
        sequence to the simulator engine (per-epoch anytime targets,
        per-job sizes for k-batch)."""
        if self.rc.batch_schedule.schedule == "fixed":
            return None
        from repro.core.batch_schedule import make_batch_schedule
        return make_batch_schedule(self.rc.batch_schedule,
                                   self.rc.ambdg.b_bar,
                                   self.rc.ambdg.tau)

    @classmethod
    def timeline_model(cls) -> TimelineModel:
        raise NotImplementedError


_REGISTRY: Dict[str, Type[Strategy]] = {}


def register(cls: Type[Strategy]) -> Type[Strategy]:
    """Class decorator: make ``cls`` constructible by name through
    ``repro.api.build`` / ``get_strategy``."""
    if cls.name in _REGISTRY:
        raise ValueError(f"strategy {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls
    return cls


def get_strategy(name: str) -> Type[Strategy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _require_fixed_delay(rc: RunConfig, name: str, why: str):
    """Strategies without a master delay ring reject stochastic
    ``rc.delay`` processes up front (a silently-ignored knob is worse
    than an error). The knob is still *read* by every strategy:
    ``staleness_schedule`` reports it, and the kbatch SIMULATOR
    consumes it for per-message network delays."""
    if rc.delay.process != "fixed":
        raise ValueError(
            f"strategy {name!r} does not support the stochastic delay "
            f"process {rc.delay.process!r}: {why}")


# ---------------------------------------------------------------------------
# AMB-DG (the paper) and its synchronous AMB degenerate
# ---------------------------------------------------------------------------
@register
class AmbdgStrategy(Strategy):
    """Anytime minibatch with delayed gradients: anytime accumulation
    -> tau-deep delay ring -> dual averaging, on the persistent arena
    master pipeline (or the pytree reference path)."""

    name = "ambdg"
    schedule_summary = "deterministic tau"
    sim_engine = "anytime"

    def __init__(self, model: Model, rc: RunConfig):
        super().__init__(model, rc)
        self.init_state, self.train_step = ambdg.build_step_fns(model, rc)

    def staleness_schedule(self) -> StalenessSchedule:
        from repro.core.delay_process import resolve_bounds
        dc = self.rc.delay
        if dc.process != "fixed":
            lo, hi = resolve_bounds(dc, self.rc.ambdg.tau)
            adaptive = ("delay-adaptive alpha" if dc.adaptive_alpha
                        else "worst-case alpha")
            return StalenessSchedule(
                "random", hi,
                f"stochastic tau_t in [{lo}, {hi}] from the seeded "
                f"{dc.process!r} delay process (delay-tolerant ring, "
                f"{adaptive})")
        tau = self.rc.ambdg.tau
        return StalenessSchedule(
            "delayed" if tau else "sync", tau,
            "deterministic tau = ceil(T_c / T_p) after pipeline fill")

    @classmethod
    def timeline_model(cls) -> TimelineModel:
        # workers never idle: epochs tile at T_p; the t-th update lands
        # half a round trip after epoch t ends (paper Fig. 1)
        return TimelineModel(
            scheme=cls.name, event_driven=False,
            epoch_duration=lambda t_p, t_c: t_p,
            update_time=lambda t, t_p, t_c: t * t_p + 0.5 * t_c,
            n_updates=lambda total, t_p, t_c:
                max(int((total - 0.5 * t_c) // t_p), 0))


@register
class AmbStrategy(Strategy):
    """Synchronous AMB (Ferdinand et al.): the AMB-DG step with tau=0
    on device; the wall-clock penalty (workers idle through the round
    trip) lives entirely in the timeline model."""

    name = "amb"
    schedule_summary = "none (sync)"
    sim_engine = "anytime"

    def __init__(self, model: Model, rc: RunConfig):
        _require_fixed_delay(rc, self.name,
                             "the synchronous baseline blocks on every "
                             "round trip — a stochastic tau_t belongs "
                             "to 'ambdg'")
        rc = rc.replace(ambdg=dataclasses.replace(rc.ambdg, tau=0))
        super().__init__(model, rc)
        self.init_state, self.train_step = ambdg.build_step_fns(model, rc)

    def staleness_schedule(self) -> StalenessSchedule:
        return StalenessSchedule("sync", 0, "fresh gradients every epoch")

    @classmethod
    def timeline_model(cls) -> TimelineModel:
        return TimelineModel(
            scheme=cls.name, event_driven=False,
            epoch_duration=lambda t_p, t_c: t_p + t_c,
            update_time=lambda t, t_p, t_c: t * t_p + (t - 0.5) * t_c,
            n_updates=lambda total, t_p, t_c:
                max(int((total - t_p - 0.5 * t_c) // (t_p + t_c)) + 1, 0))


# ---------------------------------------------------------------------------
# K-batch async (Dutta et al., AISTATS'18)
# ---------------------------------------------------------------------------
class KBatchState(NamedTuple):
    """The synchronous on-device realization's state: the shared
    master-pipeline state plus the parameter-version counter
    ``ref_epoch`` threaded through so staleness bookkeeping (and the
    simulator's Fig.-4 histogram) is derived from *state*, never from
    event-arrival order."""
    base: ambdg.TrainState
    ref_epoch: jax.Array    # i32: version the NEXT gradients refer to


@register
class KBatchStrategy(Strategy):
    """Fixed-per-message minibatch. The interesting behaviour — K
    arrivals per update, random staleness — is event-driven and lives
    in the simulator (``core.kbatch.KBatchMaster``, constructed by
    ``sim.simulate_kbatch`` with K defaulting to
    ``AmbdgConfig.kbatch_K``); the on-device SPMD realization is its
    synchronous degenerate (every worker's message arrives together,
    so staleness is 0 and the step is the tau=0 master pipeline on
    fixed-size minibatches)."""

    name = "kbatch"
    schedule_summary = "random (per message)"
    sim_engine = "kbatch"

    def __init__(self, model: Model, rc: RunConfig):
        from repro.core.delay_process import resolve_bounds
        # validated here, CONSUMED by the event-driven simulator: a
        # stochastic rc.delay jitters the per-message uplink times
        # (sim.simulate_kbatch's delay_process); the on-device SPMD
        # realization stays the synchronous degenerate either way
        resolve_bounds(rc.delay, rc.ambdg.tau)
        delay_cfg = rc.delay
        self._nominal_tau = rc.ambdg.tau
        rc = rc.replace(ambdg=dataclasses.replace(rc.ambdg, tau=0),
                        delay=dataclasses.replace(delay_cfg,
                                                  process="fixed",
                                                  tau_max=0))
        self.delay_cfg = delay_cfg
        super().__init__(model, rc)
        init_base, step_base = ambdg.build_step_fns(model, rc)

        def init_state(key) -> KBatchState:
            return KBatchState(base=init_base(key),
                               ref_epoch=jnp.ones((), jnp.int32))

        def train_step(state: KBatchState, batch):
            base, metrics = step_base(state.base, batch)
            metrics["staleness"] = metrics["step"] - state.ref_epoch
            return KBatchState(base=base,
                               ref_epoch=state.ref_epoch + 1), metrics

        self.init_state = init_state
        self.train_step = train_step

    def delay_process(self):
        # the on-device step stripped rc.delay to fixed; the simulator
        # hook reconstructs the configured process from the original
        if self.delay_cfg.process == "fixed":
            return None
        from repro.core.delay_process import make_delay_process
        return make_delay_process(self.delay_cfg, self._nominal_tau)

    def batch_schedule(self):
        # the on-device step runs the tau=0 synchronous degenerate,
        # but the delay-aware schedule still references the ORIGINAL
        # nominal staleness (the event-driven simulator's regime)
        if self.rc.batch_schedule.schedule == "fixed":
            return None
        from repro.core.batch_schedule import make_batch_schedule
        return make_batch_schedule(self.rc.batch_schedule,
                                   self.rc.ambdg.b_bar,
                                   self._nominal_tau)

    def staleness_schedule(self) -> StalenessSchedule:
        extra = ""
        if self.delay_cfg.process != "fixed":
            extra = (f"; uplink times jittered by the seeded "
                     f"{self.delay_cfg.process!r} delay process in the "
                     f"event-driven simulator")
        return StalenessSchedule(
            "random", 0,
            "random per-message staleness (update t applies messages "
            "with ref_epoch <= t; distribution from the arrival heap)"
            + extra)

    @classmethod
    def timeline_model(cls) -> TimelineModel:
        return TimelineModel(scheme=cls.name, event_driven=True)


# ---------------------------------------------------------------------------
# Decentralized AMB-DG (paper Sec. V): gossip consensus, no master
# ---------------------------------------------------------------------------
class DecentralizedState(NamedTuple):
    params: Any        # per-worker stacked pytree: leaves (n, *shape) f32
    z: jax.Array       # (n, rows, 128) f32 — per-worker duals, arena layout
    # (n, rows, 128) f32 — per-worker error-feedback residual of the
    # int8-compressed gossip (arena layout, donated alongside z; stays
    # zero under compression="none")
    residual: jax.Array
    t: jax.Array       # i32: dual-averaging epoch counter
    step: jax.Array    # i32: steps taken (mirrors TrainState.step)


@register
class DecentralizedStrategy(Strategy):
    """No master: each of ``rc.consensus.n_workers`` workers holds its
    own dual variable z_i in arena layout ((n, rows, 128), built once
    from the model's abstract shapes) and its own parameters w_i. Per
    epoch every worker computes an anytime gradient at w_i, forms the
    message m_i = n (b_i z_i + g_i) / b(t), and the messages run r
    gossip rounds through the topology's doubly-stochastic stencil;
    the consensus result is the new z_i and w_i = prox(z_i) applies
    per worker. r comes from the paper's eq. (24) bound computed from
    ``rc.consensus`` (or its explicit ``rounds`` override).

    Two gossip executions (``rc.consensus.gossip_impl``):

      "shard_map"  one mesh index per worker on a 1-D ('worker',)
                   device mesh; each round's neighbour exchange is a
                   ``lax.ppermute`` (specs from
                   ``dist.sharding.gossip_specs``) — the on-device
                   deployment path;
      "dense"      the same ordered stencil fold on the stacked (n,
                   rows, 128) array in one program — the gossip-matrix
                   power oracle, and the fallback when n_workers
                   doesn't map onto the local device count ("auto"
                   picks per availability).

    The two are bit-identical ON THE SAME MESSAGES (same fold, same
    barriers; validated every step by the conformance suite via
    ``ConsensusConfig.debug_messages``). Whole-run agreement across
    the two program variants is at tolerance only: GSPMD partitions
    the surrounding per-worker gradient matmuls differently in the
    multi-device program, which reorders their reductions.

    ``rc.consensus.compression="int8"`` quantizes each round's
    outgoing message to int8 with per-row scales (the delay-ring
    scheme) and carries the quantization error in the per-worker
    ``DecentralizedState.residual`` (arena layout, donated), so the
    compression error telescopes across rounds and train steps; the
    wire payload per round drops ~3.9x and the dense/shard_map
    bit-identity holds per compression mode (compressed sharded vs
    the compressed dense oracle). See docs/strategies.md.
    """

    name = "decentralized"
    schedule_summary = "none (gossip consensus)"
    sim_engine = None      # on-device only (api.build + the example)

    def __init__(self, model: Model, rc: RunConfig):
        _require_fixed_delay(rc, self.name,
                             "gossip consensus exchanges fresh local "
                             "duals every epoch (no master delay ring "
                             "to jitter)")
        super().__init__(model, rc)
        cc = rc.consensus
        n = cc.n_workers
        # elastic worker set: the host ships the per-epoch active mask
        # as batch["active"]; the gossip stencil renormalizes around
        # dead neighbours and dead workers' state freezes
        self._elastic = rc.elastic.process != "static"
        self.consumes_active_mask = self._elastic
        if self._elastic and cc.compression == "int8":
            raise ValueError(
                "decentralized elastic churn does not compose with "
                "int8 gossip compression: a dead worker cannot "
                "quantize its message or carry error feedback for "
                "rounds it never ran (the telescoping identity would "
                "break); use compression='none' with a non-static "
                "rc.elastic")
        self.Q = consensus.gossip_matrix(cc.topology, n)
        self.lam2 = consensus.lambda2(self.Q)
        self.rounds = cc.rounds if cc.rounds > 0 else consensus.min_rounds(
            cc.delta, n, cc.msg_norm_J, self.lam2)
        params_shapes = jax.eval_shape(lambda k: model.init(k)[0],
                                       jax.random.PRNGKey(0))
        self.layout = arena_mod.make_layout(params_shapes)
        self.gossip_impl = self._resolve_gossip_impl(cc)
        self._mesh = None
        if self.gossip_impl == "shard_map":
            self._mesh = jax.sharding.Mesh(
                np.asarray(jax.devices()[:n]), ("worker",))
        self.init_state, self.train_step = self._build()

    @staticmethod
    def _resolve_gossip_impl(cc) -> str:
        if cc.gossip_impl != "auto":
            return cc.gossip_impl
        # only the literal deployment shape — one local device per
        # worker — auto-selects the shard_map path: its private 1-D
        # worker mesh must own the same device set a surrounding jit
        # lowers for (a pod-mesh dryrun over MORE devices would
        # conflict), and device_count == n_workers is the one case
        # where that holds by construction
        return ("shard_map" if jax.device_count() == cc.n_workers
                else "dense")

    def _gossip_fn(self):
        """The consensus exchange as one closure (m0, residual) ->
        (z_new, residual_new): four variants over
        {dense, shard_map} x {none, int8}. Under "none" the residual
        is donated straight through (aliased, no copy); under "int8"
        each round quantizes/dequantizes through the shared
        error-feedback body in ``core.consensus``, so the dense and
        shard_map executions stay bit-identical on the same inputs."""
        cc = self.rc.consensus
        topology, rounds = cc.topology, self.rounds
        compression = cc.compression
        elastic = self._elastic
        if compression not in consensus.COMPRESSION_MODES:
            raise ValueError(f"unknown gossip compression "
                             f"{compression!r}")
        if self.gossip_impl == "dense":
            if compression == "int8":
                return lambda m0, res: consensus.run_consensus_fold_int8(
                    m0, res, topology, rounds)
            if elastic:
                # the masked fold: dead neighbours contribute identity
                # weight, the stencil renormalizes per receiver
                return lambda m0, res, active: (
                    consensus.run_consensus_fold_masked(
                        m0, topology, rounds, active), res)
            return lambda m0, res: (consensus.run_consensus_fold(
                m0, topology, rounds), res)
        if self.gossip_impl != "shard_map":
            raise ValueError(f"unknown gossip_impl "
                             f"{self.gossip_impl!r}")
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        from repro.dist.sharding import gossip_specs
        msg_spec = gossip_specs().msg

        n = self.rc.consensus.n_workers

        if elastic:
            # the (n,) active mask is replicated to every worker
            # (spec P()): each shard resolves its own per-term source
            # activity from the full mask + its axis index
            def local_masked(x, res, active):
                return consensus.gossip_rounds_shard_masked(
                    x, "worker", topology, n, rounds, active), res

            return shard_map(local_masked, mesh=self._mesh,
                             in_specs=(msg_spec, msg_spec,
                                       PartitionSpec()),
                             out_specs=(msg_spec, msg_spec),
                             check_rep=False)

        def local(x, res):   # x, res: (1, rows, 128) — this worker's
            if compression == "int8":
                return consensus.gossip_rounds_shard_int8(
                    x, res, "worker", topology, n, rounds)
            return consensus.gossip_rounds_shard(
                x, "worker", topology, n, rounds), res

        return shard_map(local, mesh=self._mesh,
                         in_specs=(msg_spec, msg_spec),
                         out_specs=(msg_spec, msg_spec), check_rep=False)

    def _build(self):
        model, rc = self.model, self.rc
        cfg = rc.ambdg
        n = rc.consensus.n_workers
        n_mb = cfg.n_microbatches
        layout = self.layout
        loss_fn = ambdg._loss_with_remat(model, rc)
        gossip = self._gossip_fn()

        def init_state(key) -> DecentralizedState:
            params0, _ = model.init(key)
            # every worker starts at the same point, f32 (dual
            # averaging overwrites w with -alpha z from step 1 on, so
            # params stay f32 exactly like the arena master path)
            stacked = jax.tree.map(
                lambda p: jnp.tile(p.astype(jnp.float32)[None],
                                   (n,) + (1,) * p.ndim), params0)
            return DecentralizedState(
                params=stacked,
                z=jnp.zeros((n, layout.rows, arena_mod.LANES),
                            jnp.float32),
                residual=jnp.zeros((n, layout.rows, arena_mod.LANES),
                                   jnp.float32),
                t=jnp.zeros((), jnp.int32),
                step=jnp.zeros((), jnp.int32))

        def per_worker_grads(params, batch):
            def one_worker(p, chunk):
                n_active = chunk.get("n_active", jnp.int32(n_mb))
                chunk = {k: v for k, v in chunk.items()
                         if k != "n_active"}
                if cfg.anytime_impl == "while_dynamic":
                    return anytime.accumulate_while(
                        loss_fn, p, chunk, n_mb, n_active)
                return anytime.accumulate_scan(loss_fn, p, chunk, n_mb)

            chunked = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                batch)
            g, c, m = jax.vmap(one_worker, in_axes=(0, 0))(params, chunked)
            return g, c, m["loss_sum"]

        elastic = self._elastic
        variable_batch = rc.batch_schedule.schedule != "fixed"

        def messages(state, batch, scale):
            """(m0, per-worker counts, loss sums, flat grads): the
            pre-gossip consensus inputs. The oracle harness reads m0
            through the ``debug_messages`` metrics capture below, so
            what it validates is exactly what this program gossiped.
            ``scale`` is the effective fleet size the Sec.-V messages
            scale by: the static ``n``, or the traced alive count
            under churn (so the alive consensus still targets
            z-bar + sum(g)/b(t) over the workers that exist)."""
            g, b, loss = per_worker_grads(state.params, batch)
            g_flat = arena_mod.flatten_tree(layout, g, leading=1)
            denom = jnp.maximum(jnp.sum(b), 1e-12)
            # m_i^(0) = n * b_i * (z_i + g_i / b_i) / b(t)
            #         = n * (b_i z_i + g_i) / b(t)  (paper Sec. V)
            m0 = (scale * (state.z * b[:, None, None] + g_flat)) / denom
            return m0, b, loss, g_flat

        def train_step(state: DecentralizedState, batch):
            if elastic:
                if "active" not in batch:
                    raise ValueError(
                        "decentralized elastic step needs the per-"
                        "epoch active mask as batch['active'] (the "
                        "host loop / harness ships the (n_workers,) "
                        "0/1 vector the worker process drew)")
                active = jnp.asarray(batch["active"],
                                     jnp.float32).reshape(n)
                batch = {k: v for k, v in batch.items()
                         if k != "active"}
                scale = jnp.sum(active)
            else:
                active, scale = None, n
            b_sched = None
            if variable_batch:
                if "b_sched" not in batch:
                    raise ValueError(
                        f"rc.batch_schedule.schedule="
                        f"{rc.batch_schedule.schedule!r} needs a per-"
                        "step batch['b_sched'] scalar (the host loop "
                        "draws it from core.batch_schedule)")
                b_sched = jnp.asarray(batch["b_sched"], jnp.float32)
                batch = {k: v for k, v in batch.items()
                         if k != "b_sched"}
            m0, b, loss, g_flat = messages(state, batch, scale)
            total_b = jnp.sum(b)
            denom = jnp.maximum(total_b, 1e-12)
            if elastic:
                z_g, res_new = gossip(m0, state.residual, active)
                # dead workers are frozen spectators: their dual (and
                # params, below) carry over bit-identically until the
                # process brings them back
                z_new = jnp.where(active[:, None, None] > 0, z_g,
                                  state.z)
            else:
                z_new, res_new = gossip(m0, state.residual)
            t_next = state.t + 1
            a = da.alpha(t_next.astype(jnp.float32) + 1.0, cfg,
                         b=b_sched)
            w = -a * z_new
            if cfg.proximal == "l2_ball":
                # per-worker projection (each worker owns its prox)
                norms = jnp.sqrt(jnp.sum(jnp.square(w), axis=(1, 2)))
                proj = jnp.minimum(
                    1.0, cfg.radius_C / jnp.maximum(norms, 1e-12))
                w = w * proj[:, None, None]
            params = arena_mod.unflatten_tree(layout, w, cast=False)
            if elastic:
                params = jax.tree.map(
                    lambda new, old: jnp.where(
                        (active > 0).reshape(
                            (n,) + (1,) * (new.ndim - 1)),
                        new, old),
                    params, state.params)
            grad_sum = jnp.sum(g_flat, axis=0)
            metrics = {
                "loss": jnp.sum(loss) / denom,
                "applied_count": total_b,
                "local_count": total_b,
                "grad_norm": (jnp.sqrt(jnp.sum(jnp.square(grad_sum)))
                              / denom),
                "consensus_error": (
                    consensus.consensus_error_masked(
                        z_new.reshape(n, -1), active) if elastic
                    else consensus.consensus_error(
                        z_new.reshape(n, -1))),
                "step": state.step + 1,
            }
            if elastic:
                metrics["active_workers"] = scale
            if rc.consensus.debug_messages:
                # the exact messages this program's gossip consumed:
                # the oracle harness re-applies the dense fold to them
                # (with the same incoming residual under compression,
                # and the same mask under churn)
                metrics["gossip_m0"] = m0
                metrics["gossip_r0"] = state.residual
                if elastic:
                    metrics["gossip_active"] = active
            return DecentralizedState(params=params, z=z_new,
                                      residual=res_new, t=t_next,
                                      step=state.step + 1), metrics

        return init_state, train_step

    def staleness_schedule(self) -> StalenessSchedule:
        return StalenessSchedule(
            "gossip", 0,
            f"fresh local gradients; r={self.rounds} gossip rounds "
            f"(eq. 24: delta={self.rc.consensus.delta}, "
            f"lambda2={self.lam2:.4f}) bound the consensus error")

    @classmethod
    def timeline_model(cls) -> TimelineModel:
        # synchronous epochs like AMB: the gossip exchange rides the
        # round trip T_c between compute epochs
        return TimelineModel(
            scheme=cls.name, event_driven=False,
            epoch_duration=lambda t_p, t_c: t_p + t_c,
            update_time=lambda t, t_p, t_c: t * t_p + (t - 0.5) * t_c,
            n_updates=lambda total, t_p, t_c:
                max(int((total - t_p - 0.5 * t_c) // (t_p + t_c)) + 1, 0))
