"""Elastic-worker processes: time-varying active sets and speed skew.

Exploiting stragglers is the founding premise of the AMB line
(Ferdinand et al., "Anytime MiniBatch: Exploiting Stragglers"), and
AMB-DG's aggregation rule makes worker failure cheap by construction:
a dead worker contributes b_i(t) = 0 and the eq. (5) normalization
stays exact (paper Sec. IV-C). This module is the single source of
seeded churn/straggler/crash scenarios for every layer — the elastic
twin of ``core.delay_process``:

  * the HOST training loop draws one ``(active_mask, speeds)`` pair
    per step and folds it into ``batch["weights"]`` (via
    ``train.fault``), heartbeating ``WorkerHealth`` on the way so
    eviction / elastic re-mesh / readmission run against the same
    seeded sequence;
  * the cluster simulator draws per-epoch masks for the anytime
    engine and epoch-indexed masks for the k-batch arrival heap, so
    golden traces pin the sequences exactly;
  * the decentralized strategy ships the mask to the device step as
    ``batch["active"]`` and renormalizes its gossip stencil around
    dead neighbours.

Every process is seeded (``numpy.random.default_rng``), emits one
boolean ``(n_workers,)`` active mask plus one float64 ``(n_workers,)``
speed vector per epoch, and checkpoints its full state
(``state_dict``/``load_state_dict``) so restarts reproduce the exact
remaining sequence — the same restart-exactness contract the data
pipeline and the delay processes keep.

Four processes (``ElasticConfig.process``):

  static         everyone alive at speed 1.0 — the degenerate case:
                 the host loop and the strategies route it to the
                 pre-existing no-churn path, pinned bit-identical by
                 the regression suites.
  heterogeneous  persistent per-worker speed skew: multipliers drawn
                 once from lognormal(-sigma^2/2, sigma) (mean 1.0),
                 floored at ``speed_min``; all workers stay alive.
  churn          per-worker Gilbert-Elliott up/down chain (the
                 BurstyDelay precedent, one chain per worker):
                 up -> down with p_fail, down -> up with p_recover —
                 geometric dwell times, join/leave membership.
  crash_restart  exponential MTTF/MTTR in epoch units: each worker
                 alternates Exp(mttf)-long lives with Exp(mttr)-long
                 outages (fail-stop and restart), timers redrawn on
                 every transition.
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

import numpy as np

from repro.configs.base import ElasticConfig


def validate_elastic(cfg: ElasticConfig) -> None:
    """Validate ``cfg`` (the ``resolve_bounds`` twin — every consumer
    goes through here via ``make_worker_process``; strategies call it
    at build time so a bad config fails before any step runs)."""
    if cfg.process not in WORKER_PROCESSES:
        raise ValueError(f"unknown elastic worker process "
                         f"{cfg.process!r}; registered: "
                         f"{sorted(WORKER_PROCESSES)}")
    if not 0.0 <= cfg.p_fail <= 1.0 or not 0.0 <= cfg.p_recover <= 1.0:
        raise ValueError("churn transition probabilities must be in "
                         f"[0, 1], got p_fail={cfg.p_fail}, "
                         f"p_recover={cfg.p_recover}")
    if cfg.process == "churn" and cfg.p_fail > 0 and cfg.p_recover == 0:
        raise ValueError("churn with p_recover=0 permanently drains "
                         "the worker set; use crash_restart semantics "
                         "or a nonzero p_recover")
    if cfg.mttf <= 0.0 or cfg.mttr <= 0.0:
        raise ValueError(f"mttf/mttr must be > 0 epochs, got "
                         f"mttf={cfg.mttf}, mttr={cfg.mttr}")
    if cfg.speed_sigma < 0.0:
        raise ValueError(f"speed_sigma must be >= 0, got "
                         f"{cfg.speed_sigma}")
    if not 0.0 < cfg.speed_min <= 1.0:
        raise ValueError(f"speed_min must be in (0, 1], got "
                         f"{cfg.speed_min}")


class WorkerProcess:
    """One seeded per-epoch ``(active_mask, speeds)`` sequence.
    Subclasses implement ``_draw()`` -> (bool (n,), float (n,)); the
    base class owns seeding, sanitization and checkpointable state."""

    name: str = "?"

    def __init__(self, cfg: ElasticConfig, n_workers: int):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        validate_elastic(cfg)
        self.cfg = cfg
        self.n_workers = int(n_workers)
        self._rng = np.random.default_rng(cfg.seed)
        self._t = 0

    def _draw(self) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def step(self) -> Tuple[np.ndarray, np.ndarray]:
        """Draw the next epoch's (active bool (n,), speeds f64 (n,))
        pair (advances the seeded state). Speeds are clipped to >= 0;
        a dead worker's speed is still emitted (the mask governs)."""
        active, speeds = self._draw()
        self._t += 1
        active = np.asarray(active, bool).reshape(self.n_workers)
        speeds = np.maximum(
            np.asarray(speeds, np.float64).reshape(self.n_workers), 0.0)
        return active, speeds

    def sequence(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """The next ``n`` epochs as stacked (n, n_workers) mask/speed
        arrays (advances state)."""
        pairs = [self.step() for _ in range(n)]
        return (np.stack([a for a, _ in pairs]),
                np.stack([s for _, s in pairs]))

    # -- restart exactness -------------------------------------------------
    def state_dict(self) -> Dict:
        return {"rng": self._rng.bit_generator.state, "t": self._t}

    def load_state_dict(self, s: Dict):
        self._rng.bit_generator.state = s["rng"]
        self._t = int(s.get("t", 0))

    def __repr__(self):
        return (f"{type(self).__name__}(n_workers={self.n_workers}, "
                f"seed={self.cfg.seed})")


class StaticWorkers(WorkerProcess):
    """Everyone alive at speed 1.0 — the degenerate process the host
    loop and every strategy route to the exact pre-existing no-churn
    path (regression-pinned bit-identical)."""

    name = "static"

    def _draw(self):
        return (np.ones(self.n_workers, bool),
                np.ones(self.n_workers, np.float64))


class HeterogeneousWorkers(WorkerProcess):
    """Persistent per-worker speed skew: multipliers drawn ONCE from
    lognormal(-sigma^2/2, sigma) (unit mean before the floor), floored
    at ``speed_min`` — the paper's SciNet observation that straggling
    is persistent, as a speed process. All workers stay alive."""

    name = "heterogeneous"

    def __init__(self, cfg: ElasticConfig, n_workers: int):
        super().__init__(cfg, n_workers)
        sig = cfg.speed_sigma
        self._speeds = np.maximum(
            self._rng.lognormal(-0.5 * sig * sig, sig, n_workers)
            if sig > 0 else np.ones(n_workers, np.float64),
            cfg.speed_min)

    def _draw(self):
        return np.ones(self.n_workers, bool), self._speeds.copy()

    def state_dict(self) -> Dict:
        s = super().state_dict()
        # speeds are derivable from the seed, but a restore must not
        # depend on the restoring instance having drawn them the same
        # way — carry them explicitly (restart exactness by value)
        s["speeds"] = self._speeds.tolist()
        return s

    def load_state_dict(self, s: Dict):
        super().load_state_dict(s)
        if "speeds" in s:
            self._speeds = np.asarray(s["speeds"], np.float64)


class ChurnWorkers(WorkerProcess):
    """Join/leave membership: one Gilbert-Elliott up/down chain per
    worker (the BurstyDelay precedent vectorized across the fleet).
    Transitions are drawn BEFORE the emission, so a worker that fails
    at epoch t already contributes b_i(t) = 0. Dwell times are
    geometric: mean uptime 1/p_fail, mean downtime 1/p_recover."""

    name = "churn"

    def __init__(self, cfg: ElasticConfig, n_workers: int):
        super().__init__(cfg, n_workers)
        self._up = np.ones(n_workers, bool)

    def _draw(self):
        u = self._rng.random(self.n_workers)
        fail = self._up & (u < self.cfg.p_fail)
        recover = ~self._up & (u < self.cfg.p_recover)
        self._up = (self._up & ~fail) | recover
        return self._up.copy(), np.ones(self.n_workers, np.float64)

    def state_dict(self) -> Dict:
        s = super().state_dict()
        s["up"] = self._up.tolist()
        return s

    def load_state_dict(self, s: Dict):
        super().load_state_dict(s)
        if "up" in s:
            self._up = np.asarray(s["up"], bool)


class CrashRestartWorkers(WorkerProcess):
    """Fail-stop with restart: each worker alternates Exp(mttf)-long
    lives and Exp(mttr)-long outages (continuous-time two-state
    renewal process sampled on the epoch grid). Per-worker countdown
    timers are redrawn on every transition; ceil to >= 1 epoch so a
    transition is always observable."""

    name = "crash_restart"

    def __init__(self, cfg: ElasticConfig, n_workers: int):
        super().__init__(cfg, n_workers)
        self._up = np.ones(n_workers, bool)
        self._timer = self._draw_timers(self._up)

    def _draw_timers(self, up: np.ndarray) -> np.ndarray:
        mean = np.where(up, self.cfg.mttf, self.cfg.mttr)
        return np.maximum(
            np.ceil(self._rng.exponential(mean)).astype(np.int64), 1)

    def _draw(self):
        self._timer -= 1
        expired = self._timer <= 0
        if expired.any():
            self._up = self._up ^ expired
            fresh = self._draw_timers(self._up)
            self._timer = np.where(expired, fresh, self._timer)
        return self._up.copy(), np.ones(self.n_workers, np.float64)

    def state_dict(self) -> Dict:
        s = super().state_dict()
        s["up"] = self._up.tolist()
        s["timer"] = self._timer.tolist()
        return s

    def load_state_dict(self, s: Dict):
        super().load_state_dict(s)
        if "up" in s:
            self._up = np.asarray(s["up"], bool)
        if "timer" in s:
            self._timer = np.asarray(s["timer"], np.int64)


WORKER_PROCESSES: Dict[str, Type[WorkerProcess]] = {
    c.name: c for c in (StaticWorkers, HeterogeneousWorkers,
                        ChurnWorkers, CrashRestartWorkers)}


def make_worker_process(cfg: ElasticConfig, n_workers: int
                        ) -> WorkerProcess:
    """Construct the process named by ``cfg.process`` (validates the
    config — every consumer goes through here)."""
    validate_elastic(cfg)         # raise early with the full message
    return WORKER_PROCESSES[cfg.process](cfg, n_workers)
