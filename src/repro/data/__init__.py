from repro.data.pipeline import AnytimePipeline  # noqa: F401
from repro.data.synthetic import (ImageClassStream, LinRegStream,  # noqa: F401
                                  TokenStream, make_stream)
from repro.data.timing import ShiftedExponential  # noqa: F401
