"""Host-side data pipeline: per-shard iterators with anytime masking.

The pipeline owns the *anytime* decision: given per-worker minibatch
sizes b_i(t) (from a real timer on hardware, or the shifted-exponential
model in simulation/CI), it emits a fixed-shape global batch whose
per-sample ``weights`` zero out the samples slower workers did not
finish — the device program stays static while the effective minibatch
varies exactly like the paper's b(t).

Checkpointable: ``state_dict``/``load_state_dict`` round-trips the
stream cursor so restarts are sample-exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import make_stream
from repro.data.timing import ShiftedExponential


@dataclass
class AnytimePipeline:
    cfg: ModelConfig
    n_workers: int
    samples_per_worker: int          # max samples a worker may contribute
    seq_len: int = 0
    seed: int = 0
    timing: Optional[ShiftedExponential] = None
    t_p: float = 2.5

    def __post_init__(self):
        self.stream = make_stream(self.cfg, self.seed)
        self._rng = np.random.default_rng(self.seed + 17)
        self.b_history = []

    def _draw_b(self) -> np.ndarray:
        """Per-worker completed sample counts for this epoch."""
        if self.timing is None:
            return np.full((self.n_workers,), self.samples_per_worker,
                           np.int64)
        b = self.timing.minibatch_in(self._rng, self.n_workers, self.t_p)
        return np.minimum(b, self.samples_per_worker)

    def next_global_batch(self) -> Dict[str, np.ndarray]:
        """Fixed-shape (n_workers * samples_per_worker, ...) batch with
        anytime weights. Worker i's samples occupy the contiguous slice
        [i*spw, (i+1)*spw); the first b_i(t) carry weight 1."""
        total = self.n_workers * self.samples_per_worker
        if self.seq_len:
            batch = self.stream.next_batch(total, self.seq_len)
        else:
            batch = self.stream.next_batch(total)
        b = self._draw_b()
        self.b_history.append(b.copy())
        w = np.zeros((self.n_workers, self.samples_per_worker), np.float32)
        for i, bi in enumerate(b):
            w[i, :bi] = 1.0
        batch["weights"] = w.reshape(-1)
        return batch

    # -- fault tolerance hooks -------------------------------------------
    def mark_failed(self, worker: int):
        """A failed worker contributes b_i = 0 until it recovers — the
        aggregation rule stays correct (paper Sec. IV-C)."""
        self._failed = getattr(self, "_failed", set())
        self._failed.add(worker)

    def state_dict(self):
        return {"stream": self.stream.state_dict(),
                "rng": self._rng.bit_generator.state}

    def load_state_dict(self, s):
        self.stream.load_state_dict(s["stream"])
        self._rng.bit_generator.state = s["rng"]


def apply_batch_target(weights: np.ndarray, b_target: int,
                       n_workers: int,
                       samples_per_worker: int) -> np.ndarray:
    """Cap the anytime weights at a batch schedule's global target
    b(t): the target splits evenly across workers (remainder to the
    lowest ranks) and worker i keeps the first min(b_i, share_i) of
    its drawn samples. The anytime semantic is preserved — a worker
    can never contribute samples it did not finish — while the
    schedule bounds the total the step aggregates (alpha meanwhile
    assumes the schedule's EXPECTED b(t), shipped separately as
    ``batch["b_sched"]``)."""
    w = np.asarray(weights, np.float32).reshape(
        n_workers, samples_per_worker)
    share, rem = divmod(int(b_target), n_workers)
    out = np.zeros_like(w)
    for i in range(n_workers):
        cap = min(share + (1 if i < rem else 0), samples_per_worker)
        drawn = int(round(float(np.count_nonzero(w[i]))))
        keep = min(drawn, cap)
        if keep > 0:
            out[i, :keep] = w[i, :keep]
    return out.reshape(-1)
