"""Synthetic data streams for the paper's experiments and the LM configs.

All streams are deterministic functions of (seed, cursor) so the data
pipeline can checkpoint/restore exactly (fault tolerance: a restarted
job resumes the stream at the same position).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, VLM, ENCDEC


@dataclass
class LinRegStream:
    """Paper Sec. VI-A: zeta ~ N(0,I_d); y = zeta^T w* + eps,
    eps ~ N(0, 1e-3). ``seed`` fixes the problem (w*); ``sample_seed``
    fixes the stream, so parallel workers share one problem but draw
    i.i.d. disjoint samples."""
    dim: int
    seed: int = 0
    sample_seed: Optional[int] = None
    noise_var: float = 1e-3

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.w_star = rng.standard_normal(self.dim).astype(np.float32)
        if self.sample_seed is None:
            self.sample_seed = self.seed
        self._cursor = 0

    def next_batch(self, n: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.sample_seed + 1, self._cursor))
        self._cursor += 1
        x = rng.standard_normal((n, self.dim)).astype(np.float32)
        noise = (self.noise_var ** 0.5) * rng.standard_normal(n)
        y = (x @ self.w_star + noise).astype(np.float32)
        return {"x": x, "y": y,
                "weights": np.ones((n,), np.float32)}

    def eval_matrix(self, n_rows: int, seed: int = 123) -> np.ndarray:
        """The paper's error-rate matrix A (N x d), eq. (28)."""
        rng = np.random.default_rng(seed)
        return rng.standard_normal((n_rows, self.dim)).astype(np.float32)

    def state_dict(self):
        return {"cursor": self._cursor}

    def load_state_dict(self, s):
        self._cursor = int(s["cursor"])


@dataclass
class ImageClassStream:
    """Synthetic stand-in for CIFAR-10 (Sec. VI-B): class-conditional
    Gaussian blobs so training actually learns something measurable.
    ``seed`` fixes the class prototypes; ``sample_seed`` the stream."""
    image_size: int = 32
    n_classes: int = 10
    seed: int = 0
    sample_seed: Optional[int] = None

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.prototypes = rng.standard_normal(
            (self.n_classes, self.image_size, self.image_size, 3)
        ).astype(np.float32)
        if self.sample_seed is None:
            self.sample_seed = self.seed
        self._cursor = 0

    def next_batch(self, n: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.sample_seed + 1, self._cursor))
        self._cursor += 1
        labels = rng.integers(0, self.n_classes, size=n)
        noise = rng.standard_normal(
            (n, self.image_size, self.image_size, 3)).astype(np.float32)
        images = 0.5 * self.prototypes[labels] + noise
        return {"images": images, "labels": labels.astype(np.int32),
                "weights": np.ones((n,), np.float32)}

    def state_dict(self):
        return {"cursor": self._cursor}

    def load_state_dict(self, s):
        self._cursor = int(s["cursor"])


@dataclass
class TokenStream:
    """Synthetic LM token stream (Zipf-ish marginal so the loss has
    structure). Supports the VLM/encdec extras per ``ModelConfig``."""
    cfg: ModelConfig
    seed: int = 0
    sample_seed: Optional[int] = None

    def __post_init__(self):
        if self.sample_seed is None:
            self.sample_seed = self.seed
        self._cursor = 0

    def next_batch(self, batch: int, seq: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.sample_seed + 1, self._cursor))
        self._cursor += 1
        cfg = self.cfg
        n_text = seq - cfg.n_frontend_tokens if cfg.family == VLM else seq
        # Zipf over the vocab, clipped
        z = rng.zipf(1.3, size=(batch, n_text))
        tokens = np.minimum(z, cfg.vocab_size - 1).astype(np.int32)
        out = {"tokens": tokens,
               "weights": np.ones((batch,), np.float32)}
        if cfg.family == VLM:
            out["patches"] = rng.standard_normal(
                (batch, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32)
        if cfg.family == ENCDEC:
            out["frames"] = rng.standard_normal(
                (batch, seq, cfg.d_model)).astype(np.float32)
        return out

    def state_dict(self):
        return {"cursor": self._cursor}

    def load_state_dict(self, s):
        self._cursor = int(s["cursor"])


def make_stream(cfg: ModelConfig, seed: int = 0,
                sample_seed: Optional[int] = None):
    from repro.configs.base import LINREG, CNN
    if cfg.family == LINREG:
        return LinRegStream(cfg.linreg_dim, seed, sample_seed)
    if cfg.family == CNN:
        return ImageClassStream(cfg.image_size, cfg.n_classes, seed,
                                sample_seed)
    return TokenStream(cfg, seed, sample_seed)
