"""Worker compute-time models (paper Sec. VI-A.3).

The paper models the time for a worker to produce ``b`` gradients as a
shifted exponential: f(tau) = lambda * exp(-lambda (tau - xi)), tau >= xi,
with linear progress within an epoch — so in a fixed window T_p worker i
completes b_i(t) = b * T_p / T_i(t) gradients. Paper constants:
lambda = 2/3, xi = 1, b = 60, T_p = 2.5, n = 10 => E[b(t)] >= 600.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ShiftedExponential:
    lam: float = 2.0 / 3.0
    xi: float = 1.0
    b: int = 60          # reference minibatch the time is quoted for

    def sample_times(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """T_i: time to compute ``b`` gradients, one draw per worker."""
        return self.xi + rng.exponential(1.0 / self.lam, size=n)

    def minibatch_in(self, rng: np.random.Generator, n: int,
                     t_p: float) -> np.ndarray:
        """b_i(t) for an epoch of length t_p (linear-progress model)."""
        times = self.sample_times(rng, n)
        return np.maximum((self.b * t_p / times).astype(np.int64), 0)

    def time_for(self, rng: np.random.Generator, n: int,
                 k: int) -> np.ndarray:
        """Time for each of n workers to compute exactly k gradients
        (K-batch async needs this): k * T_i / b."""
        times = self.sample_times(rng, n)
        return k * times / self.b

    @property
    def mean_minibatch_rate(self) -> float:
        """E[b_i per unit time] ~ b * E[1/T]; used for b_bar estimates."""
        # E[1/T] for shifted exponential has no closed form; Monte-Carlo
        rng = np.random.default_rng(0)
        t = self.sample_times(rng, 200_000)
        return float(self.b * np.mean(1.0 / t))


@dataclass
class PersistentWorkerSpeeds:
    """Heterogeneous-cluster variant: each worker's speed T_i is drawn
    ONCE and persists (the paper's SciNet workers show persistent
    straggling — this reproduces Fig. 4's heavier staleness tail,
    because a permanently slow worker's messages are always stale)."""
    base: ShiftedExponential
    n_workers: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._times = self.base.sample_times(rng, self.n_workers)

    @property
    def b(self) -> int:
        return self.base.b

    def sample_times(self, rng, n: int) -> np.ndarray:
        assert n <= self.n_workers
        return self._times[:n]

    def minibatch_in(self, rng, n: int, t_p: float) -> np.ndarray:
        return np.maximum(
            (self.base.b * t_p / self.sample_times(rng, n)).astype(np.int64),
            0)

    def time_for(self, rng, n: int, k: int) -> np.ndarray:
        # A partial call (n < n_workers) would silently return workers
        # 0..n-1's persistent times regardless of WHICH worker is
        # asking — the worker-identity loss that once made every
        # k-batch job run at worker 0's speed. The per-worker question
        # has a per-worker answer: ``per_worker_time(worker, k)``
        # (``simulate_kbatch`` routes through it automatically).
        if n != self.n_workers:
            raise ValueError(
                f"PersistentWorkerSpeeds.time_for is fleet-wide "
                f"(n_workers={self.n_workers}, got n={n}); a partial "
                f"call loses the worker identity — use "
                f"per_worker_time(worker, k) for one worker's time")
        return k * self._times / self.base.b

    def per_worker_time(self, worker: int, k: int) -> float:
        return float(k * self._times[worker] / self.base.b)
