"""Distribution layer: logical-axes sharding resolution and the spec
builders the launcher/dry-run uses to jit with full production
shardings.

    spec_for(axes, shape, mesh)     logical axes -> PartitionSpec
    shapes_and_axes(init_fn, *a)    abstract-eval an (arrays, axes) init
    batch_specs(model, rc)          specs for the global batch pytree
    state_specs(model, rc, init)    specs for the whole TrainState
    to_shardings(specs, mesh)       PartitionSpec tree -> NamedSharding
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (_is_axes_leaf, shapes_and_axes,  # noqa: F401
                                 spec_for)

__all__ = ["batch_specs", "shapes_and_axes", "spec_for",
           "specs_for_state", "state_specs", "to_shardings"]


def batch_specs(model, rc):
    """Specs for the global batch: dim 0 is the batch dim (sharded over
    ('pod','data')), everything else replicated; scalars -> P()."""
    shapes = model.input_specs(rc.shape.global_batch, rc.shape.seq_len)
    return jax.tree.map(
        lambda sh: spec_for(
            (("batch",) + (None,) * (len(sh.shape) - 1)) if sh.shape else (),
            tuple(sh.shape), rc.mesh),
        shapes)


def state_specs(model, rc, init_state):
    """Specs for the full train state produced by ``init_state``. For
    the shared-master pipeline's TrainState:

      params      by their logical axes from ``model.init``
      opt_state   subtrees structurally matching params reuse the param
                  axes (dual z / momenta mirror params); (rows, 128)
                  leaves are arena buffers -> rows over the intra-pod
                  slice; scalars replicated
      buffer      pytree delay buffer via ``delayed.buffer_logical_axes``
      arena       flat delay ring via ``arena.arena_logical_axes``

    Strategy states wrap or replace TrainState and resolve through the
    same machinery: a wrapper with a ``base`` field (KBatchState)
    recurses into it with extra scalars replicated; the decentralized
    state's per-worker stacked leaves prepend a replicated worker dim
    (the worker axis lives on the strategy's own 1-D gossip mesh, not
    the pod mesh).
    """
    state_shapes = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    return specs_for_state(model, rc, state_shapes)


def specs_for_state(model, rc, state_shapes):
    """``state_specs`` on an already-abstract state tree."""
    from repro.core import arena as arena_mod
    from repro.core import delayed
    from repro.core.strategy import DecentralizedState, KBatchState

    if isinstance(state_shapes, KBatchState):
        return KBatchState(
            base=specs_for_state(model, rc, state_shapes.base),
            ref_epoch=P())

    _, params_axes = shapes_and_axes(model.init, jax.random.PRNGKey(0))

    if isinstance(state_shapes, DecentralizedState):
        p_specs = jax.tree.map(
            lambda ax, sh: spec_for((None,) + tuple(ax),
                                    tuple(sh.shape), rc.mesh),
            params_axes, state_shapes.params, is_leaf=_is_axes_leaf)
        return DecentralizedState(
            params=p_specs,
            z=spec_for((None, "flat", None),
                       tuple(state_shapes.z.shape), rc.mesh),
            residual=spec_for((None, "flat", None),
                              tuple(state_shapes.residual.shape),
                              rc.mesh),
            t=P(), step=P())

    def resolve(ax, sh):
        return spec_for(tuple(ax), tuple(sh.shape), rc.mesh)

    def resolve_tree(axes_tree, shapes_tree):
        return jax.tree.map(resolve, axes_tree, shapes_tree,
                            is_leaf=_is_axes_leaf)

    p_specs = resolve_tree(params_axes, state_shapes.params)
    params_structure = jax.tree.structure(state_shapes.params)

    def opt_specs(node):
        if isinstance(node, jax.ShapeDtypeStruct):
            if node.ndim == 2 and node.shape[-1] == 128:  # arena row buffer
                return resolve(("flat", None), node)
            return P()
        if jax.tree.structure(node) == params_structure:
            return resolve_tree(params_axes, node)
        return jax.tree.map(opt_specs, node, is_leaf=lambda c: c is not node)

    fields = {
        "params": p_specs,
        "opt_state": opt_specs(state_shapes.opt_state),
        "step": P(),
    }
    buffer_shapes = getattr(state_shapes, "buffer", None)
    if buffer_shapes is not None:
        buf_axes = delayed.buffer_logical_axes(
            params_axes, rc.ambdg.tau, rc.ambdg.pod_compression)
        fields["buffer"] = resolve_tree(buf_axes, buffer_shapes)
    arena_shapes = getattr(state_shapes, "arena", None)
    if arena_shapes is not None:
        fields["arena"] = resolve_tree(
            arena_mod.arena_logical_axes(arena_shapes), arena_shapes)
    return type(state_shapes)(**{
        f: fields.get(f) for f in state_shapes._fields})


def to_shardings(specs, mesh):
    """Map a PartitionSpec tree onto NamedShardings for one mesh."""
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def retree_specs(specs, target):
    """Rebuild a spec tree onto ``target``'s (possibly different)
    structure — same array leaves, different static pytree metadata.

    Needed for jitted out_shardings of the train step: the arena's
    slot-schedule ``phase`` is static aux data that ADVANCES each step,
    so the output TrainState's structure differs from the input's in
    metadata only, and the input-derived spec tree would be rejected
    as an out_shardings prefix. Array-leaf count and order are
    identical, so the specs transplant 1:1."""
    leaves = jax.tree.flatten(specs,
                              is_leaf=lambda x: isinstance(x, P))[0]
    return jax.tree.unflatten(jax.tree.structure(target), leaves)
