"""Ambient sharding profile: lets model code pin intermediate
activations to logical axes without threading a mesh through every
call.

    with sharding_profile(rc.mesh):            # train profile
        ...
    with sharding_profile(rc.mesh, "serve"):   # serve profile
        ...

``constrain(x, axes)`` resolves the logical axes against the active
profile and applies ``with_sharding_constraint``; with no active
profile (unit tests, single-device runs — ``sharding_profile(None)``
also counts) it is the identity, so model code can call it
unconditionally.
"""
from __future__ import annotations

import contextlib
import threading

import jax

from repro.dist.sharding import spec_for

_state = threading.local()


def _active():
    stack = getattr(_state, "stack", None)
    return stack[-1] if stack else None


def active_mesh():
    """The MeshConfig of the active sharding profile, or None. Lets
    numeric code pick mesh-aware lowerings (e.g. a single pod-axis
    reduce -> DCN all-reduce) only when actually lowering for a mesh."""
    active = _active()
    return active[0] if active is not None else None


def active_physical_mesh():
    """The ambient physical ``jax.sharding.Mesh`` (set by ``with
    mesh:``), or None. The shard_map wrapper around the delay-ring
    kernel needs the actual mesh object — a MeshConfig names the axes
    but owns no devices; without an ambient mesh the wrapper cannot
    lower and the caller falls back to the XLA ref path."""
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


@contextlib.contextmanager
def sharding_profile(mesh_cfg, profile: str = "train"):
    """Activate (mesh, profile) for constrain(); ``mesh_cfg=None``
    deactivates (constrain becomes the identity inside the block)."""
    stack = getattr(_state, "stack", None)
    if stack is None:
        stack = _state.stack = []
    stack.append(None if mesh_cfg is None else (mesh_cfg, profile))
    try:
        yield
    finally:
        stack.pop()


def constrain(x, axes):
    """Pin ``x`` to the sharding its logical ``axes`` resolve to under
    the active profile (identity when none is active)."""
    active = _active()
    if active is None:
        return x
    mesh_cfg, profile = active
    spec = spec_for(tuple(axes), tuple(x.shape), mesh_cfg, profile=profile)
    return jax.lax.with_sharding_constraint(x, spec)
