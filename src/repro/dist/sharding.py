"""Logical-axes -> mesh-axes sharding resolver.

Models annotate every parameter dimension with a *logical* axis name
(see ``repro.models.common``); this module maps those names onto the
physical mesh axes of a ``MeshConfig``. Resolution is greedy
left-to-right over the dimensions of one array:

  * each logical axis has an ordered preference list of mesh axes (or
    axis *tuples*, sharded over their product);
  * a candidate is taken only if every mesh axis in it exists on this
    mesh, none of them is already used by an earlier dimension of the
    same array, and the dimension size divides the candidate's total
    device count — otherwise the next preference is tried, falling back
    to replication (None);
  * trailing Nones are trimmed so specs compare equal to their
    PartitionSpec literals.

Two profiles: "train" (FSDP weights: embed on 'data', TP dims on
'model', batch over ('pod','data')) and "serve" (weights gathered:
embed replicated, TP dims over the whole ('data','model') slice).
The "flat" axis names the row dimension of the gradient arena
(``repro.core.arena``) — a contiguous flattened-parameter buffer whose
rows shard over the entire intra-pod slice.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig

# preference lists: logical axis -> candidates, each a mesh axis name or
# a tuple of names (sharded over the product)
_TRAIN_PREFS = {
    "batch": (("pod", "data"),),
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "vocab": ("model",),
    "kv_seq": ("data", "model"),
    "seq_sp": ("model",),
    "pod": ("pod",),
    "flat": (("data", "model"), "data"),
}

_SERVE_PREFS = {
    "batch": ("data",),
    "embed": (),                       # weights gathered at use
    "mlp": (("data", "model"), "model"),
    "heads": (("data", "model"), "model"),
    "vocab": (("data", "model"), "model"),
    "kv_seq": (),
    "seq_sp": (),
    "pod": ("pod",),
    "flat": (("data", "model"), "data"),
}

_PROFILES = {"train": _TRAIN_PREFS, "serve": _SERVE_PREFS}


def _is_axes_leaf(x) -> bool:
    """A logical-axes annotation: tuple of axis names / Nones. The
    single definition shared by every tree.map over (axes, arrays)."""
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             mesh: MeshConfig, profile: str = "train") -> P:
    """Resolve one array's logical axes to a PartitionSpec."""
    assert len(axes) == len(shape), (axes, shape)
    prefs = _PROFILES[profile]
    sizes = {"data": mesh.data, "model": mesh.model}
    if mesh.n_pods > 1:
        sizes["pod"] = mesh.n_pods
    used = set()
    entries = []
    for name, dim in zip(axes, shape):
        choice = None
        for cand in prefs.get(name, ()):
            cand = cand if isinstance(cand, tuple) else (cand,)
            cand = tuple(a for a in cand if a in sizes)
            if not cand or any(a in used for a in cand):
                continue
            if dim % math.prod(sizes[a] for a in cand) != 0:
                continue
            choice = cand
            break
        if choice:
            used.update(choice)
            entries.append(choice[0] if len(choice) == 1 else choice)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def arena_slot_specs(mesh: MeshConfig, rows: int,
                     profile: str = "train") -> Tuple[P, P, P]:
    """PartitionSpecs for one v2 delay-ring slot and its per-step
    operands — the single source of truth shared by GSPMD state specs
    (via ``arena.arena_logical_axes``), the shard_map wrapper around
    the delay-ring kernel, and the kernel tests:

      slot_spec    (n_pods, rows, 128) buffers: ring slots, residual,
                   staging, the pod-stacked gradient/fed payload
      scales_spec  (n_pods, rows) per-row int8 scales
      row_spec     (rows, 128) pod-reduced row buffers (popped grad, z)
    """
    slot_spec = spec_for(("pod", "flat", None), (mesh.n_pods, rows, 128),
                         mesh, profile=profile)
    scales_spec = spec_for(("pod", "flat"), (mesh.n_pods, rows),
                           mesh, profile=profile)
    row_spec = spec_for(("flat", None), (rows, 128), mesh, profile=profile)
    return slot_spec, scales_spec, row_spec


def arena_ring_specs(mesh: MeshConfig, rows: int,
                     profile: str = "train") -> Tuple[P, P, P]:
    """PartitionSpecs for the STACKED (layout v3) delay-tolerant ring —
    the variable-delay analogue of ``arena_slot_specs``, shared by the
    GSPMD state specs, the ``ring_variable_pop_sharded`` shard_map
    wrapper, and the kernel tests:

      ring_spec    (n_slots, n_pods, rows, 128) stacked ring: the slot
                   dimension is metadata-indexed, never sharded
      scales_spec  (n_slots, n_pods, rows) stacked per-row int8 scales
      row_spec     (rows, 128) pod-reduced popped row buffer
    """
    ring_spec = spec_for((None, "pod", "flat", None),
                         (1, mesh.n_pods, rows, 128), mesh,
                         profile=profile)
    scales_spec = spec_for((None, "pod", "flat"), (1, mesh.n_pods, rows),
                           mesh, profile=profile)
    row_spec = spec_for(("flat", None), (rows, 128), mesh, profile=profile)
    return ring_spec, scales_spec, row_spec


def publish_ring_specs(mesh: MeshConfig, rows: int,
                       profile: str = "serve") -> Tuple[P, P]:
    """PartitionSpecs for the weight-publication ring
    (``serve.publisher.WeightPublisher``) — the serving analogue of
    ``arena_ring_specs``, without the pod dimension (the channel is
    master -> servers, not per-pod):

      ring_spec    (n_slots, rows, 128) int8 snapshot ring: slot dim
                   metadata-indexed, never sharded; rows over the
                   serve slice
      scales_spec  (n_slots, rows) per-row bf16 dequantization scales
    """
    ring_spec = spec_for((None, "flat", None), (1, rows, 128), mesh,
                         profile=profile)
    scales_spec = spec_for((None, "flat"), (1, rows), mesh,
                           profile=profile)
    return ring_spec, scales_spec


class GossipSpecs(NamedTuple):
    """PartitionSpecs for the decentralized gossip state under the 1-D
    ``('worker',)`` mesh the ``DecentralizedStrategy`` builds (one mesh
    index = one worker — shared by its shard_map wrapper and the
    conformance tests):

      msg      (n_workers, rows, 128) per-worker dual/message buffers —
               and the int8 wire payload and the error-feedback
               residual, which share the shape: worker dim sharded,
               whole rows local (the gossip exchanges entire per-worker
               messages, so the arena rows never split across the
               worker axis)
      scales   (n_workers, rows) per-row bf16 dequantization scales of
               the compressed payload (carried as u16 bits on the
               wire). The strategy's own wrapper never needs it — the
               scales live and die inside the shard_map body — but
               test/benchmark harnesses that stack the compressed wire
               state across workers do.
      scalar   (n_workers,) per-worker scalars (anytime counts, prox
               norms)
    """
    msg: P
    scales: P
    scalar: P


def gossip_specs() -> GossipSpecs:
    return GossipSpecs(msg=P("worker", None, None),
                       scales=P("worker", None),
                       scalar=P("worker"))


def shapes_and_axes(init_fn, *args):
    """Abstractly evaluate an ``init_fn(*args) -> (arrays, axes)`` pair
    (e.g. ``model.init`` / ``model.init_decode_state``): returns
    (ShapeDtypeStruct tree, logical-axes tree) without allocating."""
    box = {}

    def arrays_only(*a):
        arrays, axes = init_fn(*a)
        box["axes"] = axes
        return arrays

    shapes = jax.eval_shape(arrays_only, *args)
    return shapes, box["axes"]
