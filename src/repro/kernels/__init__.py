"""Pallas TPU kernels for the framework's compute hot-spots.

The paper's contribution is an optimizer/communication scheme (no kernel
of its own), but the framework's hot loops get TPU-native kernels:

  flash_attention/  blocked causal/SWA attention fwd + custom-vjp bwd
                    (dq + group-summed dkv kernels, lse recomputation —
                    no S^2 residuals; MXU 128-tiles)
  linear_scan/      chunked SSD / gated-linear-attention scan
                    (Mamba2 + mLSTM inner loop)
  dual_update/      fused dual-averaging update z += g; w = -alpha z
                    (the paper's eq. (3)-(4) hot loop, memory-bound)

Each kernel directory: kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper with an interpret fallback for CPU), ref.py
(pure-jnp oracle used by the allclose tests).
"""
