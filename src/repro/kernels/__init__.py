"""Pallas TPU kernels for the framework's compute hot-spots.

The paper's contribution is an optimizer/communication scheme (no kernel
of its own), but the framework's hot loops get TPU-native kernels:

  flash_attention/  blocked causal/SWA attention fwd + custom-vjp bwd
                    (dq + group-summed dkv kernels, lse recomputation —
                    no S^2 residuals; MXU 128-tiles)
  linear_scan/      chunked SSD / gated-linear-attention scan
                    (Mamba2 + mLSTM inner loop)
  dual_update/      fused dual-averaging update z += g; w = -alpha z
                    (the paper's eq. (3)-(4) hot loop, memory-bound);
                    the arena entry point also folds in the anytime
                    count-normalization g/count
  delay_ring/       fused delay-ring rotation on the flat gradient
                    arena: pop-oldest + push-new + int8 quantize/
                    dequantize with error feedback, one pass over the
                    slot (scalar-prefetched head; ring donated)

Each kernel directory: kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper with an interpret fallback for CPU), ref.py
(pure-jnp oracle used by the allclose tests).
"""
from __future__ import annotations


def resolve_impl(impl: str = "auto") -> str:
    """Shared impl dispatch for the arena kernels (delay_ring,
    dual_update): "auto" resolves to Pallas only on a single-pod TPU —
    a bare pallas_call on a pod-sharded arena buffer would make GSPMD
    gather the whole buffer per device (shard_map wrapper is a ROADMAP
    open item) — and to the pure-XLA reference everywhere else."""
    if impl != "auto":
        return impl
    import jax

    from repro.dist.context import active_mesh
    mesh = active_mesh()
    multi_pod = mesh is not None and mesh.n_pods > 1
    return ("pallas" if jax.default_backend() == "tpu" and not multi_pod
            else "ref")
