"""Pallas TPU kernels for the framework's compute hot-spots.

The paper's contribution is an optimizer/communication scheme (no kernel
of its own), but the framework's hot loops get TPU-native kernels:

  flash_attention/  blocked causal/SWA attention fwd + custom-vjp bwd
                    (dq + group-summed dkv kernels, lse recomputation —
                    no S^2 residuals; MXU 128-tiles)
  linear_scan/      chunked SSD / gated-linear-attention scan
                    (Mamba2 + mLSTM inner loop)
  dual_update/      fused dual-averaging update z += g; w = -alpha z
                    (the paper's eq. (3)-(4) hot loop, memory-bound);
                    the arena entry point also folds in the anytime
                    count-normalization g/count
  delay_ring/       fused delay-ring rotation on the flat gradient
                    arena: pop-oldest + push-new + int8 quantize/
                    dequantize with error feedback, one pass over the
                    slot (ring donated; v2 per-slot layout selects the
                    slot statically, v1 scalar-prefetches the head)

Each kernel directory: kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper with an interpret fallback for CPU), ref.py
(pure-jnp oracle used by the allclose tests).
"""
from __future__ import annotations

import math


def dim_shard(entry, mesh) -> int:
    """Devices a PartitionSpec entry shards one dimension over."""
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    return math.prod(int(mesh.shape[n]) for n in names)


def fit_block_rows(rows: int, want: int) -> int:
    """Largest block <= ``want`` dividing ``rows`` (gcd keeps it a
    multiple of 8 whenever rows is, which the arena layout guarantees
    down to any power-of-two device count)."""
    return math.gcd(rows, want)


def resolve_impl(impl: str = "auto", *, pod_shard_map: bool = False) -> str:
    """Shared impl dispatch for the arena kernels (delay_ring,
    dual_update): "auto" resolves to Pallas on TPU and to the pure-XLA
    reference everywhere else.

    Multi-pod meshes: a bare pallas_call on a pod-sharded arena buffer
    would make GSPMD gather the whole buffer per device, so "auto"
    resolves to "ref" — UNLESS the caller has a shard_map wrapper
    (``pod_shard_map=True``: the v2 delay ring and the dual_update
    arena entry point) and an ambient physical mesh is available to
    shard_map over, in which case it resolves to "pallas_sharded" and
    the fused kernel runs per shard."""
    if impl != "auto":
        return impl
    import jax

    from repro.dist.context import active_mesh, active_physical_mesh
    mesh = active_mesh()
    multi_pod = mesh is not None and mesh.n_pods > 1
    if jax.default_backend() != "tpu":
        return "ref"
    if not multi_pod:
        return "pallas"
    if pod_shard_map and active_physical_mesh() is not None:
        return "pallas_sharded"
    return "ref"
