"""Fused delay-ring step, Pallas TPU.

One pass over ONE ring slot (indexed by a scalar-prefetched head): pop
the tau-old entry, dequantize it, quantize the incoming gradient with
error feedback, and overwrite the slot — where the pytree path lowers
to hundreds of per-leaf dynamic-update-slice kernels plus separate
elementwise chains, this is a single kernel launch whose grid touches
exactly ``n_pods * rows/block`` blocks of the slot being rotated.

The ring, scales, and residual are donated (input_output_aliases), so
the untouched tau-1 slots are never copied: blocks outside the grid
simply keep their (aliased) contents.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANES = 128


def _ring_kernel_f32(head_ref, ring_ref, g_ref, popped_ref, ring_out_ref):
    del head_ref  # consumed by the index maps
    popped_ref[...] = ring_ref[0].astype(jnp.float32)
    ring_out_ref[...] = g_ref[...][None]


def _ring_kernel_int8(head_ref, ring_ref, scales_ref, fed_ref,
                      scale_new_ref, popped_ref, ring_out_ref,
                      scales_out_ref, residual_out_ref):
    # fed = g + residual is formed by the caller (the scale pass needs
    # it anyway); re-adding it here would cost an extra HBM read of
    # the residual per step. residual_out aliases fed's buffer.
    del head_ref
    q_old = ring_ref[0].astype(jnp.float32)            # (1, B, 128)
    s_old = scales_ref[0][..., None]                   # (1, B, 1)
    popped_ref[...] = q_old * s_old
    fed = fed_ref[...]
    s = scale_new_ref[...][..., None]                  # (1, B, 1)
    q = jnp.clip(jnp.round(fed / s), -127, 127)
    ring_out_ref[...] = q[None].astype(jnp.int8)
    scales_out_ref[...] = scale_new_ref[...][None]
    residual_out_ref[...] = fed - q * s


def delay_ring_fwd(ring, g, head, scales=None, scale_new=None, *,
                   block_rows: int = 256, interpret: bool = False):
    """ring: (tau, n_pods, rows, 128); g: (n_pods, rows, 128) f32 —
    under int8 (``scales`` is not None) ``g`` is the error-fed
    gradient fed = g + residual, and the new residual is written into
    its (donated) buffer. head: () or (1,) i32.
    Returns (popped f32, ring_new, scales_new, residual_new)."""
    tau, n_pods, rows, lanes = ring.shape
    assert lanes == _LANES and rows % block_rows == 0, (ring.shape,)
    head = jnp.asarray(head, jnp.int32).reshape((1,))
    grid = (n_pods, rows // block_rows)

    slot3 = pl.BlockSpec((1, 1, block_rows, _LANES),
                         lambda p, r, head: (head[0], p, r, 0))
    pods3 = pl.BlockSpec((1, block_rows, _LANES), lambda p, r, head: (p, r, 0))

    if scales is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=[slot3, pods3], out_specs=[pods3, slot3])
        popped, ring_new = pl.pallas_call(
            _ring_kernel_f32, grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((n_pods, rows, _LANES), jnp.float32),
                jax.ShapeDtypeStruct(ring.shape, ring.dtype),
            ],
            input_output_aliases={1: 1},    # donate ring -> ring_new
            interpret=interpret,
        )(head, ring, g)
        return popped, ring_new, None, None

    slot2 = pl.BlockSpec((1, 1, block_rows),
                         lambda p, r, head: (head[0], p, r))
    pods2 = pl.BlockSpec((1, block_rows), lambda p, r, head: (p, r))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid,
        in_specs=[slot3, slot2, pods3, pods2],
        out_specs=[pods3, slot3, slot2, pods3])
    popped, ring_new, scales_new, residual_new = pl.pallas_call(
        _ring_kernel_int8, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_pods, rows, _LANES), jnp.float32),
            jax.ShapeDtypeStruct(ring.shape, jnp.int8),
            jax.ShapeDtypeStruct(scales.shape, jnp.float32),
            jax.ShapeDtypeStruct(g.shape, jnp.float32),
        ],
        # donate ring / scales in place; residual_new reuses fed's buffer
        input_output_aliases={1: 1, 2: 2, 3: 3},
        interpret=interpret,
    )(head, ring, scales, g, scale_new)
    return popped, ring_new, scales_new, residual_new
