"""Fused delay-ring step, Pallas TPU.

One pass over the slot(s) being rotated: pop the tau-old entry,
dequantize it, quantize the incoming gradient with error feedback, and
write the push slot — where the pytree path lowers to hundreds of
per-leaf dynamic-update-slice kernels plus separate elementwise
chains, this is a single kernel launch whose grid touches exactly
``n_pods * rows/block`` blocks.

Two entry points for the two ring layouts:

  ``delay_ring_slot_fwd``  (v2, default) — the pop and push slots are
      two different per-slot buffers, statically selected by the
      caller's phase counter; only int8 needs a kernel (the f32 v2
      rotate is a read plus a scatter).
  ``delay_ring_fwd``       (v1) — one stacked (tau, ...) ring, head
      slot indexed by a scalar-prefetched index map.

State buffers are donated (input_output_aliases), so untouched slots
are never copied: blocks outside the grid keep their (aliased)
contents.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANES = 128


def _ring_kernel_f32(head_ref, ring_ref, g_ref, popped_ref, ring_out_ref):
    del head_ref  # consumed by the index maps
    popped_ref[...] = ring_ref[0].astype(jnp.float32)
    ring_out_ref[...] = g_ref[...][None]


def _ring_kernel_int8(head_ref, ring_ref, scales_ref, fed_ref,
                      scale_new_ref, popped_ref, ring_out_ref,
                      scales_out_ref, residual_out_ref):
    # fed = g + residual is formed by the caller (the scale pass needs
    # it anyway); re-adding it here would cost an extra HBM read of
    # the residual per step. residual_out aliases fed's buffer.
    del head_ref
    q_old = ring_ref[0].astype(jnp.float32)            # (1, B, 128)
    s_old = scales_ref[0][..., None]                   # (1, B, 1)
    popped_ref[...] = q_old * s_old
    fed = fed_ref[...]
    s = scale_new_ref[...][..., None]                  # (1, B, 1)
    q = jnp.clip(jnp.round(fed / s), -127, 127)
    ring_out_ref[...] = q[None].astype(jnp.int8)
    scales_out_ref[...] = scale_new_ref[...][None]
    residual_out_ref[...] = fed - q * s


def _slot_kernel_int8(pop_ref, pop_scales_ref, push_ref, push_scales_ref,
                      fed_ref, scale_new_ref, popped_ref, slot_out_ref,
                      scales_out_ref, residual_out_ref):
    # Ring layout v2: the pop and push slots are DIFFERENT buffers,
    # both statically selected by the caller's phase counter — no
    # scalar-prefetched head. push_ref/push_scales_ref are consumed
    # only through input_output_aliases (the spare slot's old contents
    # are dead by construction); residual_out aliases fed's buffer.
    del push_ref, push_scales_ref
    q_old = pop_ref[...].astype(jnp.float32)           # (1, B, 128)
    s_old = pop_scales_ref[...][..., None]             # (1, B, 1)
    popped_ref[...] = q_old * s_old
    fed = fed_ref[...]
    s = scale_new_ref[...][..., None]                  # (1, B, 1)
    q = jnp.clip(jnp.round(fed / s), -127, 127)
    slot_out_ref[...] = q.astype(jnp.int8)
    scales_out_ref[...] = scale_new_ref[...]
    residual_out_ref[...] = fed - q * s


def delay_ring_slot_fwd(slot_pop, scales_pop, slot_push, scales_push,
                        fed, scale_new, *, block_rows: int = 256,
                        interpret: bool = False):
    """Ring layout v2 int8 rotate: pop one slot, overwrite another.

    slot_pop/slot_push: (n_pods, rows, 128) int8 — two *different*
    per-slot ring buffers, selected statically by the caller's phase
    (v2 keeps tau+1 slots so the push target is always the slot whose
    entry was consumed last step). fed: (n_pods, rows, 128) f32, the
    error-fed gradient; its buffer receives the new residual.
    scales_pop/scales_push/scale_new: (n_pods, rows) f32.

    One fused pass: dequantize the popped entry, quantize fed with
    error feedback, write the push slot — ring state donated end-to-end
    via input_output_aliases. (The f32 ring needs no kernel under v2:
    its pop is a plain read and its push a scatter into the spare
    slot.) Returns (popped f32, slot_new, scales_new, residual_new)."""
    n_pods, rows, lanes = slot_pop.shape
    assert lanes == _LANES and rows % block_rows == 0, (slot_pop.shape,)
    grid = (n_pods, rows // block_rows)
    pods3 = pl.BlockSpec((1, block_rows, _LANES), lambda p, r: (p, r, 0))
    pods2 = pl.BlockSpec((1, block_rows), lambda p, r: (p, r))

    popped, slot_new, scales_new, residual_new = pl.pallas_call(
        _slot_kernel_int8, grid=grid,
        in_specs=[pods3, pods2, pods3, pods2, pods3, pods2],
        out_specs=[pods3, pods3, pods2, pods3],
        out_shape=[
            jax.ShapeDtypeStruct((n_pods, rows, _LANES), jnp.float32),
            jax.ShapeDtypeStruct(slot_push.shape, jnp.int8),
            jax.ShapeDtypeStruct(scales_push.shape, jnp.float32),
            jax.ShapeDtypeStruct(fed.shape, jnp.float32),
        ],
        # donate the push slot/scales; residual_new reuses fed's buffer
        input_output_aliases={2: 1, 3: 2, 4: 3},
        interpret=interpret,
    )(slot_pop, scales_pop, slot_push, scales_push, fed, scale_new)
    return popped, slot_new, scales_new, residual_new


def _variable_meta(mask_ref, cs_ref, n_slots, meta_ref):
    # fused scalar-metadata epilogue: count / staleness-sum fold over
    # the same scalar-prefetched masks, accumulated in the same
    # unrolled ascending-j loop order as the slot fold. cs_ref is
    # (2, n_slots) f32 in SMEM: row 0 the per-slot pod-summed example
    # counts, row 1 the per-slot tagged staleness. Every grid cell
    # writes the same two scalars (idempotent), so no separate
    # O(n_slots) metadata pass survives outside the kernel.
    count = jnp.float32(0.0)
    ssum = jnp.float32(0.0)
    for j in range(n_slots):
        mc = mask_ref[j].astype(jnp.float32) * cs_ref[0, j]
        count = count + mc
        ssum = ssum + mc * cs_ref[1, j]
    meta_ref[0, 0] = count
    meta_ref[0, 1] = ssum


def _variable_pop_kernel_f32(mask_ref, cs_ref, ring_ref, popped_ref,
                             meta_ref):
    # single pass over the stacked ring block: the (due[j]==t) masks
    # arrive as a scalar-prefetched i32 vector and the fold stays in
    # registers — one accumulator, n_slots multiply-adds, one write
    acc = jnp.zeros(popped_ref.shape, jnp.float32)
    for j in range(ring_ref.shape[0]):
        m = mask_ref[j].astype(jnp.float32)
        acc = acc + m * ring_ref[j].astype(jnp.float32)
    popped_ref[...] = acc
    _variable_meta(mask_ref, cs_ref, ring_ref.shape[0], meta_ref)


def _variable_pop_kernel_int8(mask_ref, cs_ref, ring_ref, scales_ref,
                              popped_ref, meta_ref):
    acc = jnp.zeros(popped_ref.shape, jnp.float32)
    for j in range(ring_ref.shape[0]):
        m = mask_ref[j].astype(jnp.float32)
        x = ring_ref[j].astype(jnp.float32) * scales_ref[j][..., None]
        acc = acc + m * x
    popped_ref[...] = acc
    _variable_meta(mask_ref, cs_ref, ring_ref.shape[0], meta_ref)


def variable_pop_fwd(ring, mask, scales=None, counts_stale=None, *,
                     block_rows: int = 256, interpret: bool = False):
    """Single-pass masked pop of the STACKED delay-tolerant ring
    (layout v3, see ``core.arena``): stream the tau_max+1 slots once
    and fold ``mask[j] * slot_j`` in registers — where the slot-order
    XLA loop materializes tau_max+1 separate slot reads per step.

    ring: (n_slots, n_pods, rows, 128) f32 or int8; mask: (n_slots,)
    bool/i32, ``due == t``; scales: (n_slots, n_pods, rows) f32 under
    int8 (dequantized in the same pass); counts_stale: (2, n_slots)
    f32, row 0 the pod-summed per-slot example counts, row 1 the
    per-slot tagged staleness. Pure read — the ring is not rotated here
    (the push is a static-index update-slice the caller already fused).

    Returns the per-pod popped partial sums (n_pods, rows, 128) f32,
    the pod fold/reduce left to the caller (locally under shard_map, so
    one DCN reduce crosses pods). With ``counts_stale`` the scalar
    metadata epilogue is fused into the same pass (SMEM output) and a
    second value ``meta = (count, stale_sum)`` (2,) f32 is returned —
    so the per-step O(n_slots) slot-metadata pass disappears; tau_obs
    is the caller's one division.

    The fold order (ascending j, from a zero accumulator) is the
    canonical one shared with ``ring_variable_pop_ref`` /
    ``ring_variable_meta_ref`` — bit-identical against the oracles in
    interpret mode (exact regardless of order for the meta fold: counts
    and staleness are small-integer-valued floats)."""
    n_slots, n_pods, rows, lanes = ring.shape
    assert lanes == _LANES and rows % block_rows == 0, (ring.shape,)
    mask = jnp.asarray(mask).astype(jnp.int32).reshape((n_slots,))
    with_meta = counts_stale is not None
    if with_meta:
        cs = jnp.asarray(counts_stale, jnp.float32).reshape((2, n_slots))
    else:
        # the kernels always fold the meta epilogue (one compiled
        # shape); without caller metadata it folds zeros
        cs = jnp.zeros((2, n_slots), jnp.float32)
    grid = (n_pods, rows // block_rows)

    slots4 = pl.BlockSpec((n_slots, 1, block_rows, _LANES),
                          lambda p, r, mask, cs: (0, p, r, 0))
    pods3 = pl.BlockSpec((1, block_rows, _LANES),
                         lambda p, r, mask, cs: (p, r, 0))
    meta_spec = pl.BlockSpec((1, 2), lambda p, r, mask, cs: (0, 0),
                             memory_space=pltpu.SMEM)
    out_shape = [
        jax.ShapeDtypeStruct((n_pods, rows, _LANES), jnp.float32),
        jax.ShapeDtypeStruct((1, 2), jnp.float32),
    ]

    if scales is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=grid,
            in_specs=[slots4], out_specs=[pods3, meta_spec])
        popped, meta = pl.pallas_call(
            _variable_pop_kernel_f32, grid_spec=grid_spec,
            out_shape=out_shape, interpret=interpret,
        )(mask, cs, ring)
        return (popped, meta.reshape((2,))) if with_meta else popped

    slots3 = pl.BlockSpec((n_slots, 1, block_rows),
                          lambda p, r, mask, cs: (0, p, r))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=grid,
        in_specs=[slots4, slots3], out_specs=[pods3, meta_spec])
    popped, meta = pl.pallas_call(
        _variable_pop_kernel_int8, grid_spec=grid_spec,
        out_shape=out_shape, interpret=interpret,
    )(mask, cs, ring, scales)
    return (popped, meta.reshape((2,))) if with_meta else popped


def delay_ring_fwd(ring, g, head, scales=None, scale_new=None, *,
                   block_rows: int = 256, interpret: bool = False):
    """ring: (tau, n_pods, rows, 128); g: (n_pods, rows, 128) f32 —
    under int8 (``scales`` is not None) ``g`` is the error-fed
    gradient fed = g + residual, and the new residual is written into
    its (donated) buffer. head: () or (1,) i32.
    Returns (popped f32, ring_new, scales_new, residual_new)."""
    tau, n_pods, rows, lanes = ring.shape
    assert lanes == _LANES and rows % block_rows == 0, (ring.shape,)
    head = jnp.asarray(head, jnp.int32).reshape((1,))
    grid = (n_pods, rows // block_rows)

    slot3 = pl.BlockSpec((1, 1, block_rows, _LANES),
                         lambda p, r, head: (head[0], p, r, 0))
    pods3 = pl.BlockSpec((1, block_rows, _LANES), lambda p, r, head: (p, r, 0))

    if scales is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=[slot3, pods3], out_specs=[pods3, slot3])
        popped, ring_new = pl.pallas_call(
            _ring_kernel_f32, grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((n_pods, rows, _LANES), jnp.float32),
                jax.ShapeDtypeStruct(ring.shape, ring.dtype),
            ],
            input_output_aliases={1: 1},    # donate ring -> ring_new
            interpret=interpret,
        )(head, ring, g)
        return popped, ring_new, None, None

    slot2 = pl.BlockSpec((1, 1, block_rows),
                         lambda p, r, head: (head[0], p, r))
    pods2 = pl.BlockSpec((1, block_rows), lambda p, r, head: (p, r))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid,
        in_specs=[slot3, slot2, pods3, pods2],
        out_specs=[pods3, slot3, slot2, pods3])
    popped, ring_new, scales_new, residual_new = pl.pallas_call(
        _ring_kernel_int8, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_pods, rows, _LANES), jnp.float32),
            jax.ShapeDtypeStruct(ring.shape, jnp.int8),
            jax.ShapeDtypeStruct(scales.shape, jnp.float32),
            jax.ShapeDtypeStruct(g.shape, jnp.float32),
        ],
        # donate ring / scales in place; residual_new reuses fed's buffer
        input_output_aliases={1: 1, 2: 2, 3: 3},
        interpret=interpret,
    )(head, ring, scales, g, scale_new)
    return popped, ring_new, scales_new, residual_new
