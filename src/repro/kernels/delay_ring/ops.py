"""Public wrapper: fused delay-ring pop/push on arena buffers.

Dispatch contract (shared by dual_update's arena entry point):
  impl="auto"    Pallas on TPU, pure-XLA reference elsewhere (the ref
                 IS the CPU fast path — interpret-mode Pallas is an
                 emulator, only useful for correctness tests);
  impl="pallas"  force the kernel (interpret=True off-TPU);
  impl="ref"     force the reference.

Two ring layouts:

  v1  one (tau, n_pods, rows, 128) buffer; the kernel selects the head
      slot with a scalar-prefetched index (``ring_push_pop``).
  v2  per-slot buffers with a STATIC phase schedule (see
      ``core.arena.GradArena``): the pop and push slots arrive here as
      two separate, statically-chosen arrays, so the only kernel left
      is the int8 rotate (``ring_slot_rotate_int8`` — dequantize +
      quantize + error feedback in one pass; the f32 rotate is a plain
      read plus a scatter and needs no kernel at all). On a multi-pod
      mesh the kernel runs under ``ring_slot_rotate_int8_sharded``, a
      shard_map wrapper whose only cross-shard traffic is the pop: an
      all-gather of the COMPRESSED int8 payload + per-row scales, with
      dequantization and the deterministic pod fold local to each
      shard — the compressed bytes are what cross the DCN.

  v3  the delay-tolerant (variable per-step delay) ring: one STACKED
      (n_slots, ...) buffer so the masked pop can stream every slot in
      a single pass (``ring_variable_pop`` — fold ``(due[j]==t) *
      slot_j`` in registers; ``ring_variable_pop_sharded`` folds per
      pod shard and crosses the DCN with one reduce). The push stays a
      static-index update-slice in ``core.arena`` and needs no kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.delay_ring.kernel import (delay_ring_fwd,
                                             delay_ring_slot_fwd,
                                             variable_pop_fwd)
from repro.kernels.delay_ring.ref import (ring_push_pop_ref,
                                          ring_rotate_int8,
                                          ring_slot_rotate_int8_ref,
                                          ring_variable_meta_ref,
                                          ring_variable_pop_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ring_push_pop(ring, g, head, *, scales=None, scale_new=None,
                  impl: str = "auto", interpret: Optional[bool] = None,
                  block_rows: int = 256, constrain_axes=None):
    """v1 entry point: pop ring[head] (dequantized f32), push g
    (quantized) in its place. Under int8 (``scales`` given), ``g`` is
    the already error-fed gradient fed = g + residual — the caller
    forms it once (the scale pass needs it anyway) and the new
    residual is written into its donated buffer. Returns (popped,
    ring, scales, residual); state buffers are donated end-to-end.
    See ref.py for shapes."""
    from repro.kernels import resolve_impl
    impl = resolve_impl(impl)
    if impl == "ref":
        return ring_push_pop_ref(ring, g, head, scales=scales,
                                 scale_new=scale_new,
                                 constrain_axes=constrain_axes)
    interp = (not _on_tpu()) if interpret is None else interpret
    return delay_ring_fwd(ring, g, head, scales=scales,
                          scale_new=scale_new, block_rows=block_rows,
                          interpret=interp)


def ring_slot_rotate_int8(slot_pop, scales_pop, slot_push, scales_push,
                          fed, scale_new, *, impl: str = "pallas",
                          interpret: Optional[bool] = None,
                          block_rows: int = 256):
    """v2 int8 slot rotate: dequantize ``slot_pop``, quantize ``fed``
    with error feedback into ``slot_push``'s donated buffer — one
    fused pass (the two slots are different buffers, statically chosen
    by the caller's phase). Returns (popped f32, slot_new, scales_new,
    residual_new); residual_new reuses fed's buffer."""
    from repro.kernels import resolve_impl
    impl = resolve_impl(impl)
    if impl == "ref":
        return ring_slot_rotate_int8_ref(slot_pop, scales_pop, fed,
                                         scale_new)
    interp = (not _on_tpu()) if interpret is None else interpret
    return delay_ring_slot_fwd(slot_pop, scales_pop, slot_push,
                               scales_push, fed, scale_new,
                               block_rows=block_rows, interpret=interp)


def ring_variable_pop(ring, mask, *, scales=None, counts_stale=None,
                      impl: str = "auto",
                      interpret: Optional[bool] = None,
                      block_rows: int = 256):
    """Single-pass masked pop of the STACKED delay-tolerant ring
    (layout v3): fold ``mask[j] * slot_j`` over the tau_max+1 slots in
    one kernel launch instead of tau_max+1 separate slot reads.
    Pure read — the push is the caller's static-index update-slice.

    ring: (n_slots, n_pods, rows, 128) f32|int8; mask: (n_slots,)
    bool, ``due == t``; scales: (n_slots, n_pods, rows) f32 under int8;
    counts_stale: optional (2, n_slots) f32 [pod-summed counts;
    staleness tags] — when given, the scalar count/tau metadata fold is
    fused into the kernel epilogue (SMEM output) and the return value
    becomes ``(popped, meta)`` with ``meta = (count, stale_sum)`` (2,)
    f32, eliminating the separate per-step O(n_slots) metadata pass.

    Returns the per-pod popped partials (n_pods, rows, 128) f32; the
    pod fold is the caller's (``arena._pod_fold`` / the sharded
    wrapper's single DCN reduce). NOTE: unlike the rotate entry points,
    "ref" here is the expression-identical slot fold oracle used by the
    bit-identity tests — the production CPU path is the O(arrivals)
    gather inside ``arena.push_pop_variable``, which never reaches this
    wrapper."""
    from repro.kernels import fit_block_rows, resolve_impl
    impl = resolve_impl(impl)
    if impl == "ref":
        popped = ring_variable_pop_ref(ring, mask, scales=scales)
        if counts_stale is None:
            return popped
        return popped, ring_variable_meta_ref(mask, counts_stale)
    interp = (not _on_tpu()) if interpret is None else interpret
    blk = fit_block_rows(ring.shape[2], block_rows)
    if not interp:
        assert blk % 8 == 0, (ring.shape, blk)
    return variable_pop_fwd(ring, mask, scales=scales,
                            counts_stale=counts_stale, block_rows=blk,
                            interpret=interp)


def ring_variable_pop_sharded(ring, mask, *, scales=None,
                              counts_stale=None, mesh_cfg,
                              interpret: Optional[bool] = None,
                              block_rows: int = 256):
    """``shard_map`` wrapper around the variable-pop kernel for
    multi-pod meshes (mirrors ``ring_slot_rotate_int8_sharded``): the
    kernel folds the due slots LOCALLY on each pod shard — the int8
    payload is dequantized in place, never gathered — and the pod
    reduction is ONE ``psum`` of the already-folded f32 rows, i.e. a
    single DCN reduce per step where the slot-order loop issued
    n_slots of them.

    Axis placement comes from ``arena_ring_specs`` (slot dim
    replicated, pods over 'pod', rows over the intra-pod slice); the
    (n_slots,) mask — and ``counts_stale``, when the fused metadata
    epilogue is requested — are replicated, so the kernel's (count,
    stale_sum) meta is already the GLOBAL value on every shard (the
    counts row is the pod-summed metadata the arena carries), no
    second collective needed. Returns grad_sum (rows, 128) f32 ALREADY
    summed over pods — like the sharded rotate, the pod reduction
    happens inside (it IS the DCN collective) — or (grad_sum, meta)
    with ``counts_stale``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.context import active_physical_mesh
    from repro.dist.sharding import arena_ring_specs
    from repro.kernels import dim_shard, fit_block_rows

    mesh = active_physical_mesh()
    if mesh is None:
        raise ValueError("ring_variable_pop_sharded needs an ambient "
                         "physical mesh (`with mesh:`)")
    interp = (not _on_tpu()) if interpret is None else interpret
    n_slots, n_pods, rows, _ = ring.shape
    ring_spec, scales_spec, row_spec = arena_ring_specs(mesh_cfg, rows)
    rows_local = rows // dim_shard(
        ring_spec[2] if len(ring_spec) > 2 else None, mesh)
    blk = fit_block_rows(rows_local, block_rows)
    if not interp:
        assert blk % 8 == 0, (rows_local, blk)
    mask_spec = P()
    with_meta = counts_stale is not None

    def local_pop(ring, scales, mask, cs):
        out = variable_pop_fwd(ring, mask, scales=scales,
                               counts_stale=cs if with_meta else None,
                               block_rows=blk, interpret=interp)
        part, meta = out if with_meta else (out, None)
        acc = part[0]                     # local pods: deterministic
        for p in range(1, part.shape[0]):  # left fold, shard-local
            acc = acc + part[p]
        acc = jax.lax.psum(acc, "pod")    # THE one DCN reduce
        return (acc, meta) if with_meta else acc

    out_specs = (row_spec, mask_spec) if with_meta else row_spec
    if scales is None:
        fn = shard_map(lambda r, m, cs: local_pop(r, None, m, cs),
                       mesh=mesh,
                       in_specs=(ring_spec, mask_spec, mask_spec),
                       out_specs=out_specs, check_rep=False)
        args = (ring, mask)
    else:
        fn = shard_map(local_pop, mesh=mesh,
                       in_specs=(ring_spec, scales_spec, mask_spec,
                                 mask_spec),
                       out_specs=out_specs, check_rep=False)
        args = (ring, scales, mask)
    cs = (jnp.asarray(counts_stale, jnp.float32) if with_meta
          else jnp.zeros((2, n_slots), jnp.float32))
    return fn(*args, cs)


# ---------------------------------------------------------------------------
# Multi-pod shard_map wrapper (ring layout v2 only)
# ---------------------------------------------------------------------------
def ring_slot_rotate_int8_sharded(slot_pop, scales_pop, slot_push,
                                  scales_push, fed, scale_new, *,
                                  mesh_cfg,
                                  interpret: Optional[bool] = None,
                                  block_rows: int = 256):
    """``shard_map`` wrapper around the v2 int8 slot kernel for
    multi-pod meshes — the fused kernel runs per shard instead of
    falling back to the XLA ref path (a bare pallas_call on the
    pod-sharded slots would make GSPMD gather them whole per device).

    Axis placement comes from the ``repro.dist`` profiles
    (``arena_slot_specs``): slots shard ('pod', 'flat'-rows). The only
    cross-shard traffic is the pop — an all-gather of the COMPRESSED
    int8 payload + per-row scales across the pod axis (those are the
    actual DCN bytes, mirroring the pytree path's pop_leaf wire
    contract); dequantization and the deterministic left fold happen
    locally, in the same order on every shard. The kernel's own
    (local, already-dequantized) popped output is unused here — one
    spare slot-shard write, traded for keeping the fold order
    shard-count-independent.

    Returns (grad_sum (rows, 128) f32 ALREADY summed over pods,
    slot_new, scales_new, residual_new) — unlike the unsharded entry
    points, the pod reduction happens inside (it IS the DCN
    collective)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.context import active_physical_mesh
    from repro.dist.sharding import arena_slot_specs
    from repro.kernels import dim_shard, fit_block_rows

    mesh = active_physical_mesh()
    if mesh is None:
        raise ValueError("ring_slot_rotate_int8_sharded needs an "
                         "ambient physical mesh (`with mesh:`)")
    interp = (not _on_tpu()) if interpret is None else interpret
    n_pods, rows, _ = slot_pop.shape
    slot_spec, scales_spec, row_spec = arena_slot_specs(mesh_cfg, rows)
    rows_local = rows // dim_shard(
        slot_spec[1] if len(slot_spec) > 1 else None, mesh)
    blk = fit_block_rows(rows_local, block_rows)
    if not interp:
        assert blk % 8 == 0, (rows_local, blk)

    def local_rotate(slot_pop, scales_pop, slot_push, scales_push,
                     fed, scale_new):
        # the wire transfer: gather the compressed payload over pods
        q_all = jax.lax.all_gather(slot_pop, "pod", axis=0, tiled=True)
        s_all = jax.lax.all_gather(scales_pop, "pod", axis=0, tiled=True)
        acc = None
        for p in range(q_all.shape[0]):
            x = jax.lax.optimization_barrier(
                q_all[p].astype(jnp.float32) * s_all[p][:, None])
            acc = x if acc is None else acc + x
        _, slot_new, scales_new, residual = delay_ring_slot_fwd(
            slot_pop, scales_pop, slot_push, scales_push, fed,
            scale_new, block_rows=blk, interpret=interp)
        return acc, slot_new, scales_new, residual

    fn = shard_map(
        local_rotate, mesh=mesh,
        in_specs=(slot_spec, scales_spec, slot_spec, scales_spec,
                  slot_spec, scales_spec),
        out_specs=(row_spec, slot_spec, scales_spec, slot_spec),
        check_rep=False)
    return fn(slot_pop, scales_pop, slot_push, scales_push, fed,
              scale_new)


__all__ = ["ring_push_pop", "ring_push_pop_ref", "ring_rotate_int8",
           "ring_slot_rotate_int8", "ring_slot_rotate_int8_sharded",
           "ring_variable_meta_ref", "ring_variable_pop",
           "ring_variable_pop_ref", "ring_variable_pop_sharded"]
