"""Public wrapper: fused delay-ring pop/push on arena buffers.

Dispatch contract (shared by dual_update's arena entry point):
  impl="auto"    Pallas on TPU, pure-XLA reference elsewhere (the ref
                 IS the CPU fast path — interpret-mode Pallas is an
                 emulator, only useful for correctness tests);
  impl="pallas"  force the kernel (interpret=True off-TPU);
  impl="ref"     force the reference.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.delay_ring.kernel import delay_ring_fwd
from repro.kernels.delay_ring.ref import ring_push_pop_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ring_push_pop(ring, g, head, *, scales=None, scale_new=None,
                  impl: str = "auto", interpret: Optional[bool] = None,
                  block_rows: int = 256, constrain_axes=None):
    """Pop ring[head] (dequantized f32), push g (quantized) in its
    place. Under int8 (``scales`` given), ``g`` is the already
    error-fed gradient fed = g + residual — the caller forms it once
    (the scale pass needs it anyway) and the new residual is written
    into its donated buffer. Returns (popped, ring, scales, residual);
    state buffers are donated end-to-end. See ref.py for shapes."""
    from repro.kernels import resolve_impl
    impl = resolve_impl(impl)
    if impl == "ref":
        return ring_push_pop_ref(ring, g, head, scales=scales,
                                 scale_new=scale_new,
                                 constrain_axes=constrain_axes)
    interp = (not _on_tpu()) if interpret is None else interpret
    return delay_ring_fwd(ring, g, head, scales=scales,
                          scale_new=scale_new, block_rows=block_rows,
                          interpret=interp)


__all__ = ["ring_push_pop", "ring_push_pop_ref"]
