"""Pure-jnp oracle for the fused delay-ring step — also the CPU fast
path (one dynamic-slice read + one dynamic-update-slice write on the
contiguous ring; XLA fuses the int8 elementwise chains).

Arithmetic is kept formula-identical to ``core.delayed``'s per-leaf
pytree path (quantize: ``clip(round(g/scale))``; dequantize:
``q.f32 * scale``; residual: ``fed - dequant``) so the arena path is
bit-exact against the reference implementation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ring_push_pop_ref(ring, g, head, scales=None, scale_new=None,
                      constrain_axes=None):
    """Pop ring[head] (dequantized), overwrite it with g (quantized).

    ring: (tau, n_pods, rows, 128) f32|int8; g: (n_pods, rows, 128)
    f32 — under int8 (``scales`` given) g is the already error-fed
    gradient; head: () i32; scales: (tau, n_pods, rows) f32;
    scale_new: (n_pods, rows) f32. ``constrain_axes`` optionally pins
    the *int8* popped payload (the actual DCN bytes) before
    dequantization, mirroring the pytree path.
    Returns (popped f32, ring, scales, residual).
    """
    if scales is None:
        popped = jax.lax.dynamic_index_in_dim(ring, head, 0, keepdims=False)
        ring = jax.lax.dynamic_update_index_in_dim(ring, g, head, 0)
        return popped, ring, None, None
    return ring_rotate_int8(ring, scales, g, scale_new, head,
                            constrain_axes=constrain_axes)


def ring_slot_rotate_int8_ref(slot_pop, scales_pop, fed, scale_new):
    """Ring layout v2 oracle for the int8 slot rotate: the pop and
    push slots are separate, statically-selected buffers, so no
    dynamic indexing remains. Arithmetic is formula-identical to
    ``ring_rotate_int8`` so v1 and v2 stay bit-exact. (The f32 ring
    has no v2 kernel to check: its rotate is a read and a scatter.)

    slot_pop: (n_pods, rows, 128) int8; fed: (n_pods, rows, 128) f32;
    scales_pop/scale_new: (n_pods, rows) f32.
    Returns (popped f32, slot_new, scales_new, residual_new)."""
    popped = slot_pop.astype(jnp.float32) * scales_pop[..., None]
    s = scale_new[..., None]
    q = jnp.clip(jnp.round(fed / s), -127, 127)
    # barrier as in core.delayed._dequantize: keep fed - q*s un-contracted
    residual = fed - jax.lax.optimization_barrier(q * s)
    return popped, q.astype(jnp.int8), scale_new, residual


def ring_variable_pop_ref(ring, mask, scales=None):
    """Oracle for the single-pass variable pop (``variable_pop_fwd``):
    fold ``mask[j] * slot_j`` over the stacked delay-tolerant ring in
    ascending slot order from a zero accumulator — expression-identical
    to the kernel's register fold, so interpret mode is bit-exact
    against this.

    ring: (n_slots, n_pods, rows, 128) f32|int8; mask: (n_slots,)
    bool/i32; scales: (n_slots, n_pods, rows) f32 under int8.
    Returns the per-pod popped partial sums (n_pods, rows, 128) f32
    (pod fold left to the caller, as in the kernel)."""
    n_slots, n_pods, rows, lanes = ring.shape
    acc = jnp.zeros((n_pods, rows, lanes), jnp.float32)
    for j in range(n_slots):
        m = mask[j].astype(jnp.float32)
        x = ring[j].astype(jnp.float32)
        if scales is not None:
            x = x * scales[j][..., None]
        acc = acc + m * x
    return acc


def ring_variable_meta_ref(mask, counts_stale):
    """Oracle for the fused scalar-metadata epilogue
    (``variable_pop_fwd(..., counts_stale=...)``): fold the masked
    count / staleness-sum over the slots in the kernel's ascending-j
    order from zero accumulators — expression-identical, so interpret
    mode is bit-exact against this (and exact under ANY fold order:
    counts and staleness are small-integer-valued floats, whose f32
    sums carry no rounding).

    mask: (n_slots,) bool/i32; counts_stale: (2, n_slots) f32 — row 0
    the pod-summed per-slot example counts, row 1 the per-slot tagged
    staleness. Returns (count, stale_sum) as a (2,) f32; tau_obs is the
    caller's ``stale_sum / max(count, 1)``."""
    cs = jnp.asarray(counts_stale, jnp.float32)
    count = jnp.float32(0.0)
    ssum = jnp.float32(0.0)
    for j in range(cs.shape[1]):
        mc = mask[j].astype(jnp.float32) * cs[0, j]
        count = count + mc
        ssum = ssum + mc * cs[1, j]
    return jnp.stack([count, ssum])


def ring_rotate_int8(ring, scales, fed, scale_new, head,
                     constrain_axes=None):
    """int8 rotate with the error-fed gradient already formed (the
    arena path builds ``fed`` in its scatter pass, so no extra add)."""
    q_old = jax.lax.dynamic_index_in_dim(ring, head, 0, keepdims=False)
    s_old = jax.lax.dynamic_index_in_dim(scales, head, 0, keepdims=False)
    if constrain_axes is not None:
        from repro.dist.context import constrain
        q_old = constrain(q_old, constrain_axes)
        s_old = constrain(s_old, constrain_axes[:-1])
    popped = q_old.astype(jnp.float32) * s_old[..., None]

    s = scale_new[..., None]
    q = jnp.clip(jnp.round(fed / s), -127, 127)
    ring = jax.lax.dynamic_update_index_in_dim(
        ring, q.astype(jnp.int8), head, 0)
    scales = jax.lax.dynamic_update_index_in_dim(scales, scale_new, head, 0)
    # barrier as in core.delayed._dequantize: keep fed - q*s un-contracted
    residual = fed - jax.lax.optimization_barrier(q * s)
    return popped, ring, scales, residual
