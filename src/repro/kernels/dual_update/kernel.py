"""Fused dual-averaging update, Pallas TPU.

The master's hot loop (paper eq. (3)-(4), psi = 0.5||w||^2):

    z <- z + g ;  w <- -alpha * z

Memory-bound: 2 reads + 2 writes per element. Fusing keeps z and w in
VMEM for one pass instead of XLA's two elementwise kernels, and donates
z (input_output_aliases) so no extra HBM allocation appears. Operates on
a flattened (rows, 128) lane-aligned view provided by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _update_kernel(alpha_ref, z_ref, g_ref, z_out_ref, w_out_ref):
    a = alpha_ref[0, 0]
    z = z_ref[...].astype(jnp.float32) + g_ref[...].astype(jnp.float32)
    z_out_ref[...] = z.astype(z_out_ref.dtype)
    w_out_ref[...] = (-a * z).astype(w_out_ref.dtype)


def _fused_kernel(scal_ref, z_ref, g_ref, z_out_ref, w_out_ref):
    a = scal_ref[0, 0]
    d = scal_ref[0, 1]
    g = g_ref[...].astype(jnp.float32) / d
    z = z_ref[...].astype(jnp.float32) + g
    z_out_ref[...] = z.astype(z_out_ref.dtype)
    w_out_ref[...] = (-a * z).astype(w_out_ref.dtype)


def dual_update_fused_fwd(z, g_sum, denom, alpha, *, block_rows: int = 256,
                          interpret: bool = False):
    """Arena entry point: consumes the *popped, un-normalized* gradient
    sum and fuses the anytime count-normalization into the same pass:

        w <- -alpha * (z + g_sum / denom) ;  z <- z + g_sum / denom

    z, g_sum: (rows, 128) f32; denom, alpha: scalars. Returns
    (z_new, w_new); z is donated."""
    rows, lanes = z.shape
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes)
    scal = jnp.stack([jnp.float32(alpha), jnp.float32(denom)]).reshape(1, 2)
    grid = (rows // block_rows,)
    z_new, w_new = pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, 128), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, 128), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, 128), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, 128), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 128), z.dtype),
            jax.ShapeDtypeStruct((rows, 128), z.dtype),
        ],
        input_output_aliases={1: 0},   # donate z -> z_new
        interpret=interpret,
    )(scal, z, g_sum)
    return z_new, w_new


def dual_update_fwd(z, g, alpha, *, block_rows: int = 256,
                    interpret: bool = False):
    """z, g: (rows, 128) f32; alpha: scalar f32.
    Returns (z_new, w_new) both (rows, 128)."""
    rows, lanes = z.shape
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes)
    alpha2d = jnp.reshape(alpha.astype(jnp.float32), (1, 1))
    grid = (rows // block_rows,)
    z_new, w_new = pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, 128), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, 128), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, 128), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, 128), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 128), z.dtype),
            jax.ShapeDtypeStruct((rows, 128), z.dtype),
        ],
        input_output_aliases={1: 0},   # donate z -> z_new
        interpret=interpret,
    )(alpha2d, z, g)
    return z_new, w_new
