"""Public wrappers for the fused dual-averaging update.

``dual_update_arena`` is the production entry point: it operates
directly on the persistent (rows, 128) gradient arena (see
``repro.core.arena``) — no flattening happens here at all, and the
anytime count-normalization is fused into the same pass. On multi-pod
meshes ``dual_update_arena_sharded`` runs the same kernel per shard
under shard_map (the update is elementwise, so the wrapper carries no
collectives) instead of letting GSPMD gather the flat-sharded arena.

``dual_update`` is the legacy pytree wrapper kept for ablations and
kernel tests: it re-flattens the whole tree on every call (two
concatenate+pad copies in, two unflattens out), which is exactly the
overhead the arena was introduced to eliminate.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dual_update.kernel import (dual_update_fused_fwd,
                                              dual_update_fwd)
from repro.kernels.dual_update.ref import (dual_update_fused_ref,
                                           dual_update_ref)

_LANES = 128
_BLOCK_ROWS = 256


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(x.size) for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    pad = (-flat.size) % (_LANES * _BLOCK_ROWS)
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _LANES), (treedef, sizes,
                                      [x.shape for x in leaves],
                                      [x.dtype for x in leaves])


def _unflatten(mat, meta):
    treedef, sizes, shapes, dtypes = meta
    flat = mat.reshape(-1)
    out, ofs = [], 0
    for size, shape, dtype in zip(sizes, shapes, dtypes):
        out.append(flat[ofs:ofs + size].reshape(shape).astype(dtype))
        ofs += size
    return jax.tree.unflatten(treedef, out)


def dual_update_arena(z, g_sum, count, alpha, *, impl: str = "auto",
                      interpret: Optional[bool] = None,
                      block_rows: int = _BLOCK_ROWS):
    """Fused arena update: g = g_sum / max(count, eps); z += g;
    w = -alpha z — one read/write pass over the donated (rows, 128)
    arena. impl dispatch as in kernels.delay_ring.ops ("auto" = Pallas
    on TPU, pure-XLA reference elsewhere). Returns (z_new, w)."""
    from repro.kernels import resolve_impl
    denom = jnp.maximum(count, 1e-12)
    impl = resolve_impl(impl)
    if impl == "ref":
        return dual_update_fused_ref(z, g_sum, denom, alpha)
    interp = (not _on_tpu()) if interpret is None else interpret
    return dual_update_fused_fwd(z, g_sum, denom, jnp.float32(alpha),
                                 block_rows=block_rows, interpret=interp)


def dual_update_arena_sharded(z, g_sum, count, alpha, *, mesh_cfg,
                              interpret: Optional[bool] = None,
                              block_rows: int = _BLOCK_ROWS):
    """``shard_map`` wrapper around the fused dual-update kernel for
    multi-pod meshes — mirrors ``ring_slot_rotate_int8_sharded``: a
    bare pallas_call on the flat-sharded z/g buffers would make GSPMD
    gather them whole per device, so the kernel runs per shard
    instead. The update is elementwise over rows (z and g_sum shard
    identically on the intra-pod "flat" slice via
    ``dist.sharding.arena_slot_specs``), so the wrapper needs NO
    cross-shard communication at all — count and alpha are replicated
    scalars. Returns (z_new, w) exactly like ``dual_update_arena``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.context import active_physical_mesh
    from repro.dist.sharding import arena_slot_specs
    from repro.kernels import dim_shard, fit_block_rows

    mesh = active_physical_mesh()
    if mesh is None:
        raise ValueError("dual_update_arena_sharded needs an ambient "
                         "physical mesh (`with mesh:`)")
    interp = (not _on_tpu()) if interpret is None else interpret
    rows, _ = z.shape
    _, _, row_spec = arena_slot_specs(mesh_cfg, rows)
    rows_local = rows // dim_shard(row_spec[0] if len(row_spec) else None,
                                   mesh)
    blk = fit_block_rows(rows_local, block_rows)
    if not interp:
        assert blk % 8 == 0, (rows_local, blk)
    denom = jnp.maximum(count, 1e-12)

    def local_update(z, g, scal):
        return dual_update_fused_fwd(z, g, scal[0], scal[1],
                                     block_rows=blk, interpret=interp)

    fn = shard_map(
        local_update, mesh=mesh,
        in_specs=(row_spec, row_spec, P()),
        out_specs=(row_spec, row_spec),
        check_rep=False)
    scal = jnp.stack([jnp.float32(denom), jnp.float32(alpha)])
    return fn(z, g_sum, scal)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def dual_update(z_tree, g_tree, alpha, *, interpret: Optional[bool] = None
                ) -> Tuple[Any, Any]:
    """(z_new_tree, w_new_tree) = fused [z+g ; -alpha(z+g)].

    Legacy pytree wrapper (per-call re-flatten); production runs on
    ``dual_update_arena``."""
    interp = (not _on_tpu()) if interpret is None else interpret
    z_mat, meta = _flatten(z_tree)
    g_mat, _ = _flatten(g_tree)
    z_new, w_new = dual_update_fwd(z_mat, g_mat, jnp.float32(alpha),
                                   block_rows=_BLOCK_ROWS, interpret=interp)
    return _unflatten(z_new, meta), _unflatten(w_new, meta)


__all__ = ["dual_update", "dual_update_arena", "dual_update_arena_sharded",
           "dual_update_ref"]
