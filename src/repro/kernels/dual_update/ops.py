"""Public wrappers for the fused dual-averaging update.

``dual_update_arena`` is the production entry point: it operates
directly on the persistent (rows, 128) gradient arena (see
``repro.core.arena``) — no flattening happens here at all, and the
anytime count-normalization is fused into the same pass.

``dual_update`` is the legacy pytree wrapper kept for ablations and
kernel tests: it re-flattens the whole tree on every call (two
concatenate+pad copies in, two unflattens out), which is exactly the
overhead the arena was introduced to eliminate.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dual_update.kernel import (dual_update_fused_fwd,
                                              dual_update_fwd)
from repro.kernels.dual_update.ref import (dual_update_fused_ref,
                                           dual_update_ref)

_LANES = 128
_BLOCK_ROWS = 256


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(x.size) for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    pad = (-flat.size) % (_LANES * _BLOCK_ROWS)
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _LANES), (treedef, sizes,
                                      [x.shape for x in leaves],
                                      [x.dtype for x in leaves])


def _unflatten(mat, meta):
    treedef, sizes, shapes, dtypes = meta
    flat = mat.reshape(-1)
    out, ofs = [], 0
    for size, shape, dtype in zip(sizes, shapes, dtypes):
        out.append(flat[ofs:ofs + size].reshape(shape).astype(dtype))
        ofs += size
    return jax.tree.unflatten(treedef, out)


def dual_update_arena(z, g_sum, count, alpha, *, impl: str = "auto",
                      interpret: Optional[bool] = None,
                      block_rows: int = _BLOCK_ROWS):
    """Fused arena update: g = g_sum / max(count, eps); z += g;
    w = -alpha z — one read/write pass over the donated (rows, 128)
    arena. impl dispatch as in kernels.delay_ring.ops ("auto" = Pallas
    on TPU, pure-XLA reference elsewhere). Returns (z_new, w)."""
    from repro.kernels import resolve_impl
    denom = jnp.maximum(count, 1e-12)
    impl = resolve_impl(impl)
    if impl == "ref":
        return dual_update_fused_ref(z, g_sum, denom, alpha)
    interp = (not _on_tpu()) if interpret is None else interpret
    return dual_update_fused_fwd(z, g_sum, denom, jnp.float32(alpha),
                                 block_rows=block_rows, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def dual_update(z_tree, g_tree, alpha, *, interpret: Optional[bool] = None
                ) -> Tuple[Any, Any]:
    """(z_new_tree, w_new_tree) = fused [z+g ; -alpha(z+g)].

    Legacy pytree wrapper (per-call re-flatten); production runs on
    ``dual_update_arena``."""
    interp = (not _on_tpu()) if interpret is None else interpret
    z_mat, meta = _flatten(z_tree)
    g_mat, _ = _flatten(g_tree)
    z_new, w_new = dual_update_fwd(z_mat, g_mat, jnp.float32(alpha),
                                   block_rows=_BLOCK_ROWS, interpret=interp)
    return _unflatten(z_new, meta), _unflatten(w_new, meta)


__all__ = ["dual_update", "dual_update_arena", "dual_update_ref"]
