"""Public wrapper: fused dual-averaging update over arbitrary pytrees.

Flattens every leaf into one lane-aligned (rows, 128) buffer, runs the
fused kernel once, and scatters back — one kernel launch for the whole
parameter tree instead of per-leaf elementwise chains.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dual_update.kernel import dual_update_fwd
from repro.kernels.dual_update.ref import dual_update_ref

_LANES = 128
_BLOCK_ROWS = 256


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(x.size) for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    pad = (-flat.size) % (_LANES * _BLOCK_ROWS)
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _LANES), (treedef, sizes,
                                      [x.shape for x in leaves],
                                      [x.dtype for x in leaves])


def _unflatten(mat, meta):
    treedef, sizes, shapes, dtypes = meta
    flat = mat.reshape(-1)
    out, ofs = [], 0
    for size, shape, dtype in zip(sizes, shapes, dtypes):
        out.append(flat[ofs:ofs + size].reshape(shape).astype(dtype))
        ofs += size
    return jax.tree.unflatten(treedef, out)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def dual_update(z_tree, g_tree, alpha, *, interpret: Optional[bool] = None
                ) -> Tuple[Any, Any]:
    """(z_new_tree, w_new_tree) = fused [z+g ; -alpha(z+g)]."""
    interp = (not _on_tpu()) if interpret is None else interpret
    z_mat, meta = _flatten(z_tree)
    g_mat, _ = _flatten(g_tree)
    z_new, w_new = dual_update_fwd(z_mat, g_mat, jnp.float32(alpha),
                                   block_rows=_BLOCK_ROWS, interpret=interp)
    return _unflatten(z_new, meta), _unflatten(w_new, meta)


__all__ = ["dual_update", "dual_update_ref"]
