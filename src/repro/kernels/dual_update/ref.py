"""Pure-jnp oracle for the fused dual-averaging update."""
from __future__ import annotations

import jax.numpy as jnp


def dual_update_ref(z, g, alpha):
    z_new = z.astype(jnp.float32) + g.astype(jnp.float32)
    return z_new.astype(z.dtype), (-alpha * z_new).astype(z.dtype)


def dual_update_fused_ref(z, g_sum, denom, alpha):
    """Arena variant with the count-normalization fused in; arithmetic
    mirrors ``anytime.normalize`` + ``dual_averaging.update`` exactly
    (bit-for-bit vs the pytree path)."""
    g = g_sum.astype(jnp.float32) / denom
    z_new = z.astype(jnp.float32) + g
    return z_new, -alpha * z_new
