"""Pure-jnp oracle for the fused dual-averaging update."""
from __future__ import annotations

import jax.numpy as jnp


def dual_update_ref(z, g, alpha):
    z_new = z.astype(jnp.float32) + g.astype(jnp.float32)
    return z_new.astype(z.dtype), (-alpha * z_new).astype(z.dtype)
