"""Flash-attention backward, Pallas TPU.

Two kernels over the recomputation trick (no S^2 residuals):
  * forward (kernel.py) extended to emit the row logsumexp (lse);
  * dq kernel: grid (B*Hq, nq, nk), kv innermost, dq accumulator in VMEM;
  * dkv kernel: grid (B*Hq, nk, nq), q innermost, dk/dv accumulators in
    VMEM — computed per q-head and group-summed outside (GQA).

delta = rowsum(do * o) is a cheap elementwise pass done in jnp.
``flash_attention_train`` wires these into a jax.custom_vjp so
``jax.grad`` through the kernel matches the reference exactly
(tests/test_kernels_bwd.py, interpret mode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention.kernel import flash_attention_fwd

NEG_INF = -1e30


def _mask(block_q, block_k, qi, ki, *, causal, window, sq, skv):
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (skv - sq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    m = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        m &= q_pos >= k_pos
    if window is not None:
        m &= (q_pos - k_pos) < window
    return m


# ---------------------------------------------------------------------------
# Forward with lse output (for the backward recomputation)
# ---------------------------------------------------------------------------
def _fwd_lse_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                    acc_scr, *, scale, causal, window, block_q, block_k,
                    sq, skv):
    qi, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask(block_q, block_k, qi, ki, causal=causal,
                        window=window, sq=sq, skv=skv), s, NEG_INF)
    m_prev, l_prev = m_scr[...][:, 0], l_scr[...][:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_cur = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur[:, None]
    l_scr[...] = l_cur[:, None]

    @pl.when(ki == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...][:, 0], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...][:, 0] + jnp.log(l))[:, None].astype(
            lse_ref.dtype)


def flash_fwd_lse(q, k, v, *, causal, window, scale, block_q, block_k,
                  interpret):
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, D)

    def kv_head(bh):
        return (bh // Hq) * Hkv + (bh % Hq) // G

    out, lse = pl.pallas_call(
        functools.partial(_fwd_lse_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          sq=Sq, skv=Skv),
        grid=(B * Hq, Sq // block_q, Skv // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (kv_head(bh), ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (kv_head(bh), ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bh, qi, ki: (bh, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B * Hq, Sq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, D), lse.reshape(B, Hq, Sq)


# ---------------------------------------------------------------------------
# dq kernel
# ---------------------------------------------------------------------------
def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, scale, causal, window, block_q, block_k, sq, skv):
    qi, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, 0]
    delta = delta_ref[0][:, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask(block_q, block_k, qi, ki, causal=causal,
                        window=window, sq=sq, skv=skv), s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale
    dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _fin():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# dk/dv kernel (per q-head; group-summed outside for GQA)
# ---------------------------------------------------------------------------
def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                block_q, block_k, sq, skv):
    ki, qi = pl.program_id(1), pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, 0]
    delta = delta_ref[0][:, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask(block_q, block_k, qi, ki, causal=causal,
                        window=window, sq=sq, skv=skv), s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                        # (bq, bk)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale               # (bq, bk)
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _fin():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal, window, scale,
                        block_q, block_k, interpret):
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                              # (B,Hq,Sq)

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, D)
    dof = do.reshape(B * Hq, Sq, D)
    lsef = lse.reshape(B * Hq, Sq, 1)
    deltaf = delta.reshape(B * Hq, Sq, 1)

    def kv_head(bh):
        return (bh // Hq) * Hkv + (bh % Hq) // G

    kw = dict(scale=scale, causal=causal, window=window,
              block_q=block_q, block_k=block_k, sq=Sq, skv=Skv)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **kw),
        grid=(B * Hq, Sq // block_q, Skv // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (kv_head(bh), ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (kv_head(bh), ki, 0)),
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bh, qi, ki: (bh, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    # dk/dv per q-head, group-summed after (GQA)
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, **kw),
        grid=(B * Hq, Skv // block_k, Sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, ki, qi: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, ki, qi: (kv_head(bh), ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, ki, qi: (kv_head(bh), ki, 0)),
            pl.BlockSpec((1, block_q, D), lambda bh, ki, qi: (bh, qi, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bh, ki, qi: (bh, qi, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bh, ki, qi: (bh, qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, D), lambda bh, ki, qi: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, ki, qi: (bh, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Skv, D), q.dtype),
            jax.ShapeDtypeStruct((B * Hq, Skv, D), q.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    dk = dk_h.reshape(B, Hkv, G, Skv, D).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, Hkv, G, Skv, D).sum(axis=2).astype(v.dtype)
    return dq.reshape(B, Hq, Sq, D), dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_train(q, k, v, causal=True, window=None,
                          block_q=128, block_k=128, interpret=False):
    D = q.shape[-1]
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def _train_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    D = q.shape[-1]
    o, lse = flash_fwd_lse(q, k, v, causal=causal, window=window,
                           scale=D ** -0.5, block_q=block_q,
                           block_k=block_k, interpret=interpret)
    return o, (q, k, v, o, lse)


def _train_bwd(causal, window, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    D = q.shape[-1]
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, window=window,
        scale=D ** -0.5, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return dq, dk, dv


flash_attention_train.defvjp(_train_fwd, _train_bwd)
