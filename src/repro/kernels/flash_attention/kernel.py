"""Blocked (flash) attention forward, Pallas TPU.

Grid: (batch*q_heads, Sq/block_q, Skv/block_k); the kv dimension is the
innermost (sequential on TPU) axis, carrying the online-softmax state
(m, l, acc) in VMEM scratch. Q/K/V tiles are MXU-aligned (block sizes
multiples of 128 recommended; head_dim is the lane dim). GQA is handled
in the k/v index maps (q head h reads kv head h // group_size), so no
repeated kv materialization. Causal and sliding-window masks are fused
(positions from broadcasted iota; queries right-aligned when Sq < Skv,
which is what chunked prefill produces).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 block_q: int, block_k: int, sq: int, skv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, D)
    k = k_ref[0].astype(jnp.float32)                 # (bk, D)
    v = v_ref[0].astype(jnp.float32)                 # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # positions (right-aligned queries)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (skv - sq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...][:, 0]                        # (bq,)
    l_prev = l_scr[...][:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    correction = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_cur = l_prev * correction + jnp.sum(p, axis=-1)

    acc = acc_scr[...] * correction[:, None]
    acc = acc + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    m_scr[...] = m_cur[:, None]
    l_scr[...] = l_cur[:, None]
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...][:, 0]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv)
    scale = scale if scale is not None else D ** -0.5

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, D)

    def kv_head(bh):
        return (bh // Hq) * Hkv + (bh % Hq) // G

    grid = (B * Hq, Sq // block_q, Skv // block_k)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          sq=Sq, skv=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (kv_head(bh), ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (kv_head(bh), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            # (m, l) carried as (block_q, 1) f32; acc (block_q, D) f32
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, D)
