"""Public jit'd wrapper for the flash-attention kernel.

On TPU this lowers to the Pallas kernel; elsewhere (or with
``interpret=True``) the kernel body is interpreted on CPU — used by the
allclose tests. The model layers call this through
``ModelConfig.attn_impl == "flash"``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interp)


__all__ = ["flash_attention", "attention_ref"]
