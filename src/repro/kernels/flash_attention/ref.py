"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None, scale: Optional[float] = None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). GQA via head grouping.
    Returns (B, Hq, Sq, D) in q.dtype; softmax in f32."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # right-aligned queries
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)
