"""Chunked SSD / gated-linear-attention scan, Pallas TPU.

Computes, per head, the linear recurrence

    h_t = exp(g_t) h_{t-1} + k_t v_t^T          (state: ds x hd)
    y_t = q_t^T h_t

in chunk-parallel form: within a chunk the contribution is an
attention-like masked matmul (MXU work); across chunks a small (ds, hd)
f32 state is carried in VMEM scratch over the sequential chunk grid
dimension. This is the inner loop of Mamba2 (q=C, k=B, v=dt*x,
g=dt*A) — the wrapper in ops.py does that mapping.

Grid: (B*nh, S/chunk), chunk axis innermost/sequential.
Blocks: q,k: (1, chunk, ds); v: (1, chunk, hd); g: (1, chunk).
The B/C group->head broadcast is folded into the k/q index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(g_ref, q_ref, k_ref, v_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    g = g_ref[...].astype(jnp.float32)               # (1, chunk)
    cum = jnp.cumsum(g, axis=1)[0]                   # (chunk,)
    seg = cum[-1]

    q = q_ref[0].astype(jnp.float32)                 # (chunk, ds)
    k = k_ref[0].astype(jnp.float32)                 # (chunk, ds)
    v = v_ref[0].astype(jnp.float32)                 # (chunk, hd)

    # inter-chunk: y_off = (q * exp(cum)) @ h_in
    h_in = h_scr[...]                                # (ds, hd)
    q_dec = q * jnp.exp(cum)[:, None]
    y = jax.lax.dot_general(q_dec, h_in, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk: y += (q k^T ∘ L) v, L_ij = exp(cum_i - cum_j), i >= j
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    y = y + jax.lax.dot_general(qk * L, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: h = exp(seg) h_in + (k * exp(seg - cum))^T v
    k_dec = k * jnp.exp(seg - cum)[:, None]
    h_new = h_in * jnp.exp(seg) + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    h_scr[...] = h_new

    y_ref[0] = y.astype(y_ref.dtype)


def linear_scan_fwd(g, q, k, v, *, chunk: int = 128,
                    interpret: bool = False):
    """g: (BH, S) log-decays; q,k: (BHG, S, ds) (group-shared);
    v: (BH, S, hd). BH = BHG * rep. Returns y (BH, S, hd)."""
    BH, S = g.shape
    BHG, _, ds = q.shape
    hd = v.shape[-1]
    rep = BH // BHG
    assert S % chunk == 0

    grid = (BH, S // chunk)

    def qk_map(bh, ci):
        return (bh // rep, ci, 0)

    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, ds), qk_map),
            pl.BlockSpec((1, chunk, ds), qk_map),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), v.dtype),
        scratch_shapes=[pltpu.VMEM((ds, hd), jnp.float32)],
        interpret=interpret,
    )(g, q, k, v)
    return out
