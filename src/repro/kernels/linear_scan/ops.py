"""Public wrapper: Mamba2/SSD over the generalized linear-scan kernel.

Mapping (see models/ssm.py): q=C, k=B (group-shared), v=dt*x, g=dt*A.
The D-skip, gating and projections stay in the model; this is only the
sequence-mixing hot loop.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan.kernel import linear_scan_fwd
from repro.kernels.linear_scan.ref import linear_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_scan(g, q, k, v, *, chunk: int = 128,
                interpret: Optional[bool] = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return linear_scan_fwd(g, q, k, v, chunk=chunk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_mamba2(x, dt, A, B, C, *, chunk: int = 128,
               interpret: Optional[bool] = None):
    """x: (Bt,S,nh,hd); dt: (Bt,S,nh) post-softplus; A: (nh,) negative;
    B,C: (Bt,S,g,ds). Returns y (Bt,S,nh,hd) — the SSD sequence mix
    (without the D-skip, added by the caller)."""
    Bt, S, nh, hd = x.shape
    g_grp = B.shape[2]
    ds = B.shape[-1]
    # fold dt into v; build log-decays
    v = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(Bt * nh, S, hd)
    gdec = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(Bt * nh, S)
    q = C.transpose(0, 2, 1, 3).reshape(Bt * g_grp, S, ds)
    k = B.transpose(0, 2, 1, 3).reshape(Bt * g_grp, S, ds)
    interp = (not _on_tpu()) if interpret is None else interpret
    y = linear_scan_fwd(gdec.astype(jnp.float32), q, k, v,
                        chunk=chunk, interpret=interp)
    return y.reshape(Bt, nh, S, hd).transpose(0, 2, 1, 3)


__all__ = ["linear_scan", "ssd_mamba2", "linear_scan_ref"]
