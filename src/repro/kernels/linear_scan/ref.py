"""Pure-jnp oracle for the chunked linear scan: the naive step-by-step
recurrence h_t = exp(g_t) h_{t-1} + k_t v_t^T ; y_t = q_t^T h_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(g, q, k, v):
    """g: (BH, S); q,k: (BHG, S, ds); v: (BH, S, hd) -> (BH, S, hd)."""
    BH, S = g.shape
    BHG = q.shape[0]
    rep = BH // BHG
    qf = jnp.repeat(q, rep, axis=0).astype(jnp.float32)
    kf = jnp.repeat(k, rep, axis=0).astype(jnp.float32)
    vf = v.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    ds, hd = qf.shape[-1], vf.shape[-1]

    def step(h, inp):
        gt, qt, kt, vt = inp
        h = jnp.exp(gt)[:, None, None] * h + jnp.einsum("bs,bd->bsd", kt, vt)
        y = jnp.einsum("bs,bsd->bd", qt, h)
        return h, y

    h0 = jnp.zeros((BH, ds, hd), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(gf, 1, 0),
                                    jnp.moveaxis(qf, 1, 0),
                                    jnp.moveaxis(kf, 1, 0),
                                    jnp.moveaxis(vf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype)
