# Virtual-device count for the dry-run compiles. No-clobber: a count
# already pinned in XLA_FLAGS (CI legs, the matrix harness, a caller)
# is respected; otherwise REPRO_HOST_DEVICES or the 512-chip default.
# Must run before the first jax backend touch, hence before imports.
from repro.launch.xla import ensure_host_platform_device_count
HOST_DEVICES = ensure_host_platform_device_count(default=512)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the AMB-DG train step (train shapes) or the
serve step (decode shapes) with full production shardings, lowers it
against ShapeDtypeStruct inputs (no allocation), compiles, and records:

  * memory_analysis()  — bytes per device (proves the cell fits HBM)
  * cost_analysis()    — FLOPs / bytes accessed (roofline compute+memory)
  * the collective byte count parsed from the optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — roofline's collective term.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as C
from repro.configs.base import (AmbdgConfig, MeshConfig, RunConfig,
                                ShapeConfig, SHAPES)
from repro.dist import (batch_specs, retree_specs, shapes_and_axes,
                        state_specs, to_shardings)
from repro.dist.sharding import spec_for
# the collective census lives in launch.hlo (no import side effects)
# so the benchmarks can use it without this module's forced device
# count; re-exported here for existing callers (benchmarks.roofline).
from repro.launch.hlo import collective_bytes  # noqa: F401
from repro.launch.mesh import make_mesh, mesh_config, mesh_label
from repro.models import build_model


# per-cell capacity overrides: deeper microbatching for the largest
# train cells (keeps activation residuals under the 16 GB v5e HBM)
CELL_OVERRIDES = {
    ("mixtral-8x22b", "train_4k"): {"n_microbatches": 16},
    ("paligemma-3b", "train_4k"): {"n_microbatches": 16},
    ("seamless-m4t-large-v2", "train_4k"): {"n_microbatches": 16},
}


def build_run_config(arch: str, shape_name: str, multi_pod: bool,
                     strategy: str = "ambdg", **overrides) -> RunConfig:
    for k, v in CELL_OVERRIDES.get((arch, shape_name), {}).items():
        overrides.setdefault(k, v)
    model_cfg = C.get_config(arch)
    if "model_cfg" in overrides:
        model_cfg = overrides.pop("model_cfg")
    shape = SHAPES[shape_name]
    ambdg = overrides.pop("ambdg", AmbdgConfig(
        tau=1, n_microbatches=overrides.pop("n_microbatches", 8)))
    mesh = overrides.pop("mesh", None) or mesh_config(multi_pod)
    return RunConfig(model=model_cfg, shape=shape,
                     mesh=mesh, ambdg=ambdg,
                     strategy=strategy,
                     remat=overrides.pop("remat", "dots"), **overrides)


def lower_train(rc: RunConfig, mesh):
    from repro import api
    model = build_model(rc.model)
    strategy = api.build(model, rc)
    init_state, train_step = strategy.init_state, strategy.train_step
    st_specs = state_specs(model, rc, init_state)
    b_specs = batch_specs(model, rc)
    state_shapes = jax.eval_shape(init_state, jax.random.PRNGKey(0))

    def shard_struct(specs, shapes):
        return jax.tree.map(
            lambda sp, sh: jax.ShapeDtypeStruct(
                sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
            specs, shapes, is_leaf=lambda x: isinstance(x, P))

    state_in = shard_struct(st_specs, state_shapes)
    batch_shapes = model.input_specs(rc.shape.global_batch, rc.shape.seq_len)
    if rc.delay.process != "fixed":
        # stochastic staleness: the host loop ships one delay draw per
        # step; the lowered program takes it as a replicated scalar
        batch_shapes = dict(batch_shapes,
                            delay=jax.ShapeDtypeStruct((), jnp.int32))
        b_specs = dict(b_specs, delay=P())
    if rc.batch_schedule.schedule != "fixed":
        # adaptive minibatch schedule: the host loop ships one target
        # draw per step; alpha takes it as a replicated f32 scalar
        batch_shapes = dict(batch_shapes,
                            b_sched=jax.ShapeDtypeStruct((), jnp.float32))
        b_specs = dict(b_specs, b_sched=P())
    batch_in = shard_struct(b_specs, batch_shapes)

    with mesh:
        # the output TrainState's structure differs from the input's in
        # static metadata (the arena's slot phase advances each step):
        # transplant the specs onto the output structure for
        # out_shardings (traced under the mesh: constrain() needs it).
        # Metrics are per-strategy (kbatch adds staleness, decentralized
        # consensus_error), so their spec tree comes from the same
        # abstract eval instead of a hardcoded key set.
        out_state_shapes, out_metrics_shapes = jax.eval_shape(
            train_step, state_shapes, batch_shapes)
        st_specs_out = retree_specs(st_specs, out_state_shapes)
        metrics_spec = jax.tree.map(lambda _: P(), out_metrics_shapes)
        jitted = jax.jit(
            train_step,
            in_shardings=(to_shardings(st_specs, mesh),
                          to_shardings(b_specs, mesh)),
            out_shardings=(to_shardings(st_specs_out, mesh),
                           to_shardings(metrics_spec, mesh)),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_in, batch_in)
    return lowered


def lower_serve(rc: RunConfig, mesh):
    """The continuous-batching decode step with a seq_len-deep cache:
    per-slot (B,) positions + the active-slot mask, exactly the
    program ``serve.engine`` jits at smoke scale."""
    from repro.serve.engine import continuous_decode_step
    model = build_model(rc.model)
    B, S = rc.shape.global_batch, rc.shape.seq_len

    cache_shapes, cache_axes = shapes_and_axes(
        lambda: model.init_decode_state(B, S))
    params_shapes, params_axes = shapes_and_axes(
        model.init, jax.random.PRNGKey(0))

    def resolve(ax, sh):
        return spec_for(tuple(ax), tuple(sh.shape), rc.mesh,
                        profile="serve")

    from repro.dist.sharding import _is_axes_leaf
    p_specs = jax.tree.map(resolve, params_axes, params_shapes,
                           is_leaf=_is_axes_leaf)
    c_specs = jax.tree.map(resolve, cache_axes, cache_shapes,
                           is_leaf=_is_axes_leaf)

    def shard_struct(specs, shapes):
        return jax.tree.map(
            lambda sp, sh: jax.ShapeDtypeStruct(
                sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
            specs, shapes, is_leaf=lambda x: isinstance(x, P))

    tok_spec = spec_for(("batch", None), (B, 1), rc.mesh,
                        profile="serve")
    row_spec = spec_for(("batch",), (B,), rc.mesh, profile="serve")
    serve_in = (
        shard_struct(p_specs, params_shapes),
        shard_struct(c_specs, cache_shapes),
        jax.ShapeDtypeStruct((B, 1), jnp.int32,
                             sharding=NamedSharding(mesh, tok_spec)),
        jax.ShapeDtypeStruct((B,), jnp.int32,
                             sharding=NamedSharding(mesh, row_spec)),
        jax.ShapeDtypeStruct((B,), jnp.bool_,
                             sharding=NamedSharding(mesh, row_spec)),
    )

    def serve_step(params, cache, tokens, pos, active):
        from repro.dist.context import sharding_profile
        with sharding_profile(rc.mesh, "serve"):
            return continuous_decode_step(model.decode_step, params,
                                          cache, tokens, pos, active)

    with mesh:
        jitted = jax.jit(
            serve_step,
            in_shardings=tuple(jax.tree.map(
                lambda s: s.sharding, x) for x in serve_in),
            out_shardings=(NamedSharding(mesh, row_spec),
                           to_shardings(c_specs, mesh)),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(*serve_in)
    return lowered


def lower_publish_pop(rc: RunConfig, mesh):
    """The server side of the weight-publication channel at production
    shape: dequantize one popped int8 snapshot (per-row bf16 scales)
    and unflatten it back to the sharded serve-profile parameter tree
    — the program an inference pod runs on every ``refresh_weights``.
    Shape depends only on the arch (ring depth is host metadata)."""
    from repro.core import arena as arena_mod
    from repro.optim.compression import dequantize_int8_rows
    model = build_model(rc.model)
    params_shapes, params_axes = shapes_and_axes(
        model.init, jax.random.PRNGKey(0))
    layout = arena_mod.make_layout(params_shapes)
    rows = layout.rows

    from repro.dist.sharding import _is_axes_leaf
    p_specs = jax.tree.map(
        lambda ax, sh: spec_for(tuple(ax), tuple(sh.shape), rc.mesh,
                                profile="serve"),
        params_axes, params_shapes, is_leaf=_is_axes_leaf)
    q_spec = spec_for(("flat", None), (rows, 128), rc.mesh,
                      profile="serve")
    s_spec = spec_for(("flat",), (rows,), rc.mesh, profile="serve")

    def pop(q, s):
        from repro.dist.context import sharding_profile
        with sharding_profile(rc.mesh, "serve"):
            w = dequantize_int8_rows(q, s)
            return arena_mod.unflatten_tree(layout, w, cast=True)

    pop_in = (
        jax.ShapeDtypeStruct((rows, 128), jnp.int8,
                             sharding=NamedSharding(mesh, q_spec)),
        jax.ShapeDtypeStruct((rows,), jnp.bfloat16,
                             sharding=NamedSharding(mesh, s_spec)),
    )
    with mesh:
        jitted = jax.jit(
            pop,
            in_shardings=tuple(x.sharding for x in pop_in),
            out_shardings=to_shardings(p_specs, mesh),
        )
        lowered = jitted.lower(*pop_in)
    return lowered


def resolve_cell_rc(arch: str, shape_name: str, multi_pod: bool,
                    rc: Optional[RunConfig] = None,
                    strategy: str = "ambdg",
                    gossip_compression: str = "none",
                    delay_process: str = "fixed",
                    tau_max: Optional[int] = None,
                    batch_schedule: str = "fixed",
                    mesh: Optional[MeshConfig] = None) -> RunConfig:
    """The cell's RunConfig from the CLI-style knobs (split out of
    ``run_cell`` so the override semantics are testable without a
    compile).

    ``tau_max`` is an EXPLICIT-ONLY override: ``None`` (the default)
    keeps an explicit ``rc``'s own ``rc.delay.tau_max`` (falling back
    to 4 only when that is itself unset), while any integer — zero
    included — is used verbatim.  The pre-PR-10 ``tau_max or
    rc.delay.tau_max or 4`` treated a caller's explicit 0 as "unset"
    and silently replaced a configured cap with the default.
    """
    if rc is None:
        overrides = {}
        if mesh is not None:
            overrides["mesh"] = mesh
        if gossip_compression != "none":
            from repro.configs.base import ConsensusConfig
            overrides["consensus"] = ConsensusConfig(
                compression=gossip_compression)
        if delay_process != "fixed":
            from repro.configs.base import DelayConfig
            overrides["delay"] = DelayConfig(
                process=delay_process,
                tau_max=4 if tau_max is None else tau_max)
        if batch_schedule != "fixed":
            from repro.configs.base import BatchScheduleConfig
            overrides["batch_schedule"] = BatchScheduleConfig(
                schedule=batch_schedule)
        return build_run_config(arch, shape_name, multi_pod,
                                strategy=strategy, **overrides)
    if mesh is not None:
        rc = rc.replace(mesh=mesh)
    if gossip_compression != "none":
        # an explicit rc must not silently shadow the knob
        rc = rc.replace(consensus=dataclasses.replace(
            rc.consensus, compression=gossip_compression))
    if delay_process != "fixed":
        # replace, not a fresh DelayConfig: the caller's other
        # delay fields (delay_min, seeding, adaptive_alpha) must
        # not silently reset to defaults
        resolved = (tau_max if tau_max is not None
                    else rc.delay.tau_max or 4)
        rc = rc.replace(delay=dataclasses.replace(
            rc.delay, process=delay_process, tau_max=resolved))
    if batch_schedule != "fixed":
        rc = rc.replace(batch_schedule=dataclasses.replace(
            rc.batch_schedule, schedule=batch_schedule))
    return rc


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rc: Optional[RunConfig] = None, verbose: bool = True,
             strategy: str = "ambdg",
             gossip_compression: str = "none",
             delay_process: str = "fixed",
             tau_max: Optional[int] = None,
             batch_schedule: str = "fixed",
             mesh_cfg: Optional[MeshConfig] = None,
             want_hlo: bool = False) -> Dict:
    rc = resolve_cell_rc(arch, shape_name, multi_pod, rc=rc,
                         strategy=strategy,
                         gossip_compression=gossip_compression,
                         delay_process=delay_process, tau_max=tau_max,
                         batch_schedule=batch_schedule, mesh=mesh_cfg)
    mesh = make_mesh(rc.mesh)
    t0 = time.time()
    publish_pop = None
    if rc.shape.kind in ("train", "prefill"):
        # prefill cost ~ the forward of the train step; we lower the
        # train step for train_4k and a loss-less forward for prefill
        lowered = (lower_train(rc, mesh) if rc.shape.kind == "train"
                   else lower_prefill(rc, mesh))
    else:
        lowered = lower_serve(rc, mesh)
        # decode cells also compile the per-refresh publish pop
        # (dequantize + unflatten at the serve shardings) — the other
        # half of the train-while-serve channel on this mesh
        pp = lower_publish_pop(rc, mesh).compile()
        pp_cost = pp.cost_analysis()
        if isinstance(pp_cost, (list, tuple)):
            pp_cost = pp_cost[0] if pp_cost else {}
        pp_text = pp.as_text()
        publish_pop = {
            "flops": float(pp_cost.get("flops", -1)),
            "bytes_accessed": float(pp_cost.get("bytes accessed", -1)),
            "collectives": collective_bytes(pp_text),
        }
        if want_hlo:
            publish_pop["hlo_text"] = pp_text
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    # which master delay-ring path this cell lowered with: v2 per-slot
    # ring everywhere; "pallas_sharded" = the shard_map'd fused kernel
    # (multi-pod TPU), "pallas" = single-pod TPU, "ref" = XLA (CPU)
    from repro.core import arena as arena_mod
    from repro.dist.context import sharding_profile
    from repro.kernels import resolve_impl
    with mesh, sharding_profile(rc.mesh if rc.mesh.n_devices > 1 else None):
        ring_impl = resolve_impl("auto", pod_shard_map=True)
    result = {
        "arch": arch, "shape": shape_name,
        # derived from the cell's ACTUAL mesh — an explicit rc with a
        # custom mesh used to be labeled 16x16/2x16x16 regardless
        "mesh": mesh_label(rc.mesh),
        "strategy": rc.strategy,
        "master": {"ring_version": arena_mod.RING_VERSION,
                   "ring_impl": ring_impl,
                   # delay-tolerant ring cells read all tau_max+1 slots
                   # per step (masked fold) instead of one static slot
                   "delay_process": rc.delay.process,
                   "tau_max": rc.delay.tau_max,
                   # adaptive b(t) cells take one extra replicated f32
                   # scalar (batch["b_sched"]) that alpha consumes
                   "batch_schedule": rc.batch_schedule.schedule},
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if publish_pop is not None:
        result["publish_pop"] = publish_pop
    if want_hlo:
        # the matrix runner's HLO invariants read the optimized text;
        # callers must pop this before serializing the row
        result["hlo_text"] = hlo_text
    if verbose:
        printable = {k: v for k, v in result.items() if k != "hlo_text"}
        if want_hlo and publish_pop is not None:
            printable["publish_pop"] = {
                k: v for k, v in publish_pop.items() if k != "hlo_text"}
        print(json.dumps(printable))
    return result


def lower_prefill(rc: RunConfig, mesh):
    """Prefill = full-sequence forward producing last-position logits +
    (implicitly) the cache; we lower the forward pass at the prefill
    shape — the compute/memory-dominant piece."""
    model = build_model(rc.model)
    B, S = rc.shape.global_batch, rc.shape.seq_len

    def fwd(params, batch):
        from repro.dist.context import sharding_profile
        with sharding_profile(rc.mesh):
            loss_sum, aux = model.loss(params, batch)
        return loss_sum  # forward dominates; keeps one program per cell

    # prefill has no labels/backward: lower loss forward only via
    # jax.eval_shape-compatible wrapper (no grad)
    params_shapes, params_axes = shapes_and_axes(
        model.init, jax.random.PRNGKey(0))
    from repro.dist.sharding import _is_axes_leaf
    p_specs = jax.tree.map(
        lambda ax, sh: spec_for(tuple(ax), tuple(sh.shape), rc.mesh),
        params_axes, params_shapes, is_leaf=_is_axes_leaf)
    b_specs = batch_specs(model, rc)
    batch_shapes = model.input_specs(B, S)

    def shard_struct(specs, shapes):
        return jax.tree.map(
            lambda sp, sh: jax.ShapeDtypeStruct(
                sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
            specs, shapes, is_leaf=lambda x: isinstance(x, P))

    with mesh:
        jitted = jax.jit(
            fwd,
            in_shardings=(to_shardings(p_specs, mesh),
                          to_shardings(b_specs, mesh)),
            out_shardings=NamedSharding(mesh, P()),
        )
        lowered = jitted.lower(shard_struct(p_specs, params_shapes),
                               shard_struct(b_specs, batch_shapes))
    return lowered


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="ambdg",
                    help="algorithm variant to lower (Strategy registry)")
    ap.add_argument("--gossip-compression", default="none",
                    choices=("none", "int8"),
                    help="decentralized: gossip message compression")
    ap.add_argument("--delay-process", default="fixed",
                    choices=("fixed", "jitter", "heavy_tail", "bursty"),
                    help="lower the ambdg cells with the delay-tolerant "
                         "ring for this stochastic staleness process")
    ap.add_argument("--tau-max", type=int, default=None,
                    help="staleness cap for --delay-process (explicit "
                         "values — 0 included — are used verbatim; "
                         "default: the cell's configured cap, else 4)")
    ap.add_argument("--mesh", default=None,
                    help="mesh shape spec (DxM or PxDxM, e.g. 8x8 or "
                         "2x16x16); default: the production mesh "
                         "(16x16, or 2x16x16 with --multi-pod)")
    ap.add_argument("--batch-schedule", default="fixed",
                    choices=("fixed", "linear", "adadamp", "delay_aware"),
                    help="lower the train cells with the adaptive "
                         "minibatch schedule input (b_sched scalar)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in C.ARCH_IDS:
            for shape in C.applicable_shapes(arch):
                cells.append((arch, shape.name))
    else:
        cells.append((args.arch, args.shape))

    mesh_cfg = None
    if args.mesh is not None:
        from repro.launch.mesh import parse_mesh
        mesh_cfg = parse_mesh(args.mesh)

    results, failures = [], []
    for arch, shape in cells:
        try:
            results.append(run_cell(
                arch, shape, args.multi_pod, strategy=args.strategy,
                gossip_compression=args.gossip_compression,
                delay_process=args.delay_process, tau_max=args.tau_max,
                batch_schedule=args.batch_schedule, mesh_cfg=mesh_cfg))
        except Exception as e:  # noqa: BLE001
            failures.append({"arch": arch, "shape": shape,
                             "error": repr(e)[:500]})
            print(f"FAIL {arch} {shape}: {e!r}", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"results": results, "failures": failures}, f,
                      indent=1)
    print(f"\n{len(results)} cells OK, {len(failures)} failed "
          f"({'multi-pod' if args.multi_pod else 'single-pod'})")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
