"""Optimized-HLO text probes shared by the benchmarks and the tests:
the donation/aliasing ``copy`` census and the collective wire-byte
census (used by the dry-run's roofline collective term and the
gossip-bytes benchmark).

``copy`` instructions in a compiled executable are the aliasing /
copy-protection traffic the arena's donation contract exists to drive
to zero (docs/arena.md): the master-update benchmark reports their
bytes per step, and tests/test_arena.py asserts the ring layout v2
master update compiles without any ring-dtype copies. One parser
serves both so a change in XLA's HLO text format cannot silently rot
the detector on one side only (the test keeps a compiled-v1 positive
control pointed at it).
"""
from __future__ import annotations

import re
from typing import Dict, Iterator


class HloParseError(ValueError):
    """A census probe hit HLO text it cannot account for (strict mode).

    The non-strict censuses degrade softly — an unparsed
    ``replica_groups`` counts as group size 1, an unknown dtype as 0
    bytes — which is fine for a human-facing report but silently
    DEFLATES the numbers the matrix invariants compare against the
    analytic wire model. Strict mode (used by the matrix runner and
    the tests) raises instead."""

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                "u32": 4, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _copy_result_shapes(hlo_text: str):
    """Yield (dtype, dims-string) for every result tensor of a copy /
    copy-start instruction in optimized HLO text."""
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls or (" copy(" not in ls
                               and " copy-start(" not in ls):
            continue
        # result type(s) sit between '=' and the op name
        head = ls.split(" = ", 1)[1]
        head = head[:head.index("copy")]
        yield from _SHAPE_RE.findall(head)


def copy_shapes(hlo_text: str) -> Dict[str, int]:
    """``"dtype[dims]" -> count`` over all copy instructions."""
    out: Dict[str, int] = {}
    for dt, dims in _copy_result_shapes(hlo_text):
        key = f"{dt}[{dims}]"
        out[key] = out.get(key, 0) + 1
    return out


def _shape_bytes(text: str) -> int:
    """Total tensor bytes of every typed shape in an HLO text fragment
    (unknown dtype tokens skipped) — the ONE dims-product parser both
    censuses below share, per the module rationale."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def copy_bytes(hlo_text: str) -> int:
    """Total bytes written by copy instructions."""
    return sum(_shape_bytes(f"{dt}[{dims}]")
               for dt, dims in _copy_result_shapes(hlo_text))


_META_RE = re.compile(
    r'source_file="([^"]+)"\s+source_line=(\d+)')
_OPNAME_RE = re.compile(r'op_name="([^"]+)"')


def copy_records(hlo_text: str) -> Iterator[Dict]:
    """One record per copy / copy-start instruction:
    ``{"key": "dtype[dims]", "bytes": int, "op_name": str|None,
    "source_file": str|None, "source_line": int|None}`` — the metadata
    fields come from the instruction's op metadata (the jax op path /
    source location that produced it, or the parameter name for pure
    layout copies of an input), letting a caller attribute a copy to
    the code path or buffer it came from.  Used by the matrix runner's
    ring-copy invariant to separate ring-buffer copies (contract
    violations) from the known staging-fill / residual-slice layout
    copies (see docs/matrix.md)."""
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls or (" copy(" not in ls
                               and " copy-start(" not in ls):
            continue
        head = ls.split(" = ", 1)[1]
        head = head[:head.index("copy")]
        m = _META_RE.search(ls)
        op = _OPNAME_RE.search(ls)
        src = m.group(1) if m else None
        src_line = int(m.group(2)) if m else None
        opener = " copy-start(" if " copy-start(" in ls else " copy("
        i = ls.index(opener) + len(opener)
        j = ls.find(")", i)
        operand = ls[i:j] if j != -1 else ls[i:]
        for dt, dims in _SHAPE_RE.findall(head):
            yield {"key": f"{dt}[{dims}]",
                   "bytes": _shape_bytes(f"{dt}[{dims}]"),
                   "op_name": op.group(1) if op else None,
                   "operand": operand,
                   "source_file": src, "source_line": src_line}


# ---------------------------------------------------------------------------
# Collective wire-byte census (shared by the dry-run, roofline and the
# gossip-bytes benchmark — one parser, same rationale as the copy probe)
# ---------------------------------------------------------------------------
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _group_size(line: str, strict: bool = False) -> int:
    """Participants per replica group of a collective.

    Handles the iota form ``replica_groups=[n_groups,group_size]<=...``
    (with or without a trailing transpose suffix ``T(...)``) and the
    explicit form ``replica_groups={{0,1,...},...}``.  Non-strict, any
    other string returns 1 — which silently DEFLATES the census (an
    n-participant all-reduce counted as wire-free).  ``strict=True``
    raises ``HloParseError`` instead; a ``collective-permute`` line
    carrying ``source_target_pairs=`` legitimately has no replica
    groups (its wire model does not need a group size) and is exempt.
    """
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:  # iota form: [n_groups, group_size]
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    if strict and "source_target_pairs=" not in line:
        raise HloParseError(
            "unrecognized replica_groups format (an empty "
            "replica_groups={} carries no group size): "
            + line.strip()[:300])
    return 1


def _collective_lines(hlo_text: str):
    """Yield ``(op, result_region, line)`` per collective instruction."""
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        base, pos = None, -1
        for op in COLLECTIVES:
            for suffix in ("(", "-start("):
                i = ls.find(" " + op + suffix)
                if i != -1:
                    base, pos = op, i
                    break
            if base:
                break
        if base is None:
            continue
        # result type(s): between '=' and the op name
        yield base, ls[ls.index(" = ") + 3:pos], ls


def _wire_bytes(op: str, p_bytes: int, n: int) -> int:
    if op == "all-reduce":
        return 2 * (n - 1) * p_bytes // max(n, 1)
    if op == "all-gather":
        return (n - 1) * p_bytes // max(n, 1)
    if op == "reduce-scatter":
        return (n - 1) * p_bytes  # result * n * (n-1)/n
    if op == "all-to-all":
        return (n - 1) * p_bytes // max(n, 1)
    return p_bytes  # collective-permute


def collective_bytes(hlo_text: str, strict: bool = False) -> Dict[str, int]:
    """Per-device wire bytes per collective type, from optimized HLO.

    Ring-algorithm per-device traffic for payload P over n participants:
      all-reduce      2 (n-1)/n * P      (P = result bytes)
      all-gather      (n-1)/n * P        (P = result/gathered bytes)
      reduce-scatter  (n-1)/n * P_in     (P_in = result * n)
      all-to-all      (n-1)/n * P
      collective-permute  P

    Instructions inside a called computation (e.g. a scan's while body)
    are counted ONCE — for a scanned gossip round the census is
    per-round wire bytes, independent of the round count.

    ``strict=True`` (the matrix runner / test mode) raises
    ``HloParseError`` on an unrecognized ``replica_groups`` format and
    on a collective whose result-shape region parses to 0 bytes (an
    unknown dtype token, or a format drift that moved the shapes) —
    both would silently deflate the census the invariants compare
    against the analytic wire model.
    """
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for base, region, ls in _collective_lines(hlo_text):
        p_bytes = _shape_bytes(region)
        if strict and p_bytes == 0:
            raise HloParseError(
                f"collective result-shape region parsed to 0 bytes: "
                + ls[:300])
        n = max(_group_size(ls, strict=strict), 1)
        out[base] += _wire_bytes(base, p_bytes, n)
        out["count"] += 1
    return out


def collective_bytes_by_dtype(hlo_text: str,
                              strict: bool = False) -> Dict[str, int]:
    """Per-device wire bytes per payload DTYPE, summed over all
    collective types (same ring formulas and strictness as
    ``collective_bytes``).  The matrix runner's compressed-DCN-edge
    invariant reads this census: with int8 compression on, the only
    non-``s8`` wire bytes an exchange program may move are its per-row
    scales."""
    out: Dict[str, int] = {}
    for base, region, ls in _collective_lines(hlo_text):
        n = max(_group_size(ls, strict=strict), 1)
        total = 0
        for dt, dims in _SHAPE_RE.findall(region):
            b = _shape_bytes(f"{dt}[{dims}]")
            total += b
            if b:
                out[dt] = out.get(dt, 0) + _wire_bytes(base, b, n)
        if strict and total == 0:
            raise HloParseError(
                f"collective result-shape region parsed to 0 bytes: "
                + ls[:300])
    return out
