"""Optimized-HLO text probes shared by the benchmarks and the tests:
the donation/aliasing ``copy`` census and the collective wire-byte
census (used by the dry-run's roofline collective term and the
gossip-bytes benchmark).

``copy`` instructions in a compiled executable are the aliasing /
copy-protection traffic the arena's donation contract exists to drive
to zero (docs/arena.md): the master-update benchmark reports their
bytes per step, and tests/test_arena.py asserts the ring layout v2
master update compiles without any ring-dtype copies. One parser
serves both so a change in XLA's HLO text format cannot silently rot
the detector on one side only (the test keeps a compiled-v1 positive
control pointed at it).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                "u32": 4, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _copy_result_shapes(hlo_text: str):
    """Yield (dtype, dims-string) for every result tensor of a copy /
    copy-start instruction in optimized HLO text."""
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls or (" copy(" not in ls
                               and " copy-start(" not in ls):
            continue
        # result type(s) sit between '=' and the op name
        head = ls.split(" = ", 1)[1]
        head = head[:head.index("copy")]
        yield from _SHAPE_RE.findall(head)


def copy_shapes(hlo_text: str) -> Dict[str, int]:
    """``"dtype[dims]" -> count`` over all copy instructions."""
    out: Dict[str, int] = {}
    for dt, dims in _copy_result_shapes(hlo_text):
        key = f"{dt}[{dims}]"
        out[key] = out.get(key, 0) + 1
    return out


def _shape_bytes(text: str) -> int:
    """Total tensor bytes of every typed shape in an HLO text fragment
    (unknown dtype tokens skipped) — the ONE dims-product parser both
    censuses below share, per the module rationale."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def copy_bytes(hlo_text: str) -> int:
    """Total bytes written by copy instructions."""
    return sum(_shape_bytes(f"{dt}[{dims}]")
               for dt, dims in _copy_result_shapes(hlo_text))


# ---------------------------------------------------------------------------
# Collective wire-byte census (shared by the dry-run, roofline and the
# gossip-bytes benchmark — one parser, same rationale as the copy probe)
# ---------------------------------------------------------------------------
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _group_size(line: str) -> int:
    """Participants per replica group of a collective."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:  # iota form: [n_groups, group_size]
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes per collective type, from optimized HLO.

    Ring-algorithm per-device traffic for payload P over n participants:
      all-reduce      2 (n-1)/n * P      (P = result bytes)
      all-gather      (n-1)/n * P        (P = result/gathered bytes)
      reduce-scatter  (n-1)/n * P_in     (P_in = result * n)
      all-to-all      (n-1)/n * P
      collective-permute  P

    Instructions inside a called computation (e.g. a scan's while body)
    are counted ONCE — for a scanned gossip round the census is
    per-round wire bytes, independent of the round count.
    """
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        base, pos = None, -1
        for op in COLLECTIVES:
            for suffix in ("(", "-start("):
                i = ls.find(" " + op + suffix)
                if i != -1:
                    base, pos = op, i
                    break
            if base:
                break
        if base is None:
            continue
        # result type(s): between '=' and the op name
        p_bytes = _shape_bytes(ls[ls.index(" = ") + 3:pos])
        n = max(_group_size(ls), 1)
        if base == "all-reduce":
            wire = 2 * (n - 1) * p_bytes // max(n, 1)
        elif base == "all-gather":
            wire = (n - 1) * p_bytes // max(n, 1)
        elif base == "reduce-scatter":
            wire = (n - 1) * p_bytes  # result * n * (n-1)/n
        elif base == "all-to-all":
            wire = (n - 1) * p_bytes // max(n, 1)
        else:  # collective-permute
            wire = p_bytes
        out[base] += wire
        out["count"] += 1
    return out
