"""Optimized-HLO text probes shared by the benchmarks and the tests.

``copy`` instructions in a compiled executable are the aliasing /
copy-protection traffic the arena's donation contract exists to drive
to zero (docs/arena.md): the master-update benchmark reports their
bytes per step, and tests/test_arena.py asserts the ring layout v2
master update compiles without any ring-dtype copies. One parser
serves both so a change in XLA's HLO text format cannot silently rot
the detector on one side only (the test keeps a compiled-v1 positive
control pointed at it).
"""
from __future__ import annotations

import re
from typing import Dict

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|s8|u8|pred)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                "s8": 1, "u8": 1, "pred": 1}


def _copy_result_shapes(hlo_text: str):
    """Yield (dtype, dims-string) for every result tensor of a copy /
    copy-start instruction in optimized HLO text."""
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls or (" copy(" not in ls
                               and " copy-start(" not in ls):
            continue
        # result type(s) sit between '=' and the op name
        head = ls.split(" = ", 1)[1]
        head = head[:head.index("copy")]
        yield from _SHAPE_RE.findall(head)


def copy_shapes(hlo_text: str) -> Dict[str, int]:
    """``"dtype[dims]" -> count`` over all copy instructions."""
    out: Dict[str, int] = {}
    for dt, dims in _copy_result_shapes(hlo_text):
        key = f"{dt}[{dims}]"
        out[key] = out.get(key, 0) + 1
    return out


def copy_bytes(hlo_text: str) -> int:
    """Total bytes written by copy instructions."""
    total = 0
    for dt, dims in _copy_result_shapes(hlo_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total
