# Virtual-device count for this process's cells. No-clobber: a count
# already pinned in XLA_FLAGS (a CI leg, the sweep's subprocess env)
# wins; REPRO_HOST_DEVICES injects one; the bare default covers the
# smallest cell group. One process = one count (XLA reads the flag
# once), hence benchmarks/matrix_sweep.py runs one subprocess per
# device-count group. Must run before the first jax backend touch.
from repro.launch.xla import ensure_host_platform_device_count
HOST_DEVICES = ensure_host_platform_device_count(default=64)

"""Scenario-matrix scale harness (docs/matrix.md).

One runner enumerating cells of

    strategy x model config x delay process x compression x mesh shape

at 8-512 virtual devices, reusing ``launch.dryrun.run_cell`` (which
reuses ``lower_train`` / ``lower_serve`` / ``lower_publish_pop``) for
the full-step lowering and metrics, and asserting three HLO-level
invariants per cell — not just "it compiled":

  A. zero ring-dtype copy instructions (the arena donation contract of
     docs/arena.md); the known staging-fill layout copies are
     attributed via HLO source metadata and REPORTED, not hidden (see
     docs/matrix.md — the finding this harness flushed out);
  B. compressed DCN edges: with int8 on, the exchange program's only
     non-s8 wire bytes are the per-row scales;
  C. the strict ``collective_bytes`` census of the cell's exchange
     program == the closed-form wire model (``launch.wire_model``),
     exactly, per dtype.

Usage (device count must equal each cell's mesh size — the sweep
groups cells per count and spawns one subprocess per group):

  PYTHONPATH=src REPRO_HOST_DEVICES=64 python -m repro.launch.matrix \
      --devices 64 --all --json out.json
  PYTHONPATH=src python -m repro.launch.matrix --list
"""
import argparse
import inspect
import json
import sys
import time
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

import repro.configs as C
from repro.configs.base import (AmbdgConfig, ConsensusConfig, DelayConfig,
                                RunConfig, ShapeConfig)
from repro.core import arena as arena_mod
from repro.core import consensus
from repro.dist import shapes_and_axes
from repro.launch import dryrun
from repro.launch import wire_model
from repro.launch.hlo import (collective_bytes, collective_bytes_by_dtype,
                              copy_bytes, copy_records, copy_shapes)
from repro.launch.mesh import mesh_label, parse_mesh
from repro.models import build_model

# Matrix smoke shapes: small enough that every big-config smoke
# variant lowers+compiles in seconds at 512 virtual devices, large
# enough that every mesh axis divides the batch.
MATRIX_TRAIN = ShapeConfig("matrix_train_smoke", 128, 64, "train")
MATRIX_DECODE = ShapeConfig("matrix_decode_smoke", 512, 64, "decode")

GOSSIP_ROUNDS = 2   # census is per-round (scan body), r only pads compile


@dataclass(frozen=True)
class MatrixCell:
    name: str
    arch: str               # smoke-config id (C.get_smoke_config)
    mesh: str               # parse_mesh spec; prod == device count
    strategy: str = "ambdg"
    kind: str = "train"     # "train" | "decode"
    tau: int = 1
    delay_process: str = "fixed"
    tau_max: Optional[int] = None      # explicit-only (dryrun contract)
    pod_compression: str = "none"      # master DCN compression
    gossip_compression: str = "none"   # decentralized wire compression
    topology: str = "ring"
    n_workers: int = 8
    n_microbatches: int = 2

    @property
    def devices(self) -> int:
        cfg = parse_mesh(self.mesh)
        return cfg.n_devices


# The default matrix. Axes covered: 4 strategies, 7 big-config smoke
# variants, 3 delay processes, both compression modes (master int8 DCN
# + gossip int8), 8 mesh shapes at 8/64/128/512 virtual devices.
# NOTE int8 pod compression is only paired with the FIXED delay
# process: the delay-tolerant (v3) ring folds int8 locally and ships
# one f32 psum across DCN, so a compressed-DCN-edge invariant on a
# variable-delay cell is unsatisfiable by construction (docs/matrix.md).
CELLS = (
    # -- 8 devices: the cheap CI-smoke group --------------------------
    MatrixCell("m8-ambdg-qwen15-2x2x2-int8", "qwen1.5-0.5b", "2x2x2",
               tau=1, pod_compression="int8"),
    MatrixCell("m8-decentralized-xlstm-2x4-int8", "xlstm-125m", "2x4",
               strategy="decentralized", n_workers=8,
               gossip_compression="int8"),
    # -- 64 devices ---------------------------------------------------
    MatrixCell("m64-ambdg-mixtral8x22b-2x4x8-f32", "mixtral-8x22b",
               "2x4x8", tau=1),
    MatrixCell("m64-amb-chatglm-2x4x8", "chatglm3-6b", "2x4x8",
               strategy="amb"),
    MatrixCell("m64-kbatch-zamba2-8x8", "zamba2-2.7b", "8x8",
               strategy="kbatch"),
    MatrixCell("m64-decentralized-xlstm-8x8-f32", "xlstm-125m", "8x8",
               strategy="decentralized", n_workers=8),
    MatrixCell("m64-decentralized-xlstm-8x8-int8", "xlstm-125m", "8x8",
               strategy="decentralized", n_workers=8,
               gossip_compression="int8"),
    MatrixCell("m64-ambdg-seamless-2x4x8-int8", "seamless-m4t-large-v2",
               "2x4x8", tau=2, pod_compression="int8"),
    MatrixCell("m64-ambdg-qwen3-2x4x8-jitter", "qwen3-1.7b", "2x4x8",
               delay_process="jitter", tau_max=4),
    # -- 128 devices --------------------------------------------------
    MatrixCell("m128-ambdg-chatglm-2x8x8-int8", "chatglm3-6b", "2x8x8",
               tau=2, pod_compression="int8"),
    MatrixCell("m128-ambdg-mixtral8x22b-2x8x8-heavytail",
               "mixtral-8x22b", "2x8x8", delay_process="heavy_tail",
               tau_max=6),
    MatrixCell("m128-kbatch-seamless-2x8x8", "seamless-m4t-large-v2",
               "2x8x8", strategy="kbatch"),
    MatrixCell("m128-serve-zamba2-16x8", "zamba2-2.7b", "16x8",
               kind="decode"),
    MatrixCell("m128-decentralized-qwen15-8x16-torus-int8",
               "qwen1.5-0.5b", "8x16", strategy="decentralized",
               topology="torus", n_workers=16,
               gossip_compression="int8"),
    # -- 512 devices: the production multi-pod shape ------------------
    MatrixCell("m512-ambdg-chatglm-2x16x16-int8", "chatglm3-6b",
               "2x16x16", tau=2, pod_compression="int8"),
    MatrixCell("m512-ambdg-seamless-2x16x16-bursty",
               "seamless-m4t-large-v2", "2x16x16",
               delay_process="bursty", tau_max=4),
)

CELLS_BY_NAME = {c.name: c for c in CELLS}


def build_cell_rc(cell: MatrixCell) -> RunConfig:
    """The cell's RunConfig on its SMOKE model config (the big-config
    smoke variants are the whole point: nothing else exercises them
    end-to-end)."""
    shape = MATRIX_TRAIN if cell.kind == "train" else MATRIX_DECODE
    tau = 0 if cell.strategy in ("amb", "kbatch") else cell.tau
    rc = RunConfig(
        model=C.get_smoke_config(cell.arch),
        shape=shape,
        mesh=parse_mesh(cell.mesh),
        strategy=cell.strategy,
        ambdg=AmbdgConfig(tau=tau, n_microbatches=cell.n_microbatches,
                          pod_compression=cell.pod_compression),
        consensus=ConsensusConfig(topology=cell.topology,
                                  n_workers=cell.n_workers,
                                  compression=cell.gossip_compression),
    )
    if cell.delay_process != "fixed":
        rc = rc.replace(delay=DelayConfig(process=cell.delay_process,
                                          tau_max=cell.tau_max))
    return rc


def _arena_rows(rc: RunConfig) -> int:
    model = build_model(rc.model)
    params_shapes, _ = shapes_and_axes(model.init, jax.random.PRNGKey(0))
    return arena_mod.make_layout(params_shapes).rows


# ---------------------------------------------------------------------------
# Invariant A: zero ring-dtype copies (docs/arena.md donation contract)
# ---------------------------------------------------------------------------
def _staging_fill_spans():
    """Source-line spans of the arena staging fill (``flatten_tree`` /
    ``scatter_fed``): per-leaf row-offset update-slices that GSPMD
    cannot keep row-sharded at scale, producing layout copies on
    STAGING-shaped tensors. Computed via ``inspect`` so the allowlist
    tracks the code instead of hardcoded line numbers."""
    spans = []
    for fn in (arena_mod.flatten_tree, arena_mod.scatter_fed):
        src, start = inspect.getsourcelines(fn)
        spans.append((start, start + len(src)))
    return spans


def _attribute_copy(rec: Dict, spans) -> Optional[str]:
    """Attribute a copy to one of the KNOWN per-leaf-slicing classes
    (docs/matrix.md — the finding this harness filed), or None if it
    is unaccounted for:

    ``staging_fill``    layout copies whose source line sits inside
        ``arena.flatten_tree`` / ``arena.scatter_fed`` — the per-leaf
        unaligned row-offset update-slices on the f32 staging buffer —
        or metadata-less copies of a staging-fill fusion's result
        (XLA drops op metadata on copies it inserts at fusion
        boundaries; the producing fusion's name still carries the
        dynamic-update-slice root).
    ``residual_slice``  pure layout copies of the error-feedback
        buffer (parameter op_name ``state.arena.residual``, no source
        line) that XLA inserts before the same per-leaf unaligned
        slices read the residual.  Only the residual parameter is
        exempted — a failed donation of the ring/slot buffers would
        surface under its own ``state.arena.*`` name and still FAIL.
    """
    f, ln = rec.get("source_file"), rec.get("source_line")
    if f and ln is not None and f.endswith("core/arena.py") \
            and any(lo <= ln < hi for lo, hi in spans):
        return "staging_fill"
    if rec.get("op_name") == "state.arena.residual":
        return "residual_slice"
    if (rec.get("op_name") is None
            and "dynamic-update-slice_fusion" in (rec.get("operand") or "")):
        return "staging_fill"
    return None


def _ring_param_aliases(hlo_text: str):
    """Instruction names that ARE the ring parameter, transitively
    through pure same-shape copy chains: the ``state.arena.ring``
    entry parameter and every ``copy`` of it (or of such a copy).
    The matrix's VARIABLE-delay cells use these to attribute the
    stacked ring's pop/push copy-protection pair (docs/matrix.md —
    the single-pass masked fold reads all slots of the same donated
    buffer the push overwrites; arena.GradArena documents this as the
    cost the v2 tuple-of-slots layout exists to avoid)."""
    names = set()
    copies = []   # (own name, operand name)
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        own = ls.split(" = ", 1)[0]
        if own.startswith("ROOT "):
            own = own[len("ROOT "):]
        own = own.lstrip("%")
        if " parameter(" in ls and 'op_name="state.arena.ring"' in ls:
            names.add(own)
        elif " copy(" in ls:
            toks = [t for t in ls.split("copy(", 1)[1].split()
                    if t.startswith("%")]
            if toks:
                copies.append((own, toks[-1].rstrip("),").lstrip("%")))
    changed = True
    while changed:
        changed = False
        for own, operand in copies:
            if operand in names and own not in names:
                names.add(own)
                changed = True
    return names


def _arena_shape_keys(cell: MatrixCell, rc: RunConfig, rows: int, dt: str):
    """Every "dt[dims]" an arena ring/slot/staging copy could print as,
    global or per-device-local dims."""
    mesh = rc.mesh
    flat = mesh.data * mesh.model
    row_variants = {rows}
    if rows % flat == 0:
        row_variants.add(rows // flat)
    pod_variants = {mesh.n_pods, 1}
    keys = set()
    for p in pod_variants:
        for r in row_variants:
            keys.add(f"{dt}[{p},{r},128]")
    if cell.delay_process != "fixed":   # v3 stacked ring
        depth = (cell.tau_max or 4) + 1
        for p in pod_variants:
            for r in row_variants:
                keys.add(f"{dt}[{depth},{p},{r},128]")
    return keys


def _publish_shape_keys(rc: RunConfig, rows: int):
    flat = rc.mesh.data * rc.mesh.model
    row_variants = {rows}
    if rows % flat == 0:
        row_variants.add(rows // flat)
    return {f"s8[{r},128]" for r in row_variants}


def check_ring_copies(cell: MatrixCell, rc: RunConfig, rows: int,
                      hlo_text: str, publish_hlo: Optional[str]) -> Dict:
    """Invariant A.  Violations are copies of RING-dtype arena-shaped
    tensors (the donation contract of docs/arena.md: the ring must
    rotate without copy traffic).  f32 STAGING-shaped copies — the
    per-leaf-slicing finding of docs/matrix.md — are attributed and
    reported, not violations; on an uncompressed cell the ring IS f32
    and shape-identical to staging, so there only the attributed
    classes are exempt and any unaccounted copy still fails."""
    spans = _staging_fill_spans()
    ring_dt = "s8" if cell.pod_compression == "int8" else "f32"
    if cell.kind == "decode":
        ring_keys = _publish_shape_keys(rc, rows)
        staging_keys = set()
        texts = [t for t in (hlo_text, publish_hlo) if t]
    else:
        ring_keys = _arena_shape_keys(cell, rc, rows, ring_dt)
        staging_keys = _arena_shape_keys(cell, rc, rows, "f32")
        texts = [hlo_text]
    violations = []
    attributed = {"staging_fill": {"count": 0, "bytes": 0},
                  "residual_slice": {"count": 0, "bytes": 0},
                  "stacked_pop_push": {"count": 0, "bytes": 0},
                  "unattributed_staging": {"count": 0, "bytes": 0}}
    for text in texts:
        # the stacked (v3) ring's pop/push copy-protection pair is a
        # DOCUMENTED cost of the single-pass fold on the XLA ref path
        # (arena.GradArena; the TPU kernel handles it in-registers) —
        # attributed on variable-delay cells only, a violation anywhere
        # else (a fixed-delay ring-param copy is a failed donation)
        ring_aliases = (_ring_param_aliases(text)
                        if cell.delay_process != "fixed" else set())
        for rec in copy_records(text):
            is_ring = rec["key"] in ring_keys
            if not is_ring and rec["key"] not in staging_keys:
                continue
            cls = _attribute_copy(rec, spans)
            if cls is None and is_ring:
                op_toks = [t for t in (rec.get("operand") or "").split()
                           if t.startswith("%")]
                if op_toks and op_toks[-1].lstrip("%") in ring_aliases:
                    cls = "stacked_pop_push"
            if cls is None and is_ring:
                violations.append(rec)
            else:
                bucket = cls or "unattributed_staging"
                attributed[bucket]["count"] += 1
                attributed[bucket]["bytes"] += rec["bytes"]
    return {"ok": not violations,
            "checked_keys": sorted(ring_keys),
            "violations": violations,
            # the filed finding, kept visible in BENCH_matrix.json:
            "attributed_copies": attributed}


# ---------------------------------------------------------------------------
# Invariants B + C: the cell's exchange program, census vs wire model
# ---------------------------------------------------------------------------
def _scoped_mesh(n: int, axis: str) -> Mesh:
    return Mesh(np.asarray(jax.devices()[:n]), (axis,))


def _lower_master_exchange(rows: int, n_pods: int, compression: str):
    """The fixed-delay cross-pod pop, scoped to a ('pod',) mesh — the
    DCN edge of ``ring_slot_rotate_int8_sharded`` / ``_slot_pop_sum``
    isolated from the surrounding step."""
    mesh = _scoped_mesh(n_pods, "pod")
    if compression == "int8":
        def local(q, s):     # blocks (1, rows, 128) s8, (1, rows) f32
            q_all = jax.lax.all_gather(q, "pod", axis=0, tiled=True)
            s_all = jax.lax.all_gather(s, "pod", axis=0, tiled=True)
            return jnp.sum(q_all.astype(jnp.float32) * s_all[..., None],
                           axis=0)
        args = (jax.ShapeDtypeStruct((n_pods, rows, 128), jnp.int8),
                jax.ShapeDtypeStruct((n_pods, rows), jnp.float32))
        in_specs = (P("pod", None, None), P("pod", None))
    else:
        def local(slot):     # block (1, rows, 128) f32
            return jax.lax.psum(slot[0], "pod")
        args = (jax.ShapeDtypeStruct((n_pods, rows, 128), jnp.float32),)
        in_specs = (P("pod", None, None),)
    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=in_specs,
                           out_specs=P(None, None), check_rep=False))
    return fn.lower(*args).compile()


def _lower_variable_exchange(rows: int, n_pods: int):
    """The v3 pop's single DCN reduce: one f32 psum of the locally
    folded rows (``ring_variable_pop_sharded``)."""
    mesh = _scoped_mesh(n_pods, "pod")

    def local(acc):          # block (1, rows, 128) f32: the local fold
        return jax.lax.psum(acc[0], "pod")

    fn = jax.jit(shard_map(local, mesh=mesh,
                           in_specs=(P("pod", None, None),),
                           out_specs=P(None, None), check_rep=False))
    arg = jax.ShapeDtypeStruct((n_pods, rows, 128), jnp.float32)
    return fn.lower(arg).compile()


def _lower_gossip_exchange(topology: str, n_workers: int, rows: int,
                           compression: str):
    """r gossip rounds under shard_map — the same scoped program the
    gossip-bytes benchmark censuses (rounds scan once in the HLO, so
    the census is per-round)."""
    mesh = _scoped_mesh(n_workers, "worker")
    sp = P("worker", None, None)
    if compression == "int8":
        def local(x, res):
            return consensus.gossip_rounds_shard_int8(
                x, res, "worker", topology, n_workers, GOSSIP_ROUNDS)
    else:
        def local(x, res):
            return consensus.gossip_rounds_shard(
                x, "worker", topology, n_workers, GOSSIP_ROUNDS), res
    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=(sp, sp),
                           out_specs=(sp, sp), check_rep=False))
    arg = jax.ShapeDtypeStruct((n_workers, rows, 128), jnp.float32)
    return fn.lower(arg, arg).compile()


def _lower_publish_exchange(rows: int, n_shards: int):
    """The publish-channel pop's gather: flat-sharded s8 snapshot +
    bf16 scales to full rows on every server device, then the local
    dequantize.  The scales ride the wire as their raw u16 bits —
    the publisher's own serialization (``serve/publisher`` carries
    ``scales_bits``), and gathering the bits keeps the CPU backend
    from legalizing a bf16 all-gather by promoting the payload to
    f32 (which the census invariant flagged)."""
    from repro.optim.compression import dequantize_int8_rows
    mesh = _scoped_mesh(n_shards, "flat")

    def local(q, s_bits):    # blocks (rows/n, 128) s8, (rows/n,) u16
        q_all = jax.lax.all_gather(q, "flat", axis=0, tiled=True)
        s_all = jax.lax.all_gather(s_bits, "flat", axis=0, tiled=True)
        scales = jax.lax.bitcast_convert_type(s_all, jnp.bfloat16)
        return dequantize_int8_rows(q_all, scales)

    fn = jax.jit(shard_map(local, mesh=mesh,
                           in_specs=(P("flat", None), P("flat")),
                           out_specs=P(None, None), check_rep=False))
    args = (jax.ShapeDtypeStruct((rows, 128), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.uint16))
    return fn.lower(*args).compile()


def _publish_shards(rows: int) -> int:
    for n in (16, 8, 4, 2):
        if rows % n == 0 and n <= len(jax.devices()):
            return n
    return 1


def lower_exchange(cell: MatrixCell, rc: RunConfig, rows: int):
    """(kind, compiled, analytic-by-dtype) for the cell's exchange
    path; (None, None, {}) when the cell has no exchange edge (a
    single-pod master cell — no DCN)."""
    if cell.strategy == "decentralized":
        compiled = _lower_gossip_exchange(
            cell.topology, cell.n_workers, rows, cell.gossip_compression)
        model = wire_model.gossip_round_bytes(
            cell.topology, cell.n_workers, rows,
            compression=cell.gossip_compression)
        return "gossip_round", compiled, model
    if cell.kind == "decode":
        n = _publish_shards(rows)
        if n <= 1:
            return None, None, {}
        return ("publish_pop", _lower_publish_exchange(rows, n),
                wire_model.publish_pop_bytes(rows, n))
    n_pods = rc.mesh.n_pods
    if n_pods <= 1:
        return None, None, {}
    if cell.delay_process != "fixed":
        return ("variable_pod_psum",
                _lower_variable_exchange(rows, n_pods),
                wire_model.variable_pod_exchange_bytes(rows, n_pods))
    return ("master_pod_exchange",
            _lower_master_exchange(rows, n_pods, cell.pod_compression),
            wire_model.master_pod_exchange_bytes(
                rows, n_pods, cell.pod_compression))


def check_exchange(cell: MatrixCell, rc: RunConfig, rows: int) -> Dict:
    kind, compiled, model = lower_exchange(cell, rc, rows)
    if kind is None:
        return {"kind": "none", "ok": True, "census": {},
                "census_by_dtype": {}, "analytic_by_dtype": {},
                "note": "single-pod master cell: no DCN edge"}
    text = compiled.as_text()
    census = collective_bytes(text, strict=True)
    by_dtype = collective_bytes_by_dtype(text, strict=True)
    # C: strict census == closed-form model, exactly, per dtype
    census_ok = by_dtype == model
    # B: compressed edges — with int8 on, everything except the
    # sanctioned scale payload must travel as s8
    compressed = (cell.gossip_compression == "int8"
                  if cell.strategy == "decentralized" else
                  cell.pod_compression == "int8"
                  or kind == "publish_pop")
    scale_dts = {"f32", "u16", "bf16"}
    if compressed:
        extra = {dt: b for dt, b in by_dtype.items()
                 if dt != "s8" and (dt not in scale_dts
                                    or b != model.get(dt))}
        compressed_ok = not extra and by_dtype.get("s8", 0) > 0
    else:
        compressed_ok = True
    return {"kind": kind, "ok": census_ok and compressed_ok,
            "census_matches_model": census_ok,
            "compressed_edges": compressed_ok if compressed else "n/a",
            "census": census, "census_by_dtype": by_dtype,
            "analytic_by_dtype": model}


# ---------------------------------------------------------------------------
# Cell driver
# ---------------------------------------------------------------------------
def run_matrix_cell(cell: MatrixCell, verbose: bool = True) -> Dict:
    if cell.devices != len(jax.devices()):
        raise RuntimeError(
            f"cell {cell.name} needs {cell.devices} devices but this "
            f"process has {len(jax.devices())} "
            f"(XLA pins the count at startup; run via "
            f"benchmarks/matrix_sweep.py or set REPRO_HOST_DEVICES)")
    rc = build_cell_rc(cell)
    rows = _arena_rows(rc)
    t0 = time.time()
    row = dryrun.run_cell(cell.arch, rc.shape.name,
                          rc.mesh.n_pods > 1, rc=rc, verbose=False,
                          want_hlo=True)
    hlo_text = row.pop("hlo_text")
    publish_hlo = None
    if "publish_pop" in row:
        publish_hlo = row["publish_pop"].pop("hlo_text", None)
    row.update({
        "cell": cell.name,
        "devices": cell.devices,
        "mesh": mesh_label(rc.mesh),
        "arena_rows": rows,
        "copy_bytes": copy_bytes(hlo_text),
        "copy_count": sum(copy_shapes(hlo_text).values()),
        "pod_compression": cell.pod_compression,
        "gossip_compression": cell.gossip_compression,
    })
    row["invariants"] = {
        "ring_copies": check_ring_copies(cell, rc, rows, hlo_text,
                                         publish_hlo),
        "exchange": check_exchange(cell, rc, rows),
    }
    row["invariants"]["ok"] = (row["invariants"]["ring_copies"]["ok"]
                               and row["invariants"]["exchange"]["ok"])
    row["cell_seconds"] = round(time.time() - t0, 1)
    if verbose:
        inv = row["invariants"]
        print(f"{cell.name}: invariants "
              f"{'OK' if inv['ok'] else 'FAILED'} "
              f"(exchange={inv['exchange']['kind']}, "
              f"{row['cell_seconds']}s)", flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=None,
                    help="comma-separated cell names (see --list)")
    ap.add_argument("--all", action="store_true",
                    help="every cell matching this process's device "
                         "count (others are reported as skipped)")
    ap.add_argument("--devices", type=int, default=None,
                    help="virtual device count this process was "
                         "launched for (cross-checked against the "
                         "effective XLA flag)")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    if args.list:
        for c in CELLS:
            print(f"{c.name}  devices={c.devices} strategy={c.strategy} "
                  f"arch={c.arch} mesh={c.mesh} kind={c.kind}")
        return

    if args.devices is not None and args.devices != HOST_DEVICES:
        print(f"--devices {args.devices} != effective device count "
              f"{HOST_DEVICES} (flag pinned before launch?)",
              file=sys.stderr)
        sys.exit(2)

    if args.cells:
        cells = [CELLS_BY_NAME[n] for n in args.cells.split(",")]
        bad = [c.name for c in cells if c.devices != HOST_DEVICES]
        if bad:
            print(f"cells {bad} need a different device count than "
                  f"this process's {HOST_DEVICES}", file=sys.stderr)
            sys.exit(2)
        skipped = []
    elif args.all:
        cells = [c for c in CELLS if c.devices == HOST_DEVICES]
        skipped = [c.name for c in CELLS if c.devices != HOST_DEVICES]
    else:
        print("pass --cells, --all or --list", file=sys.stderr)
        sys.exit(2)

    results, failures = [], []
    for cell in cells:
        try:
            row = run_matrix_cell(cell)
            results.append(row)
            if not row["invariants"]["ok"]:
                failures.append({"cell": cell.name,
                                 "error": "invariant violation",
                                 "invariants": row["invariants"]})
        except Exception as e:  # noqa: BLE001
            failures.append({"cell": cell.name, "error": repr(e)[:800]})
            print(f"FAIL {cell.name}: {e!r}", file=sys.stderr)
    out = {"devices": HOST_DEVICES, "results": results,
           "failures": failures, "skipped_wrong_device_count": skipped}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    n_ok = sum(1 for r in results if r["invariants"]["ok"])
    print(f"{n_ok} cells OK, {len(failures)} failed, "
          f"{len(skipped)} skipped (device count {HOST_DEVICES})")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
