"""Production meshes.

Single pod : (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips, 'pod' crosses DCN

``make_production_mesh`` is a function (not a module constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def mesh_config(multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(n_pods=2 if multi_pod else 1, data=16, model=16)
