"""Mesh construction for the dry-run / matrix harness.

The production reference shapes stay what they were:

Single pod : (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips, 'pod' crosses DCN

but mesh shape is a real harness axis now: ``parse_mesh`` turns a
``"DxM"`` / ``"PxDxM"`` spec string into a ``MeshConfig`` (a leading
pod factor > 1 adds the DCN-crossing ``pod`` axis), and ``mesh_label``
is its inverse — the canonical cell label the dry-run and the matrix
runner emit.

``make_production_mesh`` is a function (not a module constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def mesh_config(multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(n_pods=2 if multi_pod else 1, data=16, model=16)


def parse_mesh(spec: str) -> MeshConfig:
    """``"16x16" -> MeshConfig(1, 16, 16)``,
    ``"2x8x8" -> MeshConfig(2, 8, 8)``.  Two factors are (data, model);
    three are (pod, data, model).  A three-factor spec with pod=1
    collapses to the two-axis mesh (``MeshConfig.axis_names`` only
    grows the ``pod`` axis when ``n_pods > 1``, so "1x8x8" and "8x8"
    are the same mesh — and the same label, see ``mesh_label``)."""
    try:
        dims = [int(d) for d in spec.lower().split("x")]
    except ValueError:
        raise ValueError(f"unparsable mesh spec {spec!r} "
                         "(want DxM or PxDxM, e.g. 8x8 or 2x16x16)")
    if len(dims) == 2:
        dims = [1] + dims
    if len(dims) != 3 or any(d < 1 for d in dims):
        raise ValueError(f"mesh spec {spec!r} must have 2 or 3 "
                         "positive factors (DxM or PxDxM)")
    return MeshConfig(n_pods=dims[0], data=dims[1], model=dims[2])


def mesh_label(cfg: MeshConfig) -> str:
    """Canonical cell label; inverse of ``parse_mesh``."""
    return "x".join(str(d) for d in cfg.shape)
