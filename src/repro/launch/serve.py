"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``

Drives the continuous-batching engine under a seeded open-loop arrival
process (``rc.serve``: Poisson or bursty traffic), optionally with the
bounded-staleness weight-publication channel attached (--publish-period
> 0 simulates the master publishing every N steps and the engine
popping the freshest due snapshot). Runs on the local device set
(reduced config on CPU); the production-shape decode program is
exercised by the dry-run: ``repro.launch.dryrun`` lowers
``continuous_decode_step`` + the publish pop for decode_32k /
long_500k on the 256/512-chip meshes.
"""
from __future__ import annotations

import argparse

import repro.configs as C
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import Engine, RequestQueue, WeightPublisher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--n-steps", type=int, default=64)
    ap.add_argument("--arrival", default="poisson",
                    choices=("poisson", "bursty"))
    ap.add_argument("--arrival-rate", type=float, default=0.5)
    ap.add_argument("--publish-period", type=int, default=0,
                    help="master steps between weight publishes "
                         "(0 = channel off)")
    ap.add_argument("--staleness-bound", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (C.get_smoke_config(args.arch) if args.smoke
           else C.get_config(args.arch))
    model = build_model(cfg)
    if model.decode_step is None:
        raise SystemExit(f"{args.arch} has no decode path")
    sc = ServeConfig(slots=args.slots, max_len=args.max_len,
                     max_new=args.max_new, arrival=args.arrival,
                     arrival_rate=args.arrival_rate,
                     publish_period=args.publish_period,
                     staleness_bound=args.staleness_bound,
                     seed=args.seed)
    engine = Engine(model, sc.slots, sc.max_len, seed=sc.seed)
    queue = RequestQueue(sc, cfg.vocab_size)

    publisher = None
    if sc.publish_period > 0:
        from repro.core.arena import make_layout
        publisher = WeightPublisher(make_layout(engine.params), sc)
        engine.attach_publisher(publisher)

    for t in range(args.n_steps):
        if publisher is not None and t % sc.publish_period == 0:
            # stand-in master: republish the engine's own weights on
            # the publish clock so the pop/staleness path is exercised
            publisher.publish(engine.params, t)
            engine.refresh_weights(t)
        queue.step()
        engine.step(queue)

    s = engine.stats
    print(f"steps={s.steps} submitted={queue.submitted} "
          f"admitted={s.admitted} completed={s.completed} "
          f"in_flight={engine.in_flight} queued={len(queue)}")
    print(f"prefill_tok={s.prefill_tokens} decode_tok={s.decode_tokens}")
    if publisher is not None:
        print(f"publish: pops={s.publish_pops} misses={s.publish_misses} "
              f"staleness mean={s.staleness_mean():.2f} "
              f"max={s.staleness_max} (bound={sc.staleness_bound})")
    for rid, toks in engine.completions[:4]:
        print(f"req {rid}: {len(toks)} tokens")


if __name__ == "__main__":
    main()
