"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``

Runs the batched decoding engine on the local device set (reduced
config on CPU; the production-shape decode program is exercised by the
dry-run: ``repro.launch.dryrun`` lowers serve_step for decode_32k /
long_500k on the 256/512-chip meshes).
"""
from __future__ import annotations

import argparse

import numpy as np

import repro.configs as C
from repro.models import build_model
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--n-requests", type=int, default=4)
    args = ap.parse_args()

    cfg = (C.get_smoke_config(args.arch) if args.smoke
           else C.get_config(args.arch))
    model = build_model(cfg)
    if model.decode_step is None:
        raise SystemExit(f"{args.arch} has no decode path")
    engine = Engine(model, batch_slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size,
                                 size=int(rng.integers(4, 12))))
               for _ in range(args.n_requests)]
    out = engine.generate(prompts, max_new=args.max_new)
    for i, o in enumerate(out):
        print(f"req {i}: {len(prompts[i])} prompt -> "
              f"{o[len(prompts[i]):]}")
    s = engine.stats
    print(f"steps={s.steps} prefill_tok={s.prefill_tokens} "
          f"decode_tok={s.decode_tokens}")


if __name__ == "__main__":
    main()
