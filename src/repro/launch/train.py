"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs the host loop (repro.train.loop) on the local device set for any
registered strategy (``--strategy ambdg|amb|kbatch|decentralized``).
On a real pod this process runs per-host under the usual multi-host
runtime (jax.distributed.initialize) with the same code path; CI runs
a reduced config on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

import repro.configs as C
from repro.api import available_strategies
from repro.configs.base import (AmbdgConfig, BatchScheduleConfig,
                                ConsensusConfig, DelayConfig,
                                ElasticConfig, MeshConfig, RunConfig,
                                SHAPES)
from repro.core.batch_schedule import BATCH_SCHEDULES
from repro.core.delay_process import DELAY_PROCESSES
from repro.core.worker_process import WORKER_PROCESSES
from repro.models import build_model
from repro.train.loop import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--strategy", default="ambdg",
                    choices=available_strategies(),
                    help="algorithm variant (Strategy registry)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--optimizer", default="dual_averaging")
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--t-p", type=float, default=2.5)
    ap.add_argument("--t-c", type=float, default=10.0)
    ap.add_argument("--n-microbatches", type=int, default=2)
    ap.add_argument("--delay-process", default="fixed",
                    choices=sorted(DELAY_PROCESSES),
                    help="staleness process of the master exchange: "
                         "'fixed' = the paper's constant tau; the "
                         "stochastic processes run the delay-tolerant "
                         "ring (ambdg only)")
    ap.add_argument("--tau-max", type=int, default=0,
                    help="staleness cap sizing the delay-tolerant ring "
                         "(0 = 2*tau for stochastic processes)")
    ap.add_argument("--delay-min", type=int, default=1)
    ap.add_argument("--delay-seed", type=int, default=0)
    ap.add_argument("--elastic-process", default="static",
                    choices=sorted(WORKER_PROCESSES),
                    help="elastic-worker process: 'static' = the "
                         "exact fixed-fleet path; 'heterogeneous' = "
                         "persistent speed skew; 'churn' = up/down "
                         "Gilbert-Elliott chain; 'crash_restart' = "
                         "exponential MTTF/MTTR")
    ap.add_argument("--churn-rate", type=float, default=0.05,
                    help="per-epoch failure probability "
                         "(ElasticConfig.p_fail, churn process)")
    ap.add_argument("--churn-recover", type=float, default=0.5,
                    help="per-epoch recovery probability "
                         "(ElasticConfig.p_recover, churn process)")
    ap.add_argument("--elastic-seed", type=int, default=0,
                    help="seed of the elastic worker process")
    ap.add_argument("--batch-schedule", default="fixed",
                    choices=sorted(BATCH_SCHEDULES),
                    help="adaptive minibatch schedule b(t): 'fixed' = "
                         "the exact timing-driven anytime path; "
                         "'linear' ramps, 'adadamp' grows as the loss "
                         "drops, 'delay_aware' scales with observed "
                         "staleness (alpha takes b(t) for b_bar)")
    ap.add_argument("--batch-b0", type=int, default=0,
                    help="schedule base target b(1) "
                         "(0 = round(b_bar) = n_workers * "
                         "samples_per_worker)")
    ap.add_argument("--batch-cap", type=int, default=0,
                    help="cap on scheduled targets (0 = 16 * b0)")
    ap.add_argument("--batch-growth", type=float, default=1.0,
                    help="linear schedule: +samples per step")
    ap.add_argument("--batch-schedule-seed", type=int, default=0,
                    help="seed of the batch-size controller")
    ap.add_argument("--fixed-alpha", action="store_true",
                    help="disable the Agarwal-Duchi delay-adaptive "
                         "step size (use the static worst-case tau)")
    ap.add_argument("--topology", default="ring",
                    help="decentralized gossip topology")
    ap.add_argument("--gossip-rounds", type=int, default=0,
                    help="decentralized: 0 derives eq. (24)'s bound")
    ap.add_argument("--gossip-compression", default="none",
                    choices=("none", "int8"),
                    help="decentralized: compress gossip messages to "
                         "int8 + per-row scales with error feedback")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--samples-per-worker", type=int, default=4)
    args = ap.parse_args()

    model_cfg = (C.get_smoke_config(args.arch) if args.smoke
                 else C.get_config(args.arch))
    shape = SHAPES[args.shape]
    if args.smoke and args.seq_len is None:
        args.seq_len = 128          # CPU-friendly default for --smoke
    if args.seq_len or args.batch:
        shape = dataclasses.replace(
            shape, seq_len=args.seq_len or shape.seq_len,
            global_batch=args.batch or shape.global_batch)

    total = args.n_workers * args.samples_per_worker
    shape = dataclasses.replace(shape, global_batch=total)

    rc = RunConfig(
        model=model_cfg, shape=shape,
        mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(t_p=args.t_p, t_c=args.t_c, tau=args.tau,
                          n_microbatches=args.n_microbatches,
                          b_bar=float(total)),
        strategy=args.strategy,
        consensus=ConsensusConfig(topology=args.topology,
                                  n_workers=args.n_workers,
                                  rounds=args.gossip_rounds,
                                  compression=args.gossip_compression),
        delay=DelayConfig(
            process=args.delay_process,
            tau_max=args.tau_max or (2 * args.tau
                                     if args.delay_process != "fixed"
                                     else 0),
            delay_min=args.delay_min, seed=args.delay_seed,
            adaptive_alpha=not args.fixed_alpha),
        elastic=ElasticConfig(process=args.elastic_process,
                              p_fail=args.churn_rate,
                              p_recover=args.churn_recover,
                              seed=args.elastic_seed),
        batch_schedule=BatchScheduleConfig(
            schedule=args.batch_schedule, b0=args.batch_b0,
            b_cap=args.batch_cap, growth_rate=args.batch_growth,
            seed=args.batch_schedule_seed),
        optimizer=args.optimizer)
    model = build_model(model_cfg)
    loop = LoopConfig(n_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      n_workers=args.n_workers,
                      samples_per_worker=args.samples_per_worker)
    out = train(model, rc, loop, log_fn=lambda m: print(json.dumps(m)))
    print(f"done: {len(out['history'])} log points, "
          f"final loss {out['history'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
