"""Closed-form per-device wire models for the exchange paths.

The gossip-bytes benchmark already pins census == analytic for the
decentralized gossip round (``consensus.payload_bytes_per_round``).
This module extends the same closed-form treatment to the other two
exchange paths — the master arena's cross-pod (DCN) pop and the
train-while-serve publish pop — and splits every model BY PAYLOAD
DTYPE, so the matrix runner can assert both:

  * census == model           (``launch.hlo.collective_bytes``, strict)
  * compressed DCN edges      (with int8 on, the only non-``s8`` wire
                               bytes are the per-row scales)

Each function returns ``{dtype: per-device wire bytes}`` using the
same ring-algorithm formulas as the census parser (integer floor
division, so the two sides can be compared with ``==`` rather than a
tolerance):

  all-reduce  2 (n-1)/n * P        all-gather  (n-1)/n * P_gathered

Paths modeled (see docs/matrix.md for the derivations):

``master_pod_exchange_bytes``  the fixed-delay ring pop crossing the
    ``pod`` axis.  int8 (``ambdg.pod_compression``): one s8 all-gather
    of the due slot + one f32 all-gather of its per-row scales, then a
    LOCAL dequantized fold (``kernels.delay_ring
    .ring_slot_rotate_int8_sharded``).  Uncompressed: the pod-axis
    psum (all-reduce) of the f32 slot (``arena._slot_pop_sum``).

``variable_pod_exchange_bytes``  the delay-tolerant (v3) ring pop:
    each pod folds its due slots LOCALLY and ships one f32 psum —
    int8 never crosses DCN on this path, which is why the matrix
    never pairs ``pod_compression="int8"`` with a stochastic delay
    process (the compressed-edge invariant would be unsatisfiable by
    construction; docs/matrix.md).

``gossip_round_bytes``  one decentralized gossip round, per worker:
    delegates the total to ``consensus.payload_bytes_per_round`` (one
    source of truth) and splits it s8 payload / u16-bitcast bf16
    scales under int8.

``publish_pop_bytes``  the server side of the weight-publication
    channel: all-gather of the popped s8 ``(rows, 128)`` snapshot and
    its bf16 ``(rows,)`` scales across the ``flat`` shards before the
    local dequantize+unflatten.
"""
from __future__ import annotations

from typing import Dict

from repro.core import consensus

LANES = 128


def _allreduce(n: int, p_bytes: int) -> int:
    return 2 * (n - 1) * p_bytes // max(n, 1)


def _allgather(n: int, gathered_bytes: int) -> int:
    return (n - 1) * gathered_bytes // max(n, 1)


def master_pod_exchange_bytes(rows: int, n_pods: int, compression: str,
                              lanes: int = LANES) -> Dict[str, int]:
    """Fixed-delay ring pop across the pod (DCN) axis, per device."""
    if n_pods <= 1:
        return {}
    if compression == "int8":
        return {
            # s8 all-gather of the due slot: gathered (n_pods, rows, lanes)
            "s8": _allgather(n_pods, n_pods * rows * lanes),
            # f32 all-gather of the per-row scales: (n_pods, rows)
            "f32": _allgather(n_pods, n_pods * rows * 4),
        }
    # uncompressed: one f32 psum (all-reduce) of the (rows, lanes) slot
    return {"f32": _allreduce(n_pods, rows * lanes * 4)}


def variable_pod_exchange_bytes(rows: int, n_pods: int,
                                lanes: int = LANES) -> Dict[str, int]:
    """Delay-tolerant (v3) ring pop: ONE f32 psum of the locally
    folded due rows — identical wire shape for every compression mode
    (int8 stays intra-pod on this path)."""
    if n_pods <= 1:
        return {}
    return {"f32": _allreduce(n_pods, rows * lanes * 4)}


def gossip_round_bytes(topology: str, n_workers: int, rows: int,
                       compression: str = "none",
                       lanes: int = LANES) -> Dict[str, int]:
    """One gossip round per worker, split by wire dtype.  The total
    equals ``consensus.payload_bytes_per_round`` exactly (asserted, so
    the two models cannot drift apart)."""
    total = consensus.payload_bytes_per_round(
        topology, n_workers, rows, lanes=lanes, compression=compression)
    n_terms = sum(1 for nbr, _ in
                  consensus.topology_stencil(topology, n_workers)
                  if not consensus._is_self_term(nbr))
    if compression == "int8":
        out = {"s8": n_terms * rows * lanes,   # quantized message
               "u16": n_terms * rows * 2}      # bf16 scales, bitcast u16
    else:
        out = {"f32": n_terms * rows * lanes * 4}
    assert sum(out.values()) == total, (out, total)
    return out


def publish_pop_bytes(rows: int, n_shards: int,
                      lanes: int = LANES) -> Dict[str, int]:
    """Publish-channel pop: gather the flat-sharded s8 snapshot + bf16
    scales to every server device, per device.  Like the gossip path,
    the scales travel as their raw u16 bits (the publisher's own
    serialization — ``serve/publisher`` carries ``scales_bits`` —
    and what keeps the CPU backend from silently promoting a bf16
    all-gather to f32 on the wire)."""
    if n_shards <= 1:
        return {}
    return {
        "s8": _allgather(n_shards, rows * lanes),
        "u16": _allgather(n_shards, rows * 2),
    }
