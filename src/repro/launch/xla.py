"""XLA_FLAGS management for the dry-run / benchmark entry points.

The dry-run stack compiles against *virtual* CPU devices
(``--xla_force_host_platform_device_count``). XLA reads the flag once,
at backend initialization, and takes the LAST occurrence — so an
import-time ``os.environ["XLA_FLAGS"] += ...`` silently overrides any
count the caller or CI already set (the pre-PR-10 behavior of
``launch.dryrun`` and ``benchmarks.roofline``).

``ensure_host_platform_device_count`` is the one sanctioned way to
request a count. The contract, pinned by ``tests/test_matrix.py``:

  * a pre-existing ``--xla_force_host_platform_device_count`` in
    ``XLA_FLAGS`` always wins — the flag is never appended a second
    time and never rewritten, so importing ``repro.launch.dryrun``
    (or any benchmark) can no longer change a device count the
    caller pinned;
  * otherwise the count is injectable: an explicit ``count`` argument
    beats the ``REPRO_HOST_DEVICES`` environment variable beats the
    call site's ``default`` — this is how the matrix harness runs
    64/128/512-device cells from one entry point (one subprocess per
    count; the flag is process-lifetime state in XLA);
  * an explicit ``count`` (argument or ``REPRO_HOST_DEVICES``) that
    CONFLICTS with a pre-existing flag raises instead of silently
    keeping either value — by the time the conflict is visible the
    backend may already be initialized with the old count, so
    proceeding would mislabel every measurement.

Import of this module never touches jax device state (no jax import).
"""
from __future__ import annotations

import os
import re
from typing import Optional

FLAG = "--xla_force_host_platform_device_count"
ENV_VAR = "REPRO_HOST_DEVICES"

_FLAG_RE = re.compile(re.escape(FLAG) + r"=(\d+)")


def pinned_host_device_count(flags: Optional[str] = None) -> Optional[int]:
    """The device count already pinned in ``XLA_FLAGS`` (the LAST
    occurrence — XLA's own precedence), or None if the flag is absent.
    """
    if flags is None:
        flags = os.environ.get("XLA_FLAGS", "")
    counts = _FLAG_RE.findall(flags)
    return int(counts[-1]) if counts else None


def ensure_host_platform_device_count(count: Optional[int] = None, *,
                                      default: int = 512) -> int:
    """Make sure ``XLA_FLAGS`` pins a host-platform device count and
    return the effective count (see module docstring for precedence).

    Call BEFORE the first jax backend initialization — the flag is
    read exactly once per process.
    """
    env = os.environ.get(ENV_VAR)
    requested = count if count is not None else (
        int(env) if env is not None else None)
    existing = pinned_host_device_count()
    if existing is not None:
        if requested is not None and requested != existing:
            raise ValueError(
                f"{FLAG}={existing} is already pinned in XLA_FLAGS but "
                f"{requested} was requested"
                f"{' via ' + ENV_VAR if count is None else ''}; refusing "
                "to clobber a caller-set device count (spawn a fresh "
                "process for a different count)")
        return existing
    effective = requested if requested is not None else default
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = f"{flags} {FLAG}={effective}".strip()
    return effective


def without_host_device_flag(flags: str) -> str:
    """``flags`` with every device-count occurrence removed — how a
    parent that already pinned its own count builds a child env where
    ``REPRO_HOST_DEVICES`` can select a DIFFERENT count (the matrix
    sweep's one-subprocess-per-count contract) without tripping the
    conflict check above."""
    return " ".join(t for t in flags.split()
                    if not _FLAG_RE.fullmatch(t)).strip()
