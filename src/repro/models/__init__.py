from repro.models.api import Model, build_model  # noqa: F401
