"""Unified model API.

``build_model(cfg)`` returns a ``Model`` with a common interface across
all families so the AMB-DG train-step factory, the serving engine and the
dry-run never special-case architectures:

    params, axes   = model.init(key)
    loss_sum, aux  = model.loss(params, batch)       # SUM + counts
    cache, caxes   = model.init_decode_state(batch, max_len)
    logits, cache  = model.decode_step(params, cache, tokens, pos)
    batch          = model.dummy_batch(batch_size, seq_len)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (CNN, DENSE, ENCDEC, HYBRID, LINREG, MOE, SSM,
                                VLM, ModelConfig)
from repro.models import cnn as cnn_mod
from repro.models import encdec as encdec_mod
from repro.models import linear as linear_mod
from repro.models import transformer as tf_mod


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Tuple[Dict, Dict]]
    loss: Callable[[Dict, Dict], Tuple[jax.Array, Dict]]
    init_decode_state: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    dummy_batch: Optional[Callable] = None
    input_specs: Optional[Callable] = None


def _lm_dummy_batch(cfg: ModelConfig, batch: int, seq: int, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    n_text = seq - cfg.n_frontend_tokens if cfg.family == VLM else seq
    out = {
        "tokens": jax.random.randint(key, (batch, n_text), 0,
                                     cfg.vocab_size, jnp.int32),
        "weights": jnp.ones((batch,), jnp.float32),
    }
    if cfg.family == VLM:
        out["patches"] = jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == ENCDEC:
        out["frames"] = jax.random.normal(
            key, (batch, seq, cfg.d_model), jnp.float32)
    return out


def _lm_input_specs(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    n_text = seq - cfg.n_frontend_tokens if cfg.family == VLM else seq
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, n_text), jnp.int32),
        "weights": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }
    if cfg.family == VLM:
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == ENCDEC:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), jnp.float32)
    return specs


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == LINREG:
        def dummy(batch, seq=0, key=None):
            key = key if key is not None else jax.random.PRNGKey(0)
            kx, kw, kn = jax.random.split(key, 3)
            w_star = jax.random.normal(kw, (cfg.linreg_dim,))
            x = jax.random.normal(kx, (batch, cfg.linreg_dim))
            y = x @ w_star + 0.001 ** 0.5 * jax.random.normal(kn, (batch,))
            return {"x": x, "y": y, "weights": jnp.ones((batch,), jnp.float32)}
        return Model(cfg, lambda k: linear_mod.init(k, cfg),
                     lambda p, b: linear_mod.loss(p, cfg, b),
                     dummy_batch=dummy)

    if cfg.family == CNN:
        def dummy(batch, seq=0, key=None):
            key = key if key is not None else jax.random.PRNGKey(0)
            ki, kl = jax.random.split(key)
            return {
                "images": jax.random.normal(
                    ki, (batch, cfg.image_size, cfg.image_size, 3)),
                "labels": jax.random.randint(kl, (batch,), 0, cfg.n_classes),
                "weights": jnp.ones((batch,), jnp.float32),
            }
        return Model(cfg, lambda k: cnn_mod.init(k, cfg),
                     lambda p, b: cnn_mod.loss(p, cfg, b),
                     dummy_batch=dummy)

    if cfg.family == ENCDEC:
        return Model(
            cfg,
            init=lambda k: encdec_mod.init(k, cfg),
            loss=lambda p, b: encdec_mod.loss(p, cfg, b),
            init_decode_state=lambda batch, max_len, dtype=jnp.bfloat16:
                encdec_mod.init_decode_state(cfg, batch, max_len, dtype),
            decode_step=lambda p, c, t, pos: encdec_mod.decode_step(
                p, cfg, c, t, pos),
            dummy_batch=lambda b, s, key=None: _lm_dummy_batch(cfg, b, s, key),
            input_specs=lambda b, s: _lm_input_specs(cfg, b, s),
        )

    if cfg.family in (DENSE, MOE, SSM, HYBRID, VLM):
        return Model(
            cfg,
            init=lambda k: tf_mod.init(k, cfg),
            loss=lambda p, b: tf_mod.lm_loss(p, cfg, b),
            init_decode_state=lambda batch, max_len, dtype=jnp.bfloat16:
                tf_mod.init_decode_state(cfg, batch, max_len, dtype),
            decode_step=lambda p, c, t, pos: tf_mod.decode_step(
                p, cfg, c, t, pos),
            dummy_batch=lambda b, s, key=None: _lm_dummy_batch(cfg, b, s, key),
            input_specs=lambda b, s: _lm_input_specs(cfg, b, s),
        )

    raise ValueError(f"unknown family {cfg.family}")
