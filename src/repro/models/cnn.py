"""The paper's CIFAR-10 network (Sec. VI-B): 14 layers — 9 conv + 5 fc,
cross-entropy loss ([38] in the paper). Used with a synthetic image
stream offline (the paper's point is the *scheme* comparison, not the
dataset).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory, split_factory

_CONV_CHANNELS = [3, 64, 64, 64, 128, 128, 128, 256, 256, 256]  # 9 convs
_FC_WIDTHS = [1024, 512, 256, 128]                              # + n_classes


def init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    def build(f: ParamFactory):
        for i, (cin, cout) in enumerate(zip(_CONV_CHANNELS[:-1],
                                            _CONV_CHANNELS[1:])):
            f.param(f"conv{i}_w", (3, 3, cin, cout), (None, None, None, "mlp"),
                    scale=(2.0 / (3 * 3 * cin)) ** 0.5)  # He init
            f.param(f"conv{i}_b", (cout,), ("mlp",), init="zeros")
        feat = _feature_dim(cfg)
        widths = [feat] + _FC_WIDTHS + [cfg.n_classes]
        for i, (fin, fout) in enumerate(zip(widths[:-1], widths[1:])):
            f.param(f"fc{i}_w", (fin, fout), ("embed", "mlp"))
            f.param(f"fc{i}_b", (fout,), ("mlp",), init="zeros")

    return split_factory(build, key, jnp.float32)


def _feature_dim(cfg: ModelConfig) -> int:
    # three 2x pools (after conv 2, 5, 8)
    side = cfg.image_size // 8
    return 256 * side * side


def forward(params, cfg: ModelConfig, images) -> jax.Array:
    """images: (B, H, W, 3) -> logits (B, n_classes)."""
    h = images
    for i in range(9):
        w, b = params[f"conv{i}_w"], params[f"conv{i}_b"]
        h = jax.lax.conv_general_dilated(
            h, w.astype(h.dtype), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + b.astype(h.dtype))
        if i % 3 == 2:
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    n_fc = len(_FC_WIDTHS) + 1
    for i in range(n_fc):
        h = h @ params[f"fc{i}_w"].astype(h.dtype) + params[f"fc{i}_b"].astype(h.dtype)
        if i < n_fc - 1:
            h = jax.nn.relu(h)
    return h


def loss(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    """batch: {"images": (B,H,W,3), "labels": (B,), "weights": (B,)}."""
    images, labels = batch["images"], batch["labels"]
    weights = batch.get("weights")
    if weights is None:
        weights = jnp.ones((images.shape[0],), jnp.float32)
    logits = forward(params, cfg, images)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss_sum = jnp.sum(-ll * weights)
    count = jnp.sum(weights)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * weights)
    return loss_sum, {"count": count, "loss_sum": loss_sum, "acc_sum": acc}
