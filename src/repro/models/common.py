"""Shared model infrastructure: parameter trees with logical sharding axes.

Models are pure functions over nested-dict parameter pytrees. Every leaf
is created through a ``ParamFactory`` which records a tuple of *logical
axis names* per dimension (e.g. ``("vocab", "embed")``). The distribution
layer (``repro.dist.sharding``) later maps logical axes -> mesh axes, so
model code never mentions meshes.

Logical axes used across the zoo:
  "embed"   d_model-like dims            -> FSDP ("data")
  "mlp"     ffn hidden dims              -> TP ("model")
  "heads"   flattened q/kv head dims     -> TP ("model")
  "vocab"   vocabulary dim               -> TP ("model")
  "expert"  MoE expert dim               -> replicated (cap 8 < 16)
  "layers"  stacked scan dim             -> replicated
  None      replicated
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]


def _normal_init(key, shape, dtype, scale):
    return scale * jax.random.normal(key, shape, dtype)


class ParamFactory:
    """Creates parameters and records their logical axes in lockstep."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: Params = {}
        self.axes: Axes = {}

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              init: str = "normal", scale: Optional[float] = None) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "zeros":
            value = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            value = jnp.ones(shape, self.dtype)
        elif init == "normal":
            if scale is None:
                fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            value = _normal_init(self._next_key(), shape, self.dtype, scale)
        else:
            raise ValueError(init)
        self.params[name] = value
        self.axes[name] = axes
        return value

    def child(self, name: str) -> "ParamFactory":
        sub = ParamFactory(self._next_key(), self.dtype)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def vmapped_children(self, name: str, n: int,
                         build: Callable[["ParamFactory"], None]) -> None:
        """Stack ``n`` identically-structured children along a leading
        "layers" axis (scan-over-layers layout)."""
        keys = jax.random.split(self._next_key(), n)

        def one(key):
            f = ParamFactory(key, self.dtype)
            build(f)
            return f.params

        stacked = jax.vmap(one)(keys)
        probe = ParamFactory(jax.random.PRNGKey(0), self.dtype)
        build(probe)
        axes = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax),
            probe.axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x),
        )
        self.params[name] = stacked
        self.axes[name] = axes


def split_factory(build: Callable[[ParamFactory], None], key, dtype=jnp.float32):
    f = ParamFactory(key, dtype)
    build(f)
    return f.params, f.axes


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))
