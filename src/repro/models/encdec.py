"""Encoder-decoder backbone (seamless-m4t): bidirectional encoder over
stub frame embeddings + causal text decoder with cross-attention.
The w2v-BERT speech frontend is a stub per spec — ``input_specs`` feeds
precomputed (B, S_src, d_model) frames.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory, split_factory
from repro.models.transformer import _remat, _sp
from repro.models.layers import (attention_apply, attention_init, cache_axes,
                                 causal_mask, chunked_gqa_attend,
                                 decode_attention, embed_tokens,
                                 embedding_init, gqa_attend, init_kv_cache,
                                 mlp_apply, mlp_init, output_logits,
                                 rmsnorm, rmsnorm_init, _project_qkv,
                                 _CHUNK_THRESHOLD, _Q_CHUNK)


def init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    dtype = jnp.dtype(cfg.param_dtype)

    def enc_layer(f: ParamFactory):
        rmsnorm_init(f, "ln1", cfg.d_model)
        attention_init(f, cfg)
        rmsnorm_init(f, "ln2", cfg.d_model)
        mlp_init(f, cfg)

    def dec_layer(f: ParamFactory):
        rmsnorm_init(f, "ln1", cfg.d_model)
        attention_init(f, cfg, "attn")
        rmsnorm_init(f, "ln_x", cfg.d_model)
        attention_init(f, cfg, "xattn")
        rmsnorm_init(f, "ln2", cfg.d_model)
        mlp_init(f, cfg)

    def build(f: ParamFactory):
        embedding_init(f, cfg)
        f.param("frame_proj", (cfg.d_model, cfg.d_model), ("embed", None))
        f.vmapped_children("encoder", cfg.n_encoder_layers, enc_layer)
        f.vmapped_children("decoder", cfg.n_layers, dec_layer)
        rmsnorm_init(f, "ln_enc_final", cfg.d_model)
        rmsnorm_init(f, "ln_final", cfg.d_model)

    return split_factory(build, key, dtype)


def _cross_attention(p, cfg: ModelConfig, x, memory_k, memory_v):
    """x: (B,Sq,d); memory_k/v: (B,Skv,Hkv,D) precomputed from encoder."""
    B, Sq, _ = x.shape
    Skv = memory_k.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, Sq, cfg.n_heads, hd)
    mk = memory_k.astype(q.dtype)
    mv = memory_v.astype(q.dtype)
    if Sq > _CHUNK_THRESHOLD and Sq % _Q_CHUNK == 0:
        out = chunked_gqa_attend(
            q, mk, mv, lambda off, qn: jnp.ones((qn, Skv), bool))
    else:
        out = gqa_attend(q, mk, mv, jnp.ones((Sq, Skv), bool))
    return out.reshape(B, Sq, -1) @ p["wo"].astype(x.dtype)


def _memory_kv(p, cfg: ModelConfig, memory):
    """Project encoder output once into cross-attention K/V."""
    B, S, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = memory @ p["wk"].astype(memory.dtype)
    v = memory @ p["wv"].astype(memory.dtype)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(memory.dtype)
        v = v + p["bv"].astype(memory.dtype)
    return (k.reshape(B, S, cfg.n_kv_heads, hd),
            v.reshape(B, S, cfg.n_kv_heads, hd))


def encode(params, cfg: ModelConfig, frames) -> jax.Array:
    """frames: (B, S_src, d) stub embeddings -> encoder output."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = frames.astype(dtype) @ params["frame_proj"].astype(dtype)
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)   # bidirectional
    mask_fn = lambda off, qn: jnp.ones((qn, S), bool)

    def block(layer_p, hh):
        hh = hh + attention_apply(layer_p["attn"], cfg,
                                  rmsnorm(hh, layer_p["ln1"], cfg.norm_eps),
                                  positions, mask, mask_fn=mask_fn)
        hh = hh + mlp_apply(layer_p["mlp"], cfg,
                            rmsnorm(hh, layer_p["ln2"], cfg.norm_eps))
        return hh

    block = _remat(block, cfg)

    def body(hh, layer_p):
        return block(layer_p, _sp(hh, cfg)), None

    h, _ = jax.lax.scan(body, h, params["encoder"],
                        unroll=cfg.scan_unroll)
    return rmsnorm(h, params["ln_enc_final"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, tokens, memory) -> jax.Array:
    """Teacher-forced decoder. tokens: (B,S_tgt); memory: encoder out."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params, tokens, dtype) * math.sqrt(cfg.d_model)
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = causal_mask(S, S)
    mask_fn = lambda off, qn: causal_mask(qn, S, q_offset=off)

    def block(layer_p, hh):
        hh = hh + attention_apply(layer_p["attn"], cfg,
                                  rmsnorm(hh, layer_p["ln1"], cfg.norm_eps),
                                  positions, mask, mask_fn=mask_fn)
        mk, mv = _memory_kv(layer_p["xattn"], cfg, memory)
        hh = hh + _cross_attention(layer_p["xattn"], cfg,
                                   rmsnorm(hh, layer_p["ln_x"], cfg.norm_eps),
                                   mk, mv)
        hh = hh + mlp_apply(layer_p["mlp"], cfg,
                            rmsnorm(hh, layer_p["ln2"], cfg.norm_eps))
        return hh

    block = _remat(block, cfg)

    def body(hh, layer_p):
        return block(layer_p, _sp(hh, cfg)), None

    h, _ = jax.lax.scan(body, h, params["decoder"],
                        unroll=cfg.scan_unroll)
    h = rmsnorm(h, params["ln_final"], cfg.norm_eps)
    return output_logits(params, cfg, h)


def loss(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    """batch: {"frames": (B,S_src,d), "tokens": (B,S_tgt), "weights": (B,)}"""
    tokens = batch["tokens"]
    weights = batch.get("weights")
    if weights is None:
        weights = jnp.ones((tokens.shape[0],), jnp.float32)
    memory = encode(params, cfg, batch["frames"])
    # full-length decoder forward; slice logits (keeps shapes pow-2)
    logits = decode_train(params, cfg, tokens, memory)[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    per_sample = -jnp.sum(ll, axis=-1)
    loss_sum = jnp.sum(per_sample * weights)
    count = jnp.sum(weights) * targets.shape[1]
    return loss_sum, {"count": count, "loss_sum": loss_sum}


# ---------------------------------------------------------------------------
# Decode (serve)
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    """Self-attn rolling cache + precomputed cross-attention memory K/V
    (computed once at prefill, same length as the source)."""
    hd = cfg.resolved_head_dim
    cache = {
        "self": init_kv_cache(cfg, cfg.n_layers, batch, max_len, dtype),
        "mem_k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "mem_v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
    }
    ax = ("layers", "batch", "kv_seq", "heads", None)
    axes = {"self": cache_axes(cfg), "mem_k": ax, "mem_v": ax}
    return cache, axes


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params, tokens, dtype) * math.sqrt(cfg.d_model)

    def body(hh, xs):
        layer_p, k_c, v_c, mk, mv = xs
        hn = rmsnorm(hh, layer_p["ln1"], cfg.norm_eps)
        y, k_c, v_c = decode_attention(layer_p["attn"], cfg, hn, pos, k_c, v_c)
        hh = hh + y
        hh = hh + _cross_attention(layer_p["xattn"], cfg,
                                   rmsnorm(hh, layer_p["ln_x"], cfg.norm_eps),
                                   mk, mv)
        hh = hh + mlp_apply(layer_p["mlp"], cfg,
                            rmsnorm(hh, layer_p["ln2"], cfg.norm_eps))
        return hh, (k_c, v_c)

    h, (new_k, new_v) = jax.lax.scan(
        body, h, (params["decoder"], cache["self"]["k"], cache["self"]["v"],
                  cache["mem_k"], cache["mem_v"]))
    h = rmsnorm(h, params["ln_final"], cfg.norm_eps)
    new_cache = dict(cache)
    new_cache["self"] = {"k": new_k, "v": new_v}
    return output_logits(params, cfg, h), new_cache
