"""Core transformer layers: norms, RoPE (incl. partial/"2d"), GQA/MQA
attention with sliding-window / prefix-LM masks and KV-cache decode,
gated MLPs. Everything is written against logical sharding axes (see
``models.common``) and is family-agnostic.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(f: ParamFactory, name: str, dim: int):
    f.param(name, (dim,), ("embed",), init="ones")


def rmsnorm(x, scale, eps: float):
    # stats accumulate in f32 through the reduction only — materializing
    # x.astype(f32) as the first block op makes XLA hoist the convert
    # into the scan-saved residual (a full f32 copy of the carry per
    # layer => 2x remat memory; observed on the 8x22B dry-run)
    var = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def head_rmsnorm(x, scale, eps: float):
    """Per-head qk-norm (qwen3): x (..., n_heads, head_dim)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, partial: float, theta: float):
    rot = int(head_dim * partial)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, partial: float = 1.0):
    """x: (B, S, H, D); positions: (B, S) or (S,). Rotates the first
    ``partial * D`` dims (rotate-half convention); chatglm's "2d RoPE"
    corresponds to partial=0.5."""
    head_dim = x.shape[-1]
    inv, rot = rope_frequencies(head_dim, partial, theta)
    if rot == 0:
        return x
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------
def causal_mask(q_len: int, kv_len: int, *, window: Optional[int] = None,
                q_offset=0):
    """(q_len, kv_len) bool mask, True = attend. ``q_offset`` shifts query
    positions (decode / chunked prefill)."""
    q_pos = jnp.arange(q_len) + q_offset
    kv_pos = jnp.arange(kv_len)
    m = q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        m &= (q_pos[:, None] - kv_pos[None, :]) < window
    return m


def prefix_lm_mask(q_len: int, kv_len: int, prefix_len, q_offset=0):
    """Bidirectional over the first ``prefix_len`` positions, causal after."""
    base = causal_mask(q_len, kv_len, q_offset=q_offset)
    kv_pos = jnp.arange(kv_len)
    in_prefix = kv_pos[None, :] < prefix_len
    return base | in_prefix


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention_init(f: ParamFactory, cfg: ModelConfig, name: str = "attn",
                   d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    a = f.child(name)
    a.param("wq", (d, cfg.n_heads * hd), ("embed", "heads"))
    a.param("wk", (d, cfg.n_kv_heads * hd), ("embed", "heads"))
    a.param("wv", (d, cfg.n_kv_heads * hd), ("embed", "heads"))
    a.param("wo", (cfg.n_heads * hd, d), ("heads", "embed"))
    if cfg.qkv_bias:
        a.param("bq", (cfg.n_heads * hd,), ("heads",), init="zeros")
        a.param("bk", (cfg.n_kv_heads * hd,), ("heads",), init="zeros")
        a.param("bv", (cfg.n_kv_heads * hd,), ("heads",), init="zeros")
    if cfg.qk_norm:
        a.param("q_norm", (hd,), (None,), init="ones")
        a.param("k_norm", (hd,), (None,), init="ones")


def _project_qkv(p, cfg: ModelConfig, x, positions, rope: bool = True):
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_partial)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_partial)
    return q, k, v


def gqa_attend(q, k, v, mask, softcap: Optional[float] = None):
    """Grouped-query attention core. q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D);
    mask: broadcastable to (B,Hkv,G,Sq,Skv) or (Sq,Skv)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    q = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(B, Sq, Hq, D)


_Q_CHUNK = 2048          # q-block size for long-sequence attention
_CHUNK_THRESHOLD = 8192  # chunk when S exceeds this


def chunked_gqa_attend(q, k, v, mask_fn, softcap=None, chunk=_Q_CHUNK):
    """Exact attention in q-blocks (lazy softmax over the full row per
    block): peak scores memory O(chunk * S) instead of O(S^2). mask_fn
    (q_offset, q_len) -> (q_len, Skv) bool."""
    from repro.dist.context import constrain
    # pin batch-sharded activations: with few kv heads (MQA) GSPMD can
    # otherwise trade the batch sharding away and materialize unsharded
    # (B, H, chunk, S) score blocks
    q = constrain(q, ("batch", None, None, None))
    k = constrain(k, ("batch", None, None, None))
    v = constrain(v, ("batch", None, None, None))
    B, Sq, Hq, D = q.shape
    nc = Sq // chunk
    qc = q.reshape(B, nc, chunk, Hq, D)
    masks = jnp.stack([mask_fn(i * chunk, chunk) for i in range(nc)])

    def body(_, inp):
        q_i, m_i = inp
        return None, gqa_attend(q_i, k, v, m_i, softcap)

    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(qc, 1, 0), masks))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, D)


def attention_apply(p, cfg: ModelConfig, x, positions, mask,
                    d_model: Optional[int] = None, mask_fn=None):
    """Full-sequence (train / prefill) attention. For S beyond the chunk
    threshold, pass ``mask_fn`` to enable exact q-block chunking (the
    XLA stand-in for the Pallas flash kernel on TPU)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    B, S = x.shape[:2]
    if mask_fn is not None and S > _CHUNK_THRESHOLD and S % _Q_CHUNK == 0:
        out = chunked_gqa_attend(q, k, v, mask_fn, cfg.logit_softcap)
    else:
        out = gqa_attend(q, k, v, mask, cfg.logit_softcap)
    return out.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    """Cache layout: SWA archs use a rolling buffer of ``window`` slots;
    full attention keeps ``max_len`` slots."""
    hd = cfg.resolved_head_dim
    slots = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    shape = (n_layers, batch, slots, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def cache_axes(cfg: ModelConfig):
    ax = ("layers", "batch", "kv_seq", "heads", None)
    return {"k": ax, "v": ax}


def decode_attention(p, cfg: ModelConfig, x, pos, k_cache, v_cache,
                     cache_len: Optional[int] = None):
    """One-token decode step against a (possibly rolling) layer cache.

    x: (B, 1, d); k_cache/v_cache: (B, slots, Hkv, D). ``pos`` is
    either a scalar int32 absolute position (lockstep decode, same
    across the batch — the original path, kept byte-identical) or a
    (B,) int32 vector of PER-SLOT positions (continuous batching: each
    batch row is its own sequence at its own local position, so each
    writes its own cache column and masks only the columns it has
    itself written — a freshly admitted sequence at pos 0 can never
    attend to a previous occupant's stale entries). Returns
    (out, new_k, new_v).
    """
    B = x.shape[0]
    slots = k_cache.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        q, k, v = _project_qkv(p, cfg, x, jnp.full((B, 1), pos), rope=True)
        slot = pos % slots if cfg.sliding_window else pos
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=1)
        kv_pos = jnp.arange(slots)
        if cfg.sliding_window:
            # rolling buffer: once full every slot is within the window;
            # before that only slots <= pos have been written.
            valid = jnp.where(pos + 1 >= slots,
                              jnp.ones((slots,), bool), kv_pos <= pos)
        else:
            valid = kv_pos <= pos
        mask = valid[None, None, None, None, :]  # (B,Hkv,G,Sq,Skv) bcast
    else:
        # per-slot positions: batched scatter into each row's own
        # column, per-row validity mask
        q, k, v = _project_qkv(p, cfg, x, pos[:, None], rope=True)
        col = pos % slots if cfg.sliding_window else pos
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, col].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, col].set(v[:, 0].astype(v_cache.dtype))
        kv_pos = jnp.arange(slots)
        written = kv_pos[None, :] <= pos[:, None]          # (B, slots)
        if cfg.sliding_window:
            valid = jnp.where(pos[:, None] + 1 >= slots,
                              jnp.ones((B, slots), bool), written)
        else:
            valid = written
        mask = valid[:, None, None, None, :]     # (B,Hkv,G,Sq,Skv) bcast
    out = gqa_attend(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                     mask, cfg.logit_softcap)
    out = out.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------
def mlp_init(f: ParamFactory, cfg: ModelConfig, name: str = "mlp",
             d_model: Optional[int] = None, d_ff: Optional[int] = None):
    d = d_model or cfg.d_model
    dff = d_ff or cfg.d_ff
    m = f.child(name)
    m.param("w_gate", (d, dff), ("embed", "mlp"))
    m.param("w_up", (d, dff), ("embed", "mlp"))
    m.param("w_down", (dff, d), ("mlp", "embed"))


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp_apply(p, cfg: ModelConfig, x):
    act = _act(cfg.act)
    h = act(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embedding_init(f: ParamFactory, cfg: ModelConfig):
    # input table: vocab dim REPLICATED (None) so the token gather needs
    # no reshard; embed dim FSDP-sharded. The output projection is
    # vocab-sharded (TP); tied configs reshard the table at the use
    # site via the logits sharding constraint (see output_logits).
    f.param("tok_emb", (cfg.padded_vocab_size, cfg.d_model),
            (None, "embed"), scale=1.0 / math.sqrt(cfg.d_model))
    if not cfg.tie_embeddings:
        f.param("out_head", (cfg.d_model, cfg.padded_vocab_size),
                ("embed", "vocab"))


def embed_tokens(params, tokens, dtype):
    from repro.dist.context import constrain
    out = jnp.take(params["tok_emb"], tokens, axis=0).astype(dtype)
    # pin batch-sharded/embed-replicated output: the gather would
    # otherwise inherit the table's FSDP ("data") sharding on the embed
    # dim and silently drop the batch sharding for the whole network
    # (ZeRO-3 semantics: table sharded at rest, gathered at use).
    return constrain(out, ("batch",) + (None,) * (out.ndim - 1))


def output_logits(params, cfg: ModelConfig, h):
    from repro.dist.context import constrain
    if cfg.tie_embeddings:
        # reshard the (gather-layout, embed-FSDP) table into the
        # vocab-sharded TP layout BEFORE the matmul — otherwise GSPMD
        # resolves the data-axis conflict by replicating h's batch and
        # materializes unsharded (B, S, V) logits
        wt = constrain(params["tok_emb"].astype(h.dtype).T,
                       (None, "vocab"))
        logits = h @ wt
    else:
        logits = h @ params["out_head"].astype(h.dtype)
    # pin vocab-sharded logits (keeps the softmax/CE sharded over TP
    # instead of replicating a (B,S,V) tensor)
    axes = ("batch",) + (None,) * (logits.ndim - 2) + ("vocab",)
    return constrain(logits, axes)
