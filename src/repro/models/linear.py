"""The paper's linear-regression problem (Sec. VI-A).

F(w) = E[0.5 (zeta^T w - y)^2], data zeta ~ N(0, I_d), y = zeta^T w* + eps.
Workers stream (zeta, y) pairs; the per-sample gradient is
(zeta^T w - y) zeta — exactly the paper's eq. (27) (their eq. (26) writes
the squared loss; eq. (27)'s gradient lacks the factor 2, i.e. they use
the 1/2-scaled convention, which matters for the stability of the
alpha(t) schedule — see tests/test_convergence.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    params = {"w": jnp.zeros((cfg.linreg_dim,), jnp.float32)}  # paper: w(1)=0
    axes = {"w": ("embed",)}
    return params, axes


def loss(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    """batch: {"x": (B,d), "y": (B,), "weights": (B,)}. Returns the SUM of
    per-sample squared errors (AMB-DG normalizes by the global count)."""
    x, y = batch["x"], batch["y"]
    weights = batch.get("weights")
    if weights is None:
        weights = jnp.ones((x.shape[0],), jnp.float32)
    resid = x @ params["w"] - y
    per_sample = 0.5 * jnp.square(resid)
    loss_sum = jnp.sum(per_sample * weights)
    count = jnp.sum(weights)
    return loss_sum, {"count": count, "loss_sum": loss_sum}


def error_rate(w, w_star, A) -> jax.Array:
    """Paper eq. (28): ||A(w - w*)||^2 / ||A w*||^2."""
    num = jnp.sum(jnp.square(A @ (w - w_star)))
    den = jnp.sum(jnp.square(A @ w_star))
    return num / den
