"""Mixture-of-Experts FFN (Mixtral-style top-k routing), grouped dispatch.

Tokens are routed in independent groups of ``dispatch_group`` tokens
(the Mesh/Switch trick): capacity, dispatch tensors and gathers are all
per-group, so memory is O(group * E * C_g) instead of O(T * E * C) and
routing stays local to the batch shard (the group dim inherits the
batch sharding).

Two dispatch implementations, selectable via ``ModelConfig.moe_impl``
(a §Perf lever, see EXPERIMENTS.md):

* ``einsum`` — classic capacity dispatch: a dense (g, tokens, experts,
  capacity) one-hot dispatch tensor and two routing einsums. Simple and
  MXU-friendly, but the routing einsums are pure overhead FLOPs and the
  dispatch tensor is a real intermediate.
* ``gather`` — sort-free scatter/gather routing: rank tokens within
  their expert via a per-group cumsum, scatter token ids into (E*C_g)
  slots, gather activations. Zero routing matmul FLOPs, no dispatch
  tensor; only data movement.

Both drop tokens beyond capacity C_g = ceil(top_k * g / E * cf) with
identical drop order, so they are numerically equivalent.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory
from repro.models.layers import _act


def moe_init(f: ParamFactory, cfg: ModelConfig, name: str = "moe"):
    m = f.child(name)
    e, d, dff = cfg.moe.n_experts, cfg.d_model, cfg.d_ff
    m.param("w_router", (d, e), ("embed", None))
    m.param("w_gate", (e, d, dff), ("expert", "embed", "mlp"))
    m.param("w_up", (e, d, dff), ("expert", "embed", "mlp"))
    m.param("w_down", (e, dff, d), ("expert", "mlp", "embed"))


def _capacity(cfg: ModelConfig, group: int) -> int:
    mc = cfg.moe
    c = int(mc.top_k * group / mc.n_experts * mc.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _group(cfg: ModelConfig, x):
    """(B, S, d) -> (G, g, d) with the group dim inheriting the batch
    sharding (groups never span samples unless g > S)."""
    B, S, d = x.shape
    T = B * S
    g = min(cfg.moe.dispatch_group, T)
    while T % g:
        g //= 2
    return x.reshape(T // g, g, d), g


def _router(p, cfg: ModelConfig, xg):
    """xg: (G, g, d) -> gates (G,g,k), idx (G,g,k), aux scalar."""
    mc = cfg.moe
    logits = (xg @ p["w_router"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, g, E)
    gates, idx = jax.lax.top_k(probs, mc.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], mc.n_experts),
                       axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = mc.n_experts * jnp.sum(density * density_proxy) * mc.aux_loss_weight
    return gates, idx, aux


def _expert_ffn(p, cfg: ModelConfig, xe):
    """xe: (..., E, C, d) -> same, batched expert MLP."""
    act = _act(cfg.act)
    h = act(jnp.einsum("...ecd,edf->...ecf", xe,
                       p["w_gate"].astype(xe.dtype)))
    h = h * jnp.einsum("...ecd,edf->...ecf", xe,
                       p["w_up"].astype(xe.dtype))
    return jnp.einsum("...ecf,efd->...ecd", h,
                      p["w_down"].astype(xe.dtype))


def moe_apply_einsum(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """Dense grouped capacity dispatch. x: (B, S, d)."""
    mc = cfg.moe
    B, S, d = x.shape
    xg, g = _group(cfg, x)
    G = xg.shape[0]
    C = _capacity(cfg, g)
    gates, idx, aux = _router(p, cfg, xg)

    onehot = jax.nn.one_hot(idx, mc.n_experts, dtype=jnp.float32)  # (G,g,k,E)
    flat = onehot.reshape(G, g * mc.top_k, mc.n_experts)
    pos = jnp.cumsum(flat, axis=1) - 1.0
    pos = pos.reshape(G, g, mc.top_k, mc.n_experts)
    pos = jnp.sum(pos * onehot, axis=-1)                           # (G,g,k)
    keep = (pos < C).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
    dispatch = jnp.einsum("Gtke,Gtkc,Gtk->Gtec", onehot, pos_oh, keep)
    combine = jnp.einsum("Gtec,Gtk,Gtke->Gtec", dispatch,
                         gates.astype(jnp.float32), onehot)

    xe = jnp.einsum("Gtec,Gtd->Gecd", dispatch.astype(x.dtype), xg)
    ye = _expert_ffn(p, cfg, xe)
    y = jnp.einsum("Gtec,Gecd->Gtd", combine.astype(x.dtype), ye)
    return y.reshape(B, S, d), aux


def moe_apply_gather(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """Scatter/gather routing — no dispatch matmuls. x: (B, S, d)."""
    mc = cfg.moe
    B, S, d = x.shape
    xg, g = _group(cfg, x)
    G = xg.shape[0]
    C = _capacity(cfg, g)
    gates, idx, aux = _router(p, cfg, xg)
    E, k = mc.n_experts, mc.top_k

    flat_e = idx.reshape(G, g * k)                    # expert per assignment
    flat_g = gates.reshape(G, g * k)
    flat_t = jnp.tile(jnp.repeat(jnp.arange(g), k)[None], (G, 1))

    onehot = (flat_e[..., None] == jnp.arange(E)[None, None, :])
    rank = jnp.cumsum(onehot.astype(jnp.int32), axis=1) - 1
    rank = jnp.take_along_axis(rank, flat_e[..., None], axis=2)[..., 0]
    keep = rank < C
    slot = flat_e * C + jnp.minimum(rank, C - 1)      # (G, g*k)

    def per_group(xg_i, slot_i, keep_i, t_i, g_i):
        slot_token = jnp.zeros((E * C,), jnp.int32)
        slot_token = slot_token.at[
            jnp.where(keep_i, slot_i, E * C)].set(t_i.astype(jnp.int32),
                                                  mode="drop")
        xe = jnp.take(xg_i, slot_token, axis=0)       # (E*C, d)
        return xe, slot_token

    xe, _ = jax.vmap(per_group)(xg, slot, keep, flat_t, flat_g)
    xe = xe.reshape(G, E, C, d)
    ye = _expert_ffn(p, cfg, xe).reshape(G, E * C, d)

    def combine_group(ye_i, slot_i, keep_i, t_i, g_i):
        contrib = jnp.take(ye_i, slot_i, axis=0) * (
            g_i * keep_i)[:, None].astype(ye_i.dtype)
        return jnp.zeros((g, d), ye_i.dtype).at[t_i].add(contrib)

    y = jax.vmap(combine_group)(ye, slot, keep, flat_t, flat_g)
    return y.reshape(B, S, d), aux


def moe_apply(p, cfg: ModelConfig, x):
    impl = getattr(cfg, "moe_impl", "einsum")
    if impl == "gather":
        return moe_apply_gather(p, cfg, x)
    return moe_apply_einsum(p, cfg, x)
