"""Mamba2 (SSD — state-space duality) block, chunked for TPU.

Training/prefill uses the chunked SSD algorithm: within a chunk the
output is a masked (decay-weighted) attention-like matmul; across chunks
a small recurrent state (nh, hd, d_state) is carried with ``lax.scan``.
Decode is the O(1) recurrence. The chunk kernel has a Pallas
implementation in ``repro.kernels.linear_scan`` (TPU target); this module
is the pure-XLA path used for dry-runs and CPU tests.

Shapes follow the Mamba2 paper: input (B, S, d_model), inner dim
d_in = expand*d, nh = d_in/head_dim heads, n_groups shared B/C groups.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory


def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    return d_in, nh


def mamba2_init(f: ParamFactory, cfg: ModelConfig, name: str = "mamba"):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh = mamba2_dims(cfg)
    m = f.child(name)
    # fused input projection -> [z, x, B, C, dt]
    d_proj = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    m.param("w_in", (d, d_proj), ("embed", "mlp"))
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    m.param("w_conv", (s.d_conv, conv_dim), (None, "mlp"))
    m.param("b_conv", (conv_dim,), ("mlp",), init="zeros")
    m.param("a_log", (nh,), (None,), init="ones")
    m.param("dt_bias", (nh,), (None,), init="zeros")
    m.param("d_skip", (nh,), (None,), init="ones")
    m.param("norm_scale", (d_in,), ("mlp",), init="ones")
    m.param("w_out", (d_in, d), ("mlp", "embed"))


def _split_proj(p, cfg: ModelConfig, u):
    """u: (B,S,d) -> z,(B,S,d_in) xBC,(B,S,conv_dim) dt,(B,S,nh)."""
    s = cfg.ssm
    d_in, nh = mamba2_dims(cfg)
    proj = u @ p["w_in"].astype(u.dtype)
    z = proj[..., :d_in]
    xBC = proj[..., d_in:d_in + d_in + 2 * s.n_groups * s.d_state]
    dt = proj[..., -nh:]
    return z, xBC, dt


def _causal_conv(p, xBC, conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv, width d_conv. xBC: (B,S,C). If conv_state
    (B, d_conv-1, C) is given (decode), prepend it and return new state."""
    w = p["w_conv"].astype(xBC.dtype)              # (W, C)
    W = w.shape[0]
    if conv_state is not None:
        xpad = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    else:
        xpad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xpad[:, i:i + xBC.shape[1], :] * w[i] for i in range(W))
    out = out + p["b_conv"].astype(xBC.dtype)
    new_state = xpad[:, -(W - 1):, :]
    return jax.nn.silu(out), new_state


def _gated_norm(p, y, z, eps):
    y = y * jax.nn.silu(z)
    dtype = y.dtype
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y32 = y32 * jax.lax.rsqrt(var + eps)
    return (y32 * p["norm_scale"].astype(jnp.float32)).astype(dtype)


def ssd_chunked(x, dt, A, B, C, chunk: int, unroll: bool = False):
    """Chunked SSD scan (pure XLA reference; Pallas version in kernels/).

    x: (Bt, S, nh, hd); dt: (Bt, S, nh) (post-softplus);
    A: (nh,) negative decay rates; B, C: (Bt, S, g, d_state).
    Returns y: (Bt, S, nh, hd) and final state (Bt, nh, hd, d_state).
    """
    Bt, S, nh, hd = x.shape
    g = B.shape[2]
    rep = nh // g
    nchunks = S // chunk
    assert S % chunk == 0

    xc = x.reshape(Bt, nchunks, chunk, nh, hd)
    dtc = dt.reshape(Bt, nchunks, chunk, nh)
    Bc = B.reshape(Bt, nchunks, chunk, g, -1)
    Cc = C.reshape(Bt, nchunks, chunk, g, -1)

    dA = dtc * A[None, None, None, :]                       # (Bt,nc,L,nh) <= 0
    cum = jnp.cumsum(dA, axis=2)                            # running log-decay
    seg_total = cum[:, :, -1, :]                            # (Bt,nc,nh)

    # --- intra-chunk (diagonal blocks): attention-like masked matmul ---
    # L_ij = exp(cum_i - cum_j + dA? ) for i >= j  (decay from j..i incl. i's dt·A? )
    # SSD convention: y_i += C_i . (sum_{j<=i} exp(cum_i - cum_j) dt_j B_j x_j)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (Bt,nc,L,L,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: the i<j entries have diff > 0 and would overflow,
    # poisoning gradients through the where
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    L = jnp.exp(diff)
    CB = jnp.einsum("bnigs,bnjgs->bnijg", Cc, Bc)           # (Bt,nc,L,L,g)
    CB = jnp.repeat(CB, rep, axis=-1)                       # (Bt,nc,L,L,nh)
    scores = CB * L * dtc[:, :, None, :, :]                 # weight by dt_j
    y_diag = jnp.einsum("bnijh,bnjhd->bnihd", scores.astype(x.dtype), xc)

    # --- chunk states: decay-weighted sum of B x within each chunk ---
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)  # (Bt,nc,L,nh)
    Bfull = jnp.repeat(Bc, rep, axis=3) if g != nh else Bc  # (Bt,nc,L,nh,s)
    Bx = jnp.einsum("bnlhs,bnlhd->bnhds",
                    Bfull, (xc * (dtc * decay_to_end)[..., None]).astype(Bfull.dtype))

    # --- inter-chunk recurrence over nchunks (small state) ---
    def step(h, inp):
        bx, seg = inp                                        # (Bt,nh,hd,s), (Bt,nh)
        h_new = h * jnp.exp(seg)[:, :, None, None] + bx
        return h_new, h                                      # emit state *entering* chunk

    h0 = jnp.zeros((Bt, nh, hd, Bc.shape[-1]), jnp.float32)
    h_last, h_in = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(Bx, 1, 0).astype(jnp.float32),
         jnp.moveaxis(seg_total, 1, 0).astype(jnp.float32)),
        unroll=unroll)
    h_in = jnp.moveaxis(h_in, 0, 1)                          # (Bt,nc,nh,hd,s)

    # --- inter-chunk contribution: y_i += exp(cum_i) C_i . h_in ---
    Cfull = jnp.repeat(Cc, rep, axis=3) if g != nh else Cc
    y_off = jnp.einsum("bnlhs,bnhds->bnlhd",
                       (Cfull * jnp.exp(cum)[..., None].astype(Cfull.dtype)),
                       h_in.astype(Cfull.dtype))

    y = (y_diag + y_off.astype(y_diag.dtype)).reshape(Bt, S, nh, hd)
    return y, h_last


def mamba2_apply(p, cfg: ModelConfig, u):
    """Full-sequence (train/prefill). u: (B,S,d) -> (B,S,d)."""
    s = cfg.ssm
    d_in, nh = mamba2_dims(cfg)
    z, xBC, dt = _split_proj(p, cfg, u)
    xBC, _ = _causal_conv(p, xBC)
    x = xBC[..., :d_in]
    Bmat = xBC[..., d_in:d_in + s.n_groups * s.d_state]
    Cmat = xBC[..., d_in + s.n_groups * s.d_state:]
    Bt, S, _ = u.shape
    chunk = min(s.chunk_size, S)
    pad = (-S) % chunk
    if pad:  # pad to a chunk multiple; padded steps have dt=0 => no effect
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-30.0)
    Sp = S + pad
    x = x.reshape(Bt, Sp, nh, s.head_dim)
    Bmat = Bmat.reshape(Bt, Sp, s.n_groups, s.d_state)
    Cmat = Cmat.reshape(Bt, Sp, s.n_groups, s.d_state)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) +
                             p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, _ = ssd_chunked(x, dt_act, A, Bmat, Cmat, chunk,
                       unroll=cfg.scan_unroll)
    y = y + x * p["d_skip"].astype(x.dtype)[None, None, :, None]
    if pad:
        y = y[:, :S]
    y = y.reshape(Bt, S, d_in)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    return y @ p["w_out"].astype(u.dtype)


# ---------------------------------------------------------------------------
# Decode (O(1) recurrence)
# ---------------------------------------------------------------------------
def mamba2_state_init(cfg: ModelConfig, n_layers: int, batch: int,
                      dtype=jnp.float32):
    s = cfg.ssm
    d_in, nh = mamba2_dims(cfg)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return {
        "ssm": jnp.zeros((n_layers, batch, nh, s.head_dim, s.d_state), dtype),
        "conv": jnp.zeros((n_layers, batch, s.d_conv - 1, conv_dim), dtype),
    }


def mamba2_state_axes():
    return {"ssm": ("layers", "batch", "heads", None, None),
            "conv": ("layers", "batch", None, "mlp")}


def mamba2_decode(p, cfg: ModelConfig, u, ssm_state, conv_state):
    """One token. u: (B,1,d); ssm_state: (B,nh,hd,ds); conv_state:
    (B, d_conv-1, conv_dim). Returns (y, new_ssm, new_conv)."""
    s = cfg.ssm
    d_in, nh = mamba2_dims(cfg)
    z, xBC, dt = _split_proj(p, cfg, u)
    xBC, new_conv = _causal_conv(p, xBC, conv_state)
    x = xBC[..., :d_in].reshape(-1, nh, s.head_dim)
    Bmat = xBC[..., d_in:d_in + s.n_groups * s.d_state].reshape(-1, s.n_groups, s.d_state)
    Cmat = xBC[..., d_in + s.n_groups * s.d_state:].reshape(-1, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    Bfull = jnp.repeat(Bmat, rep, axis=1)
    Cfull = jnp.repeat(Cmat, rep, axis=1)
    dt_act = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                             p["dt_bias"].astype(jnp.float32))   # (B,nh)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt_act * A[None, :])                          # (B,nh)
    upd = jnp.einsum("bhd,bhs->bhds",
                     (x * dt_act[..., None].astype(x.dtype)).astype(jnp.float32),
                     Bfull.astype(jnp.float32))
    new_ssm = ssm_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhds,bhs->bhd", new_ssm.astype(x.dtype), Cfull.astype(x.dtype))
    y = y + x * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(-1, 1, d_in)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    return y @ p["w_out"].astype(u.dtype), new_ssm, new_conv
