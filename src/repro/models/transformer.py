"""Decoder-only LM covering the dense / MoE / xLSTM / hybrid families,
with scan-over-layers (stacked params), KV-cache decode, and logical
sharding axes throughout. Families:

  dense  : [attn -> mlp] x L
  moe    : [attn -> moe_ffn] x L            (mixtral)
  ssm    : [mLSTM | sLSTM] x L              (xlstm; slstm_every-th is sLSTM)
  hybrid : [mamba2] x L + shared attn block every k layers (zamba2)
  vlm    : dense backbone + patch-embedding stub, prefix-LM mask
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (DENSE, HYBRID, MOE, SSM, VLM, ModelConfig)
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import ParamFactory, split_factory
from repro.models.layers import (attention_apply, attention_init, cache_axes,
                                 causal_mask, decode_attention, embed_tokens,
                                 embedding_init, init_kv_cache, mlp_apply,
                                 mlp_init, output_logits, prefix_lm_mask,
                                 rmsnorm, rmsnorm_init)
from repro.models.moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _sp(h, cfg: ModelConfig):
    """Sequence-parallel residual boundary (no-op without a mesh)."""
    if not cfg.seq_parallel or h.shape[1] == 1:
        return h
    from repro.dist.context import constrain
    return constrain(h, ("batch", "seq_sp", None))


def _remat(fn, cfg: ModelConfig):
    """Wrap a scanned block in jax.checkpoint per cfg.block_remat."""
    if cfg.block_remat == "full":
        return jax.checkpoint(fn)
    if cfg.block_remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return fn


def _layer_init(f: ParamFactory, cfg: ModelConfig):
    """One scanned layer's params (dense/moe/hybrid backbone)."""
    rmsnorm_init(f, "ln1", cfg.d_model)
    if cfg.family in (DENSE, VLM):
        attention_init(f, cfg)
        rmsnorm_init(f, "ln2", cfg.d_model)
        mlp_init(f, cfg)
    elif cfg.family == MOE:
        attention_init(f, cfg)
        rmsnorm_init(f, "ln2", cfg.d_model)
        moe_init(f, cfg)
    elif cfg.family == HYBRID:
        ssm_mod.mamba2_init(f, cfg)
    else:
        raise ValueError(cfg.family)


def init(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    """Returns (params, logical_axes) with matching pytree structure."""
    import numpy as np
    dtype = jnp.dtype(cfg.param_dtype)

    def build(f: ParamFactory):
        embedding_init(f, cfg)
        rmsnorm_init(f, "ln_final", cfg.d_model)
        if cfg.family == SSM:
            # xLSTM: alternating block types -> two stacks
            x = cfg.xlstm
            n_sl = cfg.n_layers // x.slstm_every
            n_ml = cfg.n_layers - n_sl
            f.vmapped_children("mlstm_layers", n_ml, lambda g: (
                rmsnorm_init(g, "ln1", cfg.d_model),
                xlstm_mod.mlstm_init(g, cfg)))
            f.vmapped_children("slstm_layers", n_sl, lambda g: (
                rmsnorm_init(g, "ln1", cfg.d_model),
                xlstm_mod.slstm_init(g, cfg)))
        elif cfg.family == HYBRID:
            f.vmapped_children("layers", cfg.n_layers,
                               lambda g: _layer_init(g, cfg))
            sh = f.child("shared_attn")
            rmsnorm_init(sh, "ln1", cfg.d_model)
            attention_init(sh, cfg)
            rmsnorm_init(sh, "ln2", cfg.d_model)
            mlp_init(sh, cfg)
        else:
            f.vmapped_children("layers", cfg.n_layers,
                               lambda g: _layer_init(g, cfg))
        if cfg.family == VLM and cfg.frontend:
            # stub frontend: a learned projection applied to precomputed
            # patch embeddings + positional table
            f.param("patch_proj", (cfg.d_model, cfg.d_model),
                    ("embed", "embed2"))
            f.param("patch_pos", (cfg.n_frontend_tokens, cfg.d_model),
                    (None, "embed"))

    return split_factory(build, key, dtype)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _block_apply(layer_p, cfg: ModelConfig, h, positions, mask,
                 mask_fn=None):
    h = h + attention_apply(
        layer_p["attn"], cfg, rmsnorm(h, layer_p["ln1"], cfg.norm_eps),
        positions, mask, mask_fn=mask_fn)
    moe_aux = jnp.float32(0.0)
    if cfg.family == MOE:
        y, moe_aux = moe_apply(layer_p["moe"], cfg,
                               rmsnorm(h, layer_p["ln2"], cfg.norm_eps))
        h = h + y
    else:
        h = h + mlp_apply(layer_p["mlp"], cfg,
                          rmsnorm(h, layer_p["ln2"], cfg.norm_eps))
    return h, moe_aux


def _mamba_block_apply(layer_p, cfg: ModelConfig, h):
    return h + ssm_mod.mamba2_apply(
        layer_p["mamba"], cfg, rmsnorm(h, layer_p["ln1"], cfg.norm_eps))


def forward(params, cfg: ModelConfig, tokens, *, extra: Optional[Dict] = None,
            prefix_len=None) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S_text) int32. Returns (logits over full sequence,
    moe_aux_loss). For VLM, ``extra['patches']`` (B, P, d) is prepended."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params, tokens, dtype) * math.sqrt(cfg.d_model)
    if cfg.family == VLM and extra is not None and "patches" in extra:
        patches = extra["patches"].astype(dtype)
        patches = patches @ params["patch_proj"].astype(dtype)
        patches = patches + params["patch_pos"].astype(dtype)[None]
        h = jnp.concatenate([patches, h], axis=1)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :].astype(jnp.int32)

    if cfg.prefix_lm:
        plen = prefix_len if prefix_len is not None else (
            cfg.n_frontend_tokens if cfg.family == VLM else 0)
        mask_fn = lambda off, qn: prefix_lm_mask(qn, S, plen, q_offset=off)
    else:
        mask_fn = lambda off, qn: causal_mask(
            qn, S, window=cfg.sliding_window, q_offset=off)
    mask = mask_fn(0, S)

    aux_total = jnp.float32(0.0)

    if cfg.family == SSM:
        h, aux_total = _xlstm_forward(params, cfg, h)
    elif cfg.family == HYBRID:
        h = _hybrid_forward(params, cfg, h, positions, mask, mask_fn)
    else:
        block = _remat(
            lambda layer_p, hh: _block_apply(layer_p, cfg, hh, positions,
                                             mask, mask_fn), cfg)

        def scan_body(carry, layer_p):
            hh, aux = carry
            hh, a = block(layer_p, _sp(hh, cfg))
            return (hh, aux + a), None
        (h, aux_total), _ = jax.lax.scan(scan_body, (h, aux_total),
                                         params["layers"],
                                         unroll=cfg.scan_unroll)

    h = rmsnorm(h, params["ln_final"], cfg.norm_eps)
    logits = output_logits(params, cfg, h)
    return logits, aux_total


def _xlstm_forward(params, cfg: ModelConfig, h):
    """Interleave mLSTM / sLSTM blocks in layer order; the two stacks are
    scanned separately but applied in their true order via index map."""
    x = cfg.xlstm
    # layer i is sLSTM iff (i+1) % slstm_every == 0
    sl_block = _remat(lambda lp, hh: xlstm_mod.slstm_block_apply(
        lp["slstm"], cfg, rmsnorm(hh, lp["ln1"], cfg.norm_eps))[0], cfg)
    ml_block = _remat(lambda lp, hh: xlstm_mod.mlstm_block_apply(
        lp["mlstm"], cfg, rmsnorm(hh, lp["ln1"], cfg.norm_eps)), cfg)
    ml_i, sl_i = 0, 0
    for i in range(cfg.n_layers):
        if (i + 1) % x.slstm_every == 0:
            lp = jax.tree.map(lambda a: a[sl_i], params["slstm_layers"])
            h = h + sl_block(lp, h)
            sl_i += 1
        else:
            lp = jax.tree.map(lambda a: a[ml_i], params["mlstm_layers"])
            h = h + ml_block(lp, h)
            ml_i += 1
    return h, jnp.float32(0.0)


def _hybrid_forward(params, cfg: ModelConfig, h, positions, mask,
                    mask_fn=None):
    """zamba2: groups of ``shared_attn_every`` mamba layers, a single
    *shared* attention+mlp block applied after each group."""
    k = cfg.shared_attn_every
    n_groups = cfg.n_layers // k
    sh = params["shared_attn"]

    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, k) + a.shape[1:]), params["layers"])

    mamba_block = _remat(
        lambda layer_p, hhh: _mamba_block_apply(layer_p, cfg, hhh), cfg)
    shared_block = _remat(
        lambda _p, hh: hh
        + attention_apply(sh["attn"], cfg,
                          rmsnorm(hh, sh["ln1"], cfg.norm_eps),
                          positions, mask, mask_fn=mask_fn), cfg)

    def group_body(hh, group_p):
        def layer_body(hhh, layer_p):
            return mamba_block(layer_p, _sp(hhh, cfg)), None
        hh, _ = jax.lax.scan(layer_body, hh, group_p,
                             unroll=cfg.scan_unroll)
        # shared attention block (same params every group)
        hh = shared_block(None, hh)
        hh = hh + mlp_apply(sh["mlp"], cfg,
                            rmsnorm(hh, sh["ln2"], cfg.norm_eps))
        return hh, None

    h, _ = jax.lax.scan(group_body, h, grouped, unroll=cfg.scan_unroll)
    return h


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    """Per-sample-weighted next-token cross entropy.

    batch: {"tokens": (B,S) int32, "weights": (B,) f32, optional extras}.
    Returns (sum_weighted_loss, {"tokens": weighted token count, ...}) so
    the AMB-DG aggregation can normalize by the *global* count (paper
    eq. (5)).
    """
    tokens = batch["tokens"]
    weights = batch.get("weights")
    if weights is None:
        weights = jnp.ones((tokens.shape[0],), jnp.float32)
    extra = {k: v for k, v in batch.items()
             if k not in ("tokens", "weights", "targets")}
    # run the forward at the full (power-of-two) sequence length and
    # slice the logits — slicing the *inputs* would make every internal
    # shape odd (S-1) and break sharding divisibility throughout
    logits, aux = forward(params, cfg, tokens, extra=extra or None)
    # VLM prepends patches: logits cover [patches, text]; loss on text only
    if cfg.family == VLM and "patches" in batch:
        logits = logits[:, batch["patches"].shape[1]:, :]
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    per_sample = -jnp.sum(ll, axis=-1)                     # (B,)
    n_tok_per_sample = targets.shape[1]
    loss_sum = jnp.sum(per_sample * weights)
    count = jnp.sum(weights) * n_tok_per_sample
    return loss_sum + aux * count, {"count": count,
                                    "loss_sum": loss_sum}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> Tuple[Dict, Dict]:
    """Returns (cache pytree, logical axes pytree)."""
    if cfg.family == SSM:
        x = cfg.xlstm
        n_sl = cfg.n_layers // x.slstm_every
        n_ml = cfg.n_layers - n_sl
        nh, hd, _ = xlstm_mod.slstm_dims(cfg)
        cache = {
            "mlstm": xlstm_mod.mlstm_state_init(cfg, n_ml, batch),
            "slstm": {
                "h": jnp.zeros((n_sl, batch, nh, hd), jnp.float32),
                "c": jnp.zeros((n_sl, batch, nh, hd), jnp.float32),
                "n": jnp.zeros((n_sl, batch, nh, hd), jnp.float32),
                "m": jnp.full((n_sl, batch, nh, hd), -30.0, jnp.float32),
            },
        }
        laxes = ("layers", "batch", "heads", None)
        axes = {
            "mlstm": {"C": ("layers", "batch", "heads", None, None),
                      "n": laxes, "m": ("layers", "batch", "heads")},
            "slstm": {k: laxes for k in ("h", "c", "n", "m")},
        }
        return cache, axes
    if cfg.family == HYBRID:
        cache = {
            "mamba": ssm_mod.mamba2_state_init(cfg, cfg.n_layers, batch),
            "shared": init_kv_cache(cfg, cfg.n_layers // cfg.shared_attn_every,
                                    batch, max_len, dtype),
        }
        axes = {"mamba": ssm_mod.mamba2_state_axes(),
                "shared": cache_axes(cfg)}
        return cache, axes
    cache = init_kv_cache(cfg, cfg.n_layers, batch, max_len, dtype)
    return cache, cache_axes(cfg)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """One-token decode. tokens: (B,1) int32; pos: scalar int32 (one
    absolute position shared by the batch — lockstep decode) or (B,)
    int32 per-slot positions (continuous batching; see
    ``layers.decode_attention``). Returns (logits (B,1,V), new_cache)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params, tokens, dtype) * math.sqrt(cfg.d_model)

    if cfg.family == SSM:
        h, cache = _xlstm_decode(params, cfg, h, cache)
    elif cfg.family == HYBRID:
        h, cache = _hybrid_decode(params, cfg, h, cache, pos)
    else:
        def scan_body(carry, xs):
            hh = carry
            layer_p, k_c, v_c = xs
            hn = rmsnorm(hh, layer_p["ln1"], cfg.norm_eps)
            y, k_c, v_c = decode_attention(layer_p["attn"], cfg, hn, pos,
                                           k_c, v_c)
            hh = hh + y
            if cfg.family == MOE:
                y2, _ = moe_apply(layer_p["moe"], cfg,
                                  rmsnorm(hh, layer_p["ln2"], cfg.norm_eps))
            else:
                y2 = mlp_apply(layer_p["mlp"], cfg,
                               rmsnorm(hh, layer_p["ln2"], cfg.norm_eps))
            return hh + y2, (k_c, v_c)

        h, (new_k, new_v) = jax.lax.scan(
            scan_body, h, (params["layers"], cache["k"], cache["v"]))
        cache = {"k": new_k, "v": new_v}

    h = rmsnorm(h, params["ln_final"], cfg.norm_eps)
    return output_logits(params, cfg, h), cache


def _xlstm_decode(params, cfg: ModelConfig, h, cache):
    x = cfg.xlstm
    ml_i, sl_i = 0, 0
    m_st, s_st = cache["mlstm"], cache["slstm"]
    new_m = jax.tree.map(lambda a: a, m_st)
    new_s = jax.tree.map(lambda a: a, s_st)
    for i in range(cfg.n_layers):
        if (i + 1) % x.slstm_every == 0:
            lp = jax.tree.map(lambda a: a[sl_i], params["slstm_layers"])
            hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            st = tuple(new_s[k][sl_i] for k in ("h", "c", "n", "m"))
            y, st_out = xlstm_mod.slstm_block_apply(lp["slstm"], cfg, hn, st)
            h = h + y
            for k, v in zip(("h", "c", "n", "m"), st_out):
                new_s[k] = new_s[k].at[sl_i].set(v)
            sl_i += 1
        else:
            lp = jax.tree.map(lambda a: a[ml_i], params["mlstm_layers"])
            hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            st = {k: new_m[k][ml_i] for k in ("C", "n", "m")}
            y, st_out = xlstm_mod.mlstm_block_decode(lp["mlstm"], cfg, hn, st)
            h = h + y
            for k in ("C", "n", "m"):
                new_m[k] = new_m[k].at[ml_i].set(st_out[k])
            ml_i += 1
    return h, {"mlstm": new_m, "slstm": new_s}


def _hybrid_decode(params, cfg: ModelConfig, h, cache, pos):
    k = cfg.shared_attn_every
    n_groups = cfg.n_layers // k
    sh = params["shared_attn"]
    mamba_c = cache["mamba"]
    grouped_p = jax.tree.map(
        lambda a: a.reshape((n_groups, k) + a.shape[1:]), params["layers"])
    grouped_ssm = jax.tree.map(
        lambda a: a.reshape((n_groups, k) + a.shape[1:]), mamba_c)

    def group_body(hh, xs):
        group_p, group_state, k_c, v_c = xs

        def layer_body(hhh, ys):
            layer_p, ssm_st, conv_st = ys
            hn = rmsnorm(hhh, layer_p["ln1"], cfg.norm_eps)
            y, ssm_new, conv_new = ssm_mod.mamba2_decode(
                layer_p["mamba"], cfg, hn, ssm_st, conv_st)
            return hhh + y, (ssm_new, conv_new)

        hh, (ssm_new, conv_new) = jax.lax.scan(
            layer_body, hh, (group_p, group_state["ssm"], group_state["conv"]))
        hn = rmsnorm(hh, sh["ln1"], cfg.norm_eps)
        y, k_c, v_c = decode_attention(sh["attn"], cfg, hn, pos, k_c, v_c)
        hh = hh + y
        hh = hh + mlp_apply(sh["mlp"], cfg,
                            rmsnorm(hh, sh["ln2"], cfg.norm_eps))
        return hh, ({"ssm": ssm_new, "conv": conv_new}, k_c, v_c)

    h, (new_mamba, new_k, new_v) = jax.lax.scan(
        group_body, h,
        (grouped_p, grouped_ssm, cache["shared"]["k"], cache["shared"]["v"]))
    new_mamba = jax.tree.map(
        lambda a: a.reshape((n_groups * k,) + a.shape[2:]), new_mamba)
    return h, {"mamba": new_mamba, "shared": {"k": new_k, "v": new_v}}
