"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM
(scalar memory, strictly recurrent), per arXiv:2405.04517.

mLSTM is a gated linear-attention recurrence
    C_t = f_t C_{t-1} + i_t k_t v_t^T,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = (C_t^T q_t) / max(|n_t^T q_t|, exp(-m_t))
with exponential input gates stabilized by a running max ``m``. We
implement the chunkwise-parallel form: quadratic within a chunk,
a stabilized (C, n, m) carry across chunks (lax.scan). Decode is the
O(1) recurrence. sLSTM has no parallel form — it is a lax.scan over
time with block-diagonal recurrent weights (the paper accepts this).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamFactory
from repro.models.layers import rmsnorm, rmsnorm_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    nh = cfg.n_heads
    hd = d_in // nh
    return d_in, nh, hd


def mlstm_init(f: ParamFactory, cfg: ModelConfig, name: str = "mlstm"):
    d = cfg.d_model
    d_in, nh, hd = mlstm_dims(cfg)
    m = f.child(name)
    m.param("w_up", (d, 2 * d_in), ("embed", "mlp"))
    m.param("w_q", (d_in, d_in), ("mlp", "heads"))
    m.param("w_k", (d_in, d_in), ("mlp", "heads"))
    m.param("w_v", (d_in, d_in), ("mlp", "heads"))
    m.param("w_i", (d_in, nh), ("mlp", None))   # input gate pre-acts
    m.param("w_f", (d_in, nh), ("mlp", None))   # forget gate pre-acts
    m.param("b_i", (nh,), (None,), init="zeros")
    m.param("b_f", (nh,), (None,), init="ones")
    m.param("norm_scale", (d_in,), ("mlp",), init="ones")
    m.param("w_down", (d_in, d), ("mlp", "embed"))


def _mlstm_chunk(q, k, v, logf, logi, carry):
    """One chunk, stabilized. q,k,v: (B,nh,L,hd); logf,logi: (B,nh,L);
    carry = (C (B,nh,hd,hd), n (B,nh,hd), m (B,nh))."""
    C_st, n_st, m_st = carry
    L = q.shape[2]
    hd = q.shape[3]
    lf = jnp.cumsum(logf, axis=-1)                       # inclusive (B,nh,L)
    F = lf[..., -1]                                      # (B,nh)

    # intra-chunk log weights: D~_ij = lf_i - lf_j + logi_j, i >= j
    Dt = lf[..., :, None] - lf[..., None, :] + logi[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    Dt = jnp.where(mask, Dt, NEG_INF)
    inter_log = lf + m_st[..., None]                     # (B,nh,L)
    m_row = jnp.maximum(jnp.max(Dt, axis=-1), inter_log)  # (B,nh,L)

    S = jnp.exp(Dt - m_row[..., None])                   # (B,nh,L,L)
    qk = jnp.einsum("bhid,bhjd->bhij", q, k).astype(jnp.float32) / (hd ** 0.5)
    num_intra = jnp.einsum("bhij,bhjd->bhid", (S * qk).astype(v.dtype), v)
    # normalizer: n_i = sum_j decay_ij i_j k_j; denominator uses q_i . n_i
    den_intra = jnp.einsum("bhij,bhij->bhi", S, qk)      # (B,nh,L)

    w_inter = jnp.exp(inter_log - m_row)                 # (B,nh,L)
    num_inter = jnp.einsum("bhid,bhde->bhie", q, C_st.astype(q.dtype))
    num_inter = num_inter * w_inter[..., None].astype(q.dtype) / (hd ** 0.5)
    den_inter = jnp.einsum("bhid,bhd->bhi", q, n_st.astype(q.dtype)) / (hd ** 0.5)
    den_inter = den_inter * w_inter

    num = num_intra.astype(jnp.float32) + num_inter.astype(jnp.float32)
    den = den_intra + den_inter
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))
    y = num / denom[..., None]                           # (B,nh,L,hd)

    # ---- carry update ----
    # weights for token j surviving to chunk end: F - lf_j + logi_j
    w_end_log = F[..., None] - lf + logi                 # (B,nh,L)
    m_new = jnp.maximum(m_st + F, jnp.max(w_end_log, axis=-1))
    w_end = jnp.exp(w_end_log - m_new[..., None])
    C_new = (C_st * jnp.exp(m_st + F - m_new)[..., None, None]
             + jnp.einsum("bhjd,bhje,bhj->bhde",
                          k.astype(jnp.float32), v.astype(jnp.float32), w_end))
    n_new = (n_st * jnp.exp(m_st + F - m_new)[..., None]
             + jnp.einsum("bhjd,bhj->bhd", k.astype(jnp.float32), w_end))
    return y, (C_new, n_new, m_new)


def mlstm_sequence(q, k, v, logf, logi, chunk: int, unroll: bool = False):
    """q,k,v: (B,S,nh,hd); gates (B,S,nh). Returns y (B,S,nh,hd)."""
    B, S, nh, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    qc = jnp.moveaxis(q.reshape(B, nc, chunk, nh, hd), 3, 2)  # (B,nc,nh,L,hd)
    kc = jnp.moveaxis(k.reshape(B, nc, chunk, nh, hd), 3, 2)
    vc = jnp.moveaxis(v.reshape(B, nc, chunk, nh, hd), 3, 2)
    lfc = jnp.moveaxis(logf.reshape(B, nc, chunk, nh), 3, 2)  # (B,nc,nh,L)
    lic = jnp.moveaxis(logi.reshape(B, nc, chunk, nh), 3, 2)

    carry0 = (jnp.zeros((B, nh, hd, hd), jnp.float32),
              jnp.zeros((B, nh, hd), jnp.float32),
              jnp.full((B, nh), NEG_INF, jnp.float32))

    def step(carry, inp):
        qq, kk, vv, lf, li = inp
        y, carry = _mlstm_chunk(qq, kk, vv, lf, li, carry)
        return carry, y

    _, ys = jax.lax.scan(step, carry0,
                         (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
                          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(lfc, 1, 0),
                          jnp.moveaxis(lic, 1, 0)), unroll=unroll)
    ys = jnp.moveaxis(ys, 0, 1)                           # (B,nc,nh,L,hd)
    ys = jnp.moveaxis(ys, 2, 3).reshape(B, S, nh, hd)
    return ys


def mlstm_block_apply(p, cfg: ModelConfig, x):
    """x: (B,S,d) -> (B,S,d). Up-proj -> (mLSTM path, gate path)."""
    d_in, nh, hd = mlstm_dims(cfg)
    B, S, _ = x.shape
    up = x @ p["w_up"].astype(x.dtype)
    a, gate = up[..., :d_in], up[..., d_in:]
    q = (a @ p["w_q"].astype(x.dtype)).reshape(B, S, nh, hd)
    k = (a @ p["w_k"].astype(x.dtype)).reshape(B, S, nh, hd)
    v = (a @ p["w_v"].astype(x.dtype)).reshape(B, S, nh, hd)
    logi = (a @ p["w_i"].astype(x.dtype)).astype(jnp.float32) + p["b_i"]
    logf_pre = (a @ p["w_f"].astype(x.dtype)).astype(jnp.float32) + p["b_f"]
    logf = jax.nn.log_sigmoid(logf_pre)
    y = mlstm_sequence(q, k, v, logf, logi, cfg.xlstm.conv_width * 64,
                       unroll=cfg.scan_unroll)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(y, p["norm_scale"], cfg.norm_eps)
    y = y * jax.nn.silu(gate)
    return y @ p["w_down"].astype(x.dtype)


# -- decode --
def mlstm_state_init(cfg: ModelConfig, n_blocks: int, batch: int):
    d_in, nh, hd = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((n_blocks, batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((n_blocks, batch, nh, hd), jnp.float32),
        "m": jnp.full((n_blocks, batch, nh), NEG_INF, jnp.float32),
    }


def mlstm_block_decode(p, cfg: ModelConfig, x, state):
    """x: (B,1,d); state = dict(C,n,m) for this block."""
    d_in, nh, hd = mlstm_dims(cfg)
    B = x.shape[0]
    up = x @ p["w_up"].astype(x.dtype)
    a, gate = up[..., :d_in], up[..., d_in:]
    q = (a @ p["w_q"].astype(x.dtype)).reshape(B, nh, hd)
    k = (a @ p["w_k"].astype(x.dtype)).reshape(B, nh, hd)
    v = (a @ p["w_v"].astype(x.dtype)).reshape(B, nh, hd)
    logi = ((a @ p["w_i"].astype(x.dtype)).astype(jnp.float32) + p["b_i"]).reshape(B, nh)
    logf = jax.nn.log_sigmoid(
        ((a @ p["w_f"].astype(x.dtype)).astype(jnp.float32) + p["b_f"]).reshape(B, nh))
    C_st, n_st, m_st = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m_st, logi)
    f_w = jnp.exp(logf + m_st - m_new)
    i_w = jnp.exp(logi - m_new)
    C_new = C_st * f_w[..., None, None] + jnp.einsum(
        "bhd,bhe,bh->bhde", k.astype(jnp.float32), v.astype(jnp.float32), i_w)
    n_new = n_st * f_w[..., None] + k.astype(jnp.float32) * i_w[..., None]
    qs = q.astype(jnp.float32) / (hd ** 0.5)
    num = jnp.einsum("bhd,bhde->bhe", qs, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n_new)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(y, p["norm_scale"], cfg.norm_eps)
    y = y * jax.nn.silu(gate)
    return y @ p["w_down"].astype(x.dtype), {"C": C_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_dims(cfg: ModelConfig):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    d_ff = int(cfg.xlstm.proj_factor_slstm * cfg.d_model)
    return nh, hd, d_ff


def slstm_init(f: ParamFactory, cfg: ModelConfig, name: str = "slstm"):
    d = cfg.d_model
    nh, hd, d_ff = slstm_dims(cfg)
    m = f.child(name)
    # 4 gates (z, i, f, o): input weights (d, 4d) + block-diag recurrent
    m.param("w_x", (d, 4 * d), ("embed", "mlp"))
    m.param("w_h", (nh, hd, 4 * hd), (None, None, None))  # block-diagonal R
    m.param("b", (4 * d,), ("mlp",), init="zeros")
    # gated ffn after the recurrence
    m.param("w_ff_gate", (d, d_ff), ("embed", "mlp"))
    m.param("w_ff_up", (d, d_ff), ("embed", "mlp"))
    m.param("w_ff_down", (d_ff, d), ("mlp", "embed"))


def slstm_scan(p, cfg: ModelConfig, x, init_state=None):
    """Strict recurrence over time. x: (B,S,d)."""
    B, S, d = x.shape
    nh, hd, _ = slstm_dims(cfg)
    xg = x @ p["w_x"].astype(x.dtype) + p["b"].astype(x.dtype)  # (B,S,4d)
    xg = xg.reshape(B, S, 4, nh, hd)

    if init_state is None:
        init_state = slstm_zero_state(cfg, B)
    w_h = p["w_h"].astype(jnp.float32)                    # (nh,hd,4hd)

    def step(carry, xt):
        h, c, n, m = carry                                # h,c,n: (B,nh,hd); m: (B,nh,hd)
        rec = jnp.einsum("bhd,hde->bhe", h, w_h).reshape(B, nh, 4, hd)
        # xt: (B,4,nh,hd); rec: (B,nh,4,hd) -> align to (B,4,nh,hd)
        pre = xt.astype(jnp.float32) + jnp.moveaxis(rec, 2, 1)
        z = jnp.tanh(pre[:, 0])
        i_pre, f_pre, o_pre = pre[:, 1], pre[:, 2], pre[:, 3]
        m_new = jnp.maximum(jax.nn.log_sigmoid(f_pre) + m, i_pre)
        i_w = jnp.exp(i_pre - m_new)
        f_w = jnp.exp(jax.nn.log_sigmoid(f_pre) + m - m_new)
        c_new = f_w * c + i_w * z
        n_new = f_w * n + i_w
        h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    (h, c, n, m), hs = jax.lax.scan(step, init_state,
                                    jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    return y, (h, c, n, m)


def slstm_zero_state(cfg: ModelConfig, batch: int):
    nh, hd, _ = slstm_dims(cfg)
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return (z, z, z, jnp.full((batch, nh, hd), -30.0, jnp.float32))


def slstm_block_apply(p, cfg: ModelConfig, x, init_state=None):
    y, state = slstm_scan(p, cfg, x, init_state)
    act = jax.nn.gelu
    h = act(y @ p["w_ff_gate"].astype(x.dtype)) * (y @ p["w_ff_up"].astype(x.dtype))
    return h @ p["w_ff_down"].astype(x.dtype), state
