"""Pluggable optimizers. ``dual_averaging`` is the paper-faithful
default; sgd/adam compose the same delayed anytime gradients with
standard optimizers (beyond-paper comparisons, cf. paper Sec. III: "AMB-DG
can be implemented using other gradient-based algorithms as well")."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.core import dual_averaging as da


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]
    # update(opt_state, params, grad) -> (new_params, new_opt_state)


def dual_averaging_optimizer(rc: RunConfig) -> Optimizer:
    cfg = rc.ambdg

    def update(opt_state: da.DualAveragingState, params, g):
        w, new_state = da.update(opt_state, g, cfg)
        return w, new_state

    return Optimizer(init=da.init, update=update)


def sgd_optimizer(rc: RunConfig, lr: float = 1e-2,
                  momentum: float = 0.9) -> Optimizer:
    def init(params):
        return (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),)

    def update(opt_state, params, g):
        (m,) = opt_state
        m = jax.tree.map(lambda mi, gi: momentum * mi + gi, m, g)
        params = jax.tree.map(
            lambda p, mi: (p.astype(jnp.float32) - lr * mi).astype(p.dtype),
            params, m)
        return params, (m,)

    return Optimizer(init=init, update=update)


def adam_optimizer(rc: RunConfig, lr: float = 1e-3, b1: float = 0.9,
                   b2: float = 0.999, eps: float = 1e-8,
                   weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return (z, jax.tree.map(jnp.copy, z), jnp.zeros((), jnp.int32))

    def update(opt_state, params, g):
        m, v, t = opt_state
        t = t + 1
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * jnp.square(b), v, g)
        tf = t.astype(jnp.float32)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf

        def upd(p, mi, vi):
            step = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            out = p.astype(jnp.float32) - step
            if weight_decay:
                out = out - lr * weight_decay * p.astype(jnp.float32)
            return out.astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, (m, v, t)

    return Optimizer(init=init, update=update)


def make_optimizer(rc: RunConfig) -> Optimizer:
    name = rc.optimizer
    if name == "dual_averaging":
        return dual_averaging_optimizer(rc)
    if name == "sgd":
        return sgd_optimizer(rc)
    if name == "adam":
        return adam_optimizer(rc)
    raise ValueError(f"unknown optimizer {name!r}")


# ---------------------------------------------------------------------------
# Arena-form optimizers: states live as (rows, 128) buffers, updates are
# single fused passes over flat memory instead of per-leaf tree.maps.
# The count-normalization (g = grad_sum / count) is folded into the
# update so the popped arena row is consumed directly.
# ---------------------------------------------------------------------------
class ArenaOptimizer(NamedTuple):
    init: Callable[[], Any]
    update: Callable[[Any, Any, jax.Array, jax.Array], Tuple[Any, Any]]
    # update(opt_state, params, grad_sum_flat, count, tau_obs=None,
    #        b_sched=None) -> (params, state)
    # tau_obs: observed staleness of the applied gradients (the
    # variable-delay path passes it; dual averaging switches to the
    # delay-adaptive alpha, sgd/adam ignore it)
    # b_sched: the batch schedule's target b(t) (the adaptive-batch
    # path passes it; dual averaging swaps it for the static b_bar
    # inside alpha, sgd/adam ignore it)


def _norm_flat(g_sum, count):
    return g_sum / jnp.maximum(count, 1e-12)


def arena_dual_averaging_optimizer(rc: RunConfig, layout) -> ArenaOptimizer:
    cfg = rc.ambdg

    def update(opt_state: da.ArenaDualAveragingState, params, g_sum, count,
               tau_obs=None, b_sched=None):
        # params leaves come back f32, matching the pytree prox_step
        return da.update_arena(layout, opt_state, g_sum, count, cfg,
                               tau_obs=tau_obs, b_sched=b_sched)

    return ArenaOptimizer(init=lambda: da.init_arena(layout), update=update)


def arena_sgd_optimizer(rc: RunConfig, layout, lr: float = 1e-2,
                        momentum: float = 0.9) -> ArenaOptimizer:
    from repro.core import arena as arena_mod

    def update(opt_state, params, g_sum, count, tau_obs=None, b_sched=None):
        (m,) = opt_state
        m = momentum * m + _norm_flat(g_sum, count)
        # lr rides the unflatten gather (same trick as the dual-
        # averaging prox): no lr*m full-width temp is materialized, and
        # lr*(m-slice) is the same multiply as slicing lr*m — bit-exact
        # vs the pytree path either way
        step = arena_mod.unflatten_tree(layout, m, cast=False, scale=lr)
        params = jax.tree.map(
            lambda p, s: (p.astype(jnp.float32) - s).astype(p.dtype),
            params, step)
        return params, (m,)

    return ArenaOptimizer(
        init=lambda: (jnp.zeros((layout.rows, 128), jnp.float32),),
        update=update)


def arena_adam_optimizer(rc: RunConfig, layout, lr: float = 1e-3,
                         b1: float = 0.9, b2: float = 0.999,
                         eps: float = 1e-8, weight_decay: float = 0.0
                         ) -> ArenaOptimizer:
    from repro.core import arena as arena_mod

    def init():
        z = jnp.zeros((layout.rows, 128), jnp.float32)
        return (z, jnp.copy(z), jnp.zeros((), jnp.int32))

    def update(opt_state, params, g_sum, count, tau_obs=None, b_sched=None):
        m, v, t = opt_state
        g = _norm_flat(g_sum, count)
        t = t + 1
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        tf = t.astype(jnp.float32)
        step = lr * (m / (1 - b1 ** tf)) / (
            jnp.sqrt(v / (1 - b2 ** tf)) + eps)
        step_tree = arena_mod.unflatten_tree(layout, step, cast=False)

        def upd(p, s):
            out = p.astype(jnp.float32) - s
            if weight_decay:
                out = out - lr * weight_decay * p.astype(jnp.float32)
            return out.astype(p.dtype)

        return jax.tree.map(upd, params, step_tree), (m, v, t)

    return ArenaOptimizer(init=init, update=update)


def make_arena_optimizer(rc: RunConfig, layout) -> ArenaOptimizer:
    name = rc.optimizer
    if name == "dual_averaging":
        return arena_dual_averaging_optimizer(rc, layout)
    if name == "sgd":
        return arena_sgd_optimizer(rc, layout)
    if name == "adam":
        return arena_adam_optimizer(rc, layout)
    raise ValueError(f"unknown optimizer {name!r}")
