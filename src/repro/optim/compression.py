"""Gradient compression operators (paper Sec. II cites QSGD-style
quantization and sparsification as the standard communication-load
reducers; the delayed pod exchange uses the int8 path in
``core.delayed`` — these are the reusable operators + error feedback).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_int8_rows(g: jax.Array, scale_dtype=jnp.float32
                       ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 with one scale per row (last axis quantized as a
    group) — the delay-ring kernel's scheme, reused by the compressed
    gossip path. g: (..., lanes) f32 -> (q int8 same shape, scales
    (...) scale_dtype). Formula-identical to ``quantize_int8`` per
    row, so all int8 wire payloads in the repo share one arithmetic
    definition.

    ``scale_dtype=jnp.bfloat16`` (the gossip path) rounds the scale to
    an 8-bit mantissa BEFORE quantizing, so every dequantization
    product ``q * scale`` (7-bit integer x 8-bit mantissa <= 15 < 24
    mantissa bits) is EXACTLY representable in f32 — FMA contraction
    of the product into a following add cannot change a single bit,
    which is what makes the compressed gossip fold bit-identical
    across program variants without relying on optimization barriers
    surviving the backend. It also halves the scale wire payload."""
    amax = jnp.max(jnp.abs(g), axis=-1)
    scale = (jnp.maximum(amax, 1e-12) / 127.0).astype(scale_dtype)
    q = jnp.clip(jnp.round(g / scale.astype(jnp.float32)[..., None]),
                 -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of ``quantize_int8_rows``; elementwise, so it commutes
    bitwise with any permutation of the rows (the compressed gossip
    bit-exactness relies on dequantizing before or after the
    ``ppermute`` being the same f32 values)."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def topk_sparsify(g: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array]:
    """Keep the top ``frac`` fraction of entries by magnitude (returns
    (values, flat_indices)); the rest are dropped (to be healed by
    error feedback)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    flat = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), values.dtype)
    flat = flat.at[idx].set(values)
    return flat.reshape(shape)


class FeedbackState(NamedTuple):
    residual: Any     # pytree like grads


def init_feedback(grads) -> FeedbackState:
    return FeedbackState(jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads))


def compress_with_feedback(state: FeedbackState, grads, frac: float
                           ) -> Tuple[Any, FeedbackState]:
    """Top-k sparsification with error feedback: the dropped mass is
    carried into the next round, so the compressed stream is unbiased
    in the long run."""
    def one(g, r):
        fed = g.astype(jnp.float32) + r
        vals, idx = topk_sparsify(fed, frac)
        dense = topk_densify(vals, idx, fed.shape)
        return dense, fed - dense

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_r = treedef.flatten_up_to(state.residual)
    outs = [one(g, r) for g, r in zip(leaves_g, leaves_r)]
    compressed = jax.tree.unflatten(treedef, [o[0] for o in outs])
    residual = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return compressed, FeedbackState(residual)
