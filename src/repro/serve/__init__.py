"""Serving subsystem: continuous batching + bounded-staleness weight
publication (see docs/serve.md)."""
from repro.serve.engine import Engine, ServeStats, continuous_decode_step
from repro.serve.publisher import WeightPublisher, publish_ring_slots
from repro.serve.request_queue import (ARRIVAL_PROCESSES, Request,
                                       RequestQueue, make_arrival_process)

__all__ = [
    "ARRIVAL_PROCESSES",
    "Engine",
    "Request",
    "RequestQueue",
    "ServeStats",
    "WeightPublisher",
    "continuous_decode_step",
    "make_arrival_process",
    "publish_ring_slots",
]
