"""Continuous-batching serving engine over the unified model API.

Each cache slot holds one request at its own per-slot position: finished
sequences are evicted and new requests admitted from the queue every
decode step, so the batch never drains to the slowest member (the
lockstep ``generate()`` of the seed). One jit'd ``decode_step`` advances
every slot — idle slots ride along under an active mask (token 0 at
position 0; their cache writes land in a column the next occupant
overwrites before reading).

Per-slot positions start at 0 on admit, which kills two seed bugs at
once: no padding exists anywhere (the old path LEFT-padded rows while
its docstring said right — and pushed the pad zeros through the cache
as real tokens), and the validity mask of a fresh sequence only ever
covers columns that sequence has itself written, so a new occupant can
never attend to a previous occupant's stale cache entries. The
ragged-prompt equivalence test (batched == solo, tests/test_serve.py)
pins this.

Weights arrive through the bounded-staleness publication channel
(``serve.publisher``): ``refresh_weights(now)`` pops the freshest due
``w = -alpha z`` snapshot and threads the observed staleness into
``ServeStats``. CPU-testable at smoke scale; the dry-run lowers the
same ``continuous_decode_step`` at production shapes/meshes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve.request_queue import Request


@dataclasses.dataclass
class ServeStats:
    """Serve counters. Token counters count ACTIVE slots only — an idle
    slot riding under the mask processes no request token."""
    prefill_tokens: int = 0
    decode_tokens: int = 0
    steps: int = 0
    admitted: int = 0
    completed: int = 0
    # weight-publication channel (serve.publisher)
    publish_pops: int = 0
    publish_misses: int = 0
    staleness_last: Optional[int] = None
    staleness_sum: int = 0
    staleness_max: int = 0

    def staleness_mean(self) -> float:
        return self.staleness_sum / max(self.publish_pops, 1)


def continuous_decode_step(decode_fn, params, cache, tokens, pos, active):
    """One continuous-batching decode step (the jit/lowering unit —
    the dry-run lowers exactly this function at production shapes).

    tokens: (B, 1) int32 — the token each slot feeds this step;
    pos: (B,) int32 per-slot local positions; active: (B,) bool.
    Returns (next-token ids (B,) int32, new cache). Inactive slots
    emit 0.
    """
    logits, cache = decode_fn(params, cache, tokens, pos)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    return jnp.where(active, nxt, jnp.int32(0)), cache


def _make_slot_reset(caxes):
    """Generic admit-time cache reset: restore the init template on the
    batch rows of freshly admitted slots. Works for any decode-state
    pytree (KV, SSM recurrent state, hybrid) by locating each leaf's
    batch axis in the logical-axes tree."""
    axes, _ = jax.tree.flatten(caxes,
                               is_leaf=lambda x: isinstance(x, tuple))

    def reset(cache, cache0, admit):
        leaves, treedef = jax.tree.flatten(cache)
        leaves0, _ = jax.tree.flatten(cache0)
        out = []
        for c, c0, ax in zip(leaves, leaves0, axes):
            if "batch" in ax:
                shape = [1] * c.ndim
                shape[ax.index("batch")] = admit.shape[0]
                c = jnp.where(admit.reshape(shape), c0, c)
            out.append(c)
        return jax.tree.unflatten(treedef, out)

    return jax.jit(reset, donate_argnums=(0,))


class _StaticQueue:
    """Minimal queue protocol (pop/__len__) over a fixed request list —
    the ``generate()`` compatibility path."""

    def __init__(self, reqs):
        self._pending = deque(reqs)

    def __len__(self):
        return len(self._pending)

    def pop(self) -> Optional[Request]:
        return self._pending.popleft() if self._pending else None


class Engine:
    """Continuous batched decoding with a shared fixed-slot cache.

    ``step(queue)`` admits from the queue into free slots, advances
    every slot one token under the active mask, then evicts finished
    sequences — returning an event record (admits/evicts/active) that
    the golden serve trace pins. ``serve(queue, n)`` drives the seeded
    arrival process for n steps.
    """

    def __init__(self, model: Model, batch_slots: int, max_len: int,
                 seed: int = 0):
        self.model = model
        self.cfg = model.cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.params, _ = model.init(jax.random.PRNGKey(seed))
        self.cache, self.caxes = model.init_decode_state(batch_slots, max_len)
        # independent init template for admit-time slot resets (the
        # live cache is donated through the jit'd step)
        self._cache0, _ = model.init_decode_state(batch_slots, max_len)
        self._step = jax.jit(
            lambda p, c, t, pos, act: continuous_decode_step(
                model.decode_step, p, c, t, pos, act),
            donate_argnums=(1,))
        self._reset = _make_slot_reset(self.caxes)
        # per-slot host state
        self._req: List[Optional[Request]] = [None] * batch_slots
        self._n_fed = np.zeros((batch_slots,), np.int64)   # tokens fed
        self._emitted = np.zeros((batch_slots,), np.int64)
        self._next_tok = np.zeros((batch_slots,), np.int64)
        self._out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.completions: List[Tuple[int, List[int]]] = []
        self.publisher = None
        self.stats = ServeStats()

    # -- weight publication ------------------------------------------------
    def attach_publisher(self, publisher):
        self.publisher = publisher

    def refresh_weights(self, now: int) -> Optional[int]:
        """Pop the freshest due snapshot at master step ``now``; swap it
        in and return the observed staleness, or None on a miss (the
        engine keeps serving its previous weights — every SERVED
        snapshot therefore satisfies the bound)."""
        if self.publisher is None:
            return None
        params, stale = self.publisher.pop(now)
        if params is None:
            self.stats.publish_misses += 1
            return None
        self.params = params
        self.stats.publish_pops += 1
        self.stats.staleness_last = stale
        self.stats.staleness_sum += stale
        self.stats.staleness_max = max(self.stats.staleness_max, stale)
        return stale

    # -- scheduling --------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return sum(r is not None for r in self._req)

    def step(self, queue=None) -> Dict:
        """One engine step: admit -> decode -> evict. Returns the event
        record pinned by the golden serve trace."""
        t = self.stats.steps
        admits, evicts = [], []
        admit_mask = np.zeros((self.slots,), bool)
        if queue is not None:
            for i in range(self.slots):
                if self._req[i] is not None:
                    continue
                req = queue.pop()
                if req is None:
                    break
                self._req[i] = req
                self._n_fed[i] = 0
                self._emitted[i] = 0
                self._out[i] = list(req.prompt)
                self._next_tok[i] = req.prompt[0]
                admit_mask[i] = True
                admits.append(req.rid)
                self.stats.admitted += 1
        if admit_mask.any():
            self.cache = self._reset(self.cache, self._cache0,
                                     jnp.asarray(admit_mask))
        active = np.array([r is not None for r in self._req])
        self.stats.steps += 1
        if not active.any():
            return {"step": t, "admits": admits, "evicts": [],
                    "active": 0,
                    "queued": len(queue) if queue is not None else 0}
        toks = np.where(active, self._next_tok, 0).astype(np.int32)
        pos = np.where(active, self._n_fed, 0).astype(np.int32)
        nxt, self.cache = self._step(
            self.params, self.cache, jnp.asarray(toks[:, None]),
            jnp.asarray(pos), jnp.asarray(active))
        nxt = np.asarray(nxt)
        for i in range(self.slots):
            req = self._req[i]
            if req is None:
                continue
            fed = int(self._n_fed[i])
            self._n_fed[i] = fed + 1
            if fed < len(req.prompt) - 1:
                # still consuming the prompt: the model's prediction is
                # discarded, the next prompt token is fed instead
                self.stats.prefill_tokens += 1
                self._next_tok[i] = req.prompt[fed + 1]
            else:
                self.stats.decode_tokens += 1
                tok = int(nxt[i])
                self._out[i].append(tok)
                self._emitted[i] += 1
                self._next_tok[i] = tok
            if (self._emitted[i] >= req.max_new
                    or self._n_fed[i] >= self.max_len):
                self.completions.append((req.rid, self._out[i]))
                evicts.append(req.rid)
                self.stats.completed += 1
                self._req[i] = None
        return {"step": t, "admits": admits, "evicts": evicts,
                "active": int(active.sum()),
                "queued": len(queue) if queue is not None else 0}

    def serve(self, queue, n_steps: int) -> List[Dict]:
        """Drive the seeded arrival process for ``n_steps`` engine
        steps; returns the event trace."""
        trace = []
        for _ in range(n_steps):
            arrived = queue.step()
            ev = self.step(queue)
            ev["arrived"] = arrived
            trace.append(ev)
        return trace

    # -- compatibility -----------------------------------------------------
    def generate(self, prompts: List[List[int]], max_new: int
                 ) -> List[List[int]]:
        """Greedy generation (compatibility wrapper over the continuous
        engine). Each prompt runs at its own per-slot position from 0 —
        no padding of any kind (the seed's lockstep path left-padded
        rows and pushed the pad zeros through the cache as real
        tokens).
        """
        assert len(prompts) <= self.slots
        q = _StaticQueue(
            Request(rid=i, prompt=[int(t) for t in p], max_new=max_new)
            for i, p in enumerate(prompts))
        done_before = len(self.completions)
        while len(q) or self.in_flight:
            self.step(q)
        outs = dict(self.completions[done_before:])
        return [outs[i] for i in range(len(prompts))]
