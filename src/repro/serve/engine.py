"""Batched serving engine: prefill-by-decode + batched autoregressive
decode over the unified model API. CPU-testable at smoke scale; the
dry-run lowers the same ``decode_step`` at production shapes/meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import Model


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    steps: int = 0


class Engine:
    """Continuous batched decoding with a shared fixed-slot cache.

    Requests are (prompt tokens, max_new). Slots hold one sequence each;
    finished slots are refilled from the queue (continuous batching).
    """

    def __init__(self, model: Model, batch_slots: int, max_len: int,
                 seed: int = 0):
        self.model = model
        self.cfg = model.cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.params, _ = model.init(jax.random.PRNGKey(seed))
        self.cache, _ = model.init_decode_state(batch_slots, max_len)
        self._step = jax.jit(model.decode_step, donate_argnums=(1,))
        self.stats = ServeStats()

    def _advance(self, tokens_col: np.ndarray, pos: int) -> np.ndarray:
        """One synchronized decode step for all slots at position pos."""
        logits, self.cache = self._step(
            self.params, self.cache,
            jnp.asarray(tokens_col[:, None], jnp.int32), jnp.int32(pos))
        self.stats.steps += 1
        return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))

    def generate(self, prompts: List[List[int]], max_new: int
                 ) -> List[List[int]]:
        """Greedy generation. All prompts are right-padded into slot
        rows; positions advance in lockstep (cache layout is position-
        synchronized; production serving would use per-slot positions).
        """
        assert len(prompts) <= self.slots
        plen = max(len(p) for p in prompts)
        rows = np.zeros((self.slots, plen), np.int32)
        for i, p in enumerate(prompts):
            rows[i, plen - len(p):] = p  # left-pad to align last token
        # prefill token-by-token through the decode path (keeps one
        # compiled program; a production engine would run a fused
        # prefill kernel — the dry-run lowers that path separately)
        for t in range(plen - 1):
            self._advance(rows[:, t], t)
            self.stats.prefill_tokens += self.slots
        out = [list(p) for p in prompts]
        cur = rows[:, plen - 1]
        for step in range(max_new):
            nxt = self._advance(cur, plen - 1 + step)
            self.stats.decode_tokens += self.slots
            for i in range(len(prompts)):
                out[i].append(int(nxt[i]))
            cur = nxt
        return out
