"""Bounded-staleness weight publication: the master -> server channel.

The paper's delayed-consumer argument (Agarwal-Duchi) says consumers of
stale ``w = -alpha z`` make optimal progress as long as staleness is
bounded — and an inference server reading asynchronously published
master snapshots is exactly such a consumer. This module is that
channel, built from the pieces the training side already ships:

  * snapshots live in the arena's lane-aligned ``(rows, 128)`` layout
    (``core.arena.make_layout`` / ``flatten_tree`` — the same flat form
    the master update itself runs on), so publish is one scatter and
    pop one gather, never a per-leaf pytree walk;
  * the wire format is the gossip path's int8 + bf16-scales scheme
    (``optim.compression.quantize_int8_rows(scale_dtype=bfloat16)``) —
    literally the same function, so published weights dequantize
    BIT-IDENTICALLY to the compressed gossip payload on the same rows
    (pinned by tests/test_serve.py), and every ``q * scale`` product is
    exactly representable in f32;
  * the publish ring is sized so no *servable* snapshot is ever
    overwritten, by construction (the arena ring's dead-slot argument):
    with ``n_slots = staleness_bound // publish_period + 1`` slots and
    one publish per period, the snapshot a publish overwrites is
    ``n_slots * publish_period > staleness_bound`` steps old — already
    expired, never due.

Staleness contract: ``pop(now)`` returns the freshest snapshot whose
age ``now - published_step`` lies in ``[0, staleness_bound]``, plus
that observed age (threaded into serve stats). If nothing is due —
the master has not published yet, or every snapshot expired — the
server keeps its previous weights and the pop reports a miss; a served
snapshot therefore ALWAYS satisfies the bound.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.core import arena as arena_mod
from repro.optim.compression import (dequantize_int8_rows,
                                     quantize_int8_rows)


def publish_ring_slots(cfg: ServeConfig) -> int:
    """Ring depth for the no-unread-overwrite property (see module
    docstring); validates the serve knobs."""
    if cfg.publish_period < 1:
        raise ValueError("publisher needs publish_period >= 1 "
                         f"(0 disables the channel), got "
                         f"{cfg.publish_period}")
    if cfg.staleness_bound < 0:
        raise ValueError(f"staleness_bound must be >= 0, got "
                         f"{cfg.staleness_bound}")
    return cfg.staleness_bound // cfg.publish_period + 1


class WeightPublisher:
    """Master-side publish + server-side pop over one bounded-staleness
    ring of int8-compressed ``w`` snapshots.

    ``layout`` is the arena layout of the published parameter tree
    (``arena.make_layout(params)`` — ShapeDtypeStructs work, so the
    train loop builds it from ``jax.eval_shape``)."""

    def __init__(self, layout: arena_mod.ArenaLayout, cfg: ServeConfig):
        self.layout = layout
        self.cfg = cfg
        self.n_slots = publish_ring_slots(cfg)
        rows = layout.rows
        self.ring = jnp.zeros((self.n_slots, rows, arena_mod.LANES),
                              jnp.int8)
        self.scales = jnp.ones((self.n_slots, rows), jnp.bfloat16)
        # master step each slot was published at; -1 = never written
        self.pub_step = np.full((self.n_slots,), -1, np.int64)
        self.seq = 0                       # total publishes
        self.pops = 0                      # successful (due) pops
        self.misses = 0                    # pops with nothing due

        def _quantize(tree):
            w = arena_mod.flatten_tree(layout, tree)
            return quantize_int8_rows(w, scale_dtype=jnp.bfloat16)

        def _dequantize(q, s):
            w = dequantize_int8_rows(q, s)
            return arena_mod.unflatten_tree(layout, w, cast=True)

        self._quantize = jax.jit(_quantize)
        self._dequantize = jax.jit(_dequantize)

    # -- master side -------------------------------------------------------
    def publish(self, params, step: int):
        """Push one ``w`` snapshot taken at master step ``step``. The
        slot index rotates with the publish sequence number, so the
        overwritten snapshot is always the expired one (module
        docstring)."""
        q, s = self._quantize(params)
        k = self.seq % self.n_slots
        self.ring = self.ring.at[k].set(q)
        self.scales = self.scales.at[k].set(s)
        self.pub_step[k] = int(step)
        self.seq += 1
        return k

    # -- server side -------------------------------------------------------
    def due_slot(self, now: int) -> Optional[int]:
        """Freshest slot whose age at master step ``now`` is within the
        bound, or None."""
        ages = now - self.pub_step
        ok = (self.pub_step >= 0) & (ages >= 0) & \
            (ages <= self.cfg.staleness_bound)
        if not ok.any():
            return None
        return int(np.flatnonzero(ok)[np.argmax(self.pub_step[ok])])

    def pop(self, now: int) -> Tuple[Optional[Dict], Optional[int]]:
        """Pop the freshest due snapshot: (params tree, observed
        staleness in master steps), or (None, None) when nothing is due
        — the server keeps serving its previous weights, so every
        SERVED snapshot satisfies the bound."""
        k = self.due_slot(now)
        if k is None:
            self.misses += 1
            return None, None
        self.pops += 1
        params = self._dequantize(self.ring[k], self.scales[k])
        return params, int(now - self.pub_step[k])

    # -- restart exactness -------------------------------------------------
    def state_dict(self) -> Dict:
        return {
            "ring": np.asarray(self.ring),
            # bf16 has no numpy dtype: carry the raw bits (the wire
            # format does the same — scales travel as u16)
            "scales_bits": np.asarray(
                jax.lax.bitcast_convert_type(self.scales, jnp.uint16)),
            "pub_step": self.pub_step.copy(),
            "seq": self.seq, "pops": self.pops, "misses": self.misses,
        }

    def load_state_dict(self, s: Dict):
        self.ring = jnp.asarray(s["ring"], jnp.int8)
        self.scales = jax.lax.bitcast_convert_type(
            jnp.asarray(s["scales_bits"], jnp.uint16), jnp.bfloat16)
        self.pub_step = np.asarray(s["pub_step"], np.int64).copy()
        self.seq = int(s["seq"])
        self.pops = int(s.get("pops", 0))
        self.misses = int(s.get("misses", 0))
