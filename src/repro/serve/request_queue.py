"""Seeded request arrival processes + the serve request queue.

The serving twin of ``core.delay_process``: where the training side
draws one staleness ``tau_t`` per master step, the serving side draws
one arrival count per *decode step* — how many new requests hit the
engine while it advanced every active slot by one token. The same
contract applies:

  * every process is seeded (``numpy.random.default_rng``) and emits
    non-negative integer counts;
  * full state checkpoints through ``state_dict``/``load_state_dict``
    (restart exactness: the remaining arrival sequence AND the pending
    queue survive a server restart);
  * the property suite replays a process against the queue-conservation
    oracle (``tests/test_serve.py``), and the golden serve trace pins
    one seeded sequence exactly.

Two processes (``ServeConfig.arrival``):

  poisson   n_t ~ Poisson(arrival_rate): memoryless open-loop traffic,
            the standard load model for a request benchmark.
  bursty    2-state Gilbert-Elliott chain (the ``bursty`` delay
            process's shape applied to traffic instead of staleness):
            Poisson(arrival_rate) in the normal state,
            Poisson(burst_rate) inside a burst, with geometric dwell
            times (p_burst / p_exit).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Type

import numpy as np

from repro.configs.base import ServeConfig


def resolve_arrival(cfg: ServeConfig) -> str:
    """Validate the arrival knobs; returns the process name. Every
    consumer goes through here (mirrors ``delay_process.resolve_bounds``
    — raise early with the full message, never mid-run)."""
    if cfg.arrival not in ARRIVAL_PROCESSES:
        raise ValueError(f"unknown arrival process {cfg.arrival!r}; "
                         f"registered: {sorted(ARRIVAL_PROCESSES)}")
    if cfg.arrival_rate < 0.0 or cfg.burst_rate < 0.0:
        raise ValueError("arrival rates must be >= 0, got "
                         f"arrival_rate={cfg.arrival_rate}, "
                         f"burst_rate={cfg.burst_rate}")
    if not 0.0 <= cfg.p_burst <= 1.0 or not 0.0 <= cfg.p_exit <= 1.0:
        raise ValueError("bursty transition probabilities must be in "
                         f"[0, 1], got p_burst={cfg.p_burst}, "
                         f"p_exit={cfg.p_exit}")
    if not 1 <= cfg.prompt_len_min <= cfg.prompt_len_max:
        raise ValueError("need 1 <= prompt_len_min <= prompt_len_max, "
                         f"got [{cfg.prompt_len_min}, "
                         f"{cfg.prompt_len_max}]")
    return cfg.arrival


class ArrivalProcess:
    """One seeded per-step arrival-count sequence. Subclasses implement
    ``_draw()`` -> int; the base class owns seeding and checkpointable
    state (the contract of ``core.delay_process.DelayProcess``)."""

    name: str = "?"

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        resolve_arrival(cfg)
        self._rng = np.random.default_rng(cfg.seed)

    def _draw(self) -> int:
        raise NotImplementedError

    def next(self) -> int:
        """Draw the next arrival count (advances the seeded state)."""
        return max(int(self._draw()), 0)

    def sequence(self, n: int) -> np.ndarray:
        return np.asarray([self.next() for _ in range(n)], np.int64)

    # -- restart exactness -------------------------------------------------
    def state_dict(self) -> Dict:
        return {"rng": self._rng.bit_generator.state}

    def load_state_dict(self, s: Dict):
        self._rng.bit_generator.state = s["rng"]

    def __repr__(self):
        return (f"{type(self).__name__}(rate={self.cfg.arrival_rate}, "
                f"seed={self.cfg.seed})")


class PoissonArrival(ArrivalProcess):
    """Memoryless open-loop traffic: n_t ~ Poisson(arrival_rate)."""

    name = "poisson"

    def _draw(self) -> int:
        return int(self._rng.poisson(self.cfg.arrival_rate))


class BurstyArrival(ArrivalProcess):
    """Gilbert-Elliott traffic: a 2-state Markov chain with geometric
    dwell times. Normal state draws Poisson(arrival_rate), burst state
    Poisson(burst_rate). Transitions are drawn BEFORE the emission, so
    a burst entered at step t already floods step t (the convention of
    ``core.delay_process.BurstyDelay``)."""

    name = "bursty"

    def __init__(self, cfg: ServeConfig):
        super().__init__(cfg)
        self._in_burst = False

    def _draw(self) -> int:
        u = float(self._rng.random())
        if self._in_burst:
            self._in_burst = u >= self.cfg.p_exit
        else:
            self._in_burst = u < self.cfg.p_burst
        rate = (self.cfg.burst_rate if self._in_burst
                else self.cfg.arrival_rate)
        return int(self._rng.poisson(rate))

    def state_dict(self) -> Dict:
        s = super().state_dict()
        s["in_burst"] = bool(self._in_burst)
        return s

    def load_state_dict(self, s: Dict):
        super().load_state_dict(s)
        self._in_burst = bool(s.get("in_burst", False))


ARRIVAL_PROCESSES: Dict[str, Type[ArrivalProcess]] = {
    c.name: c for c in (PoissonArrival, BurstyArrival)}


def make_arrival_process(cfg: ServeConfig) -> ArrivalProcess:
    """Construct the process named by ``cfg.arrival`` (validates the
    config — every consumer goes through here)."""
    resolve_arrival(cfg)
    return ARRIVAL_PROCESSES[cfg.arrival](cfg)


@dataclasses.dataclass
class Request:
    """One inference request: prompt token ids + generation budget."""
    rid: int
    prompt: List[int]
    max_new: int


class RequestQueue:
    """Seeded open-loop request queue feeding the continuous-batching
    engine. ``step()`` draws one arrival count from the configured
    process and synthesizes that many requests (seeded prompt lengths
    in [prompt_len_min, prompt_len_max], token ids in [1, vocab));
    ``submit()`` enqueues an externally supplied prompt (the
    ``generate()`` compatibility path). The engine admits via ``pop()``
    whenever a slot frees.

    Conservation contract (the property suite's first invariant):
    every request that enters the queue is, at any instant, exactly one
    of pending / in flight / completed — ``submitted == len(queue) +
    in_flight + completed`` with the engine's counters."""

    def __init__(self, cfg: ServeConfig, vocab_size: int):
        self.cfg = cfg
        self.vocab_size = int(vocab_size)
        self.arrival = make_arrival_process(cfg)
        # prompt synthesis draws from its own stream so the arrival
        # sequence is invariant to prompt-length knobs
        self._prompt_rng = np.random.default_rng(cfg.seed + 1)
        self._pending: deque = deque()
        self.next_rid = 0
        self.submitted = 0

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, prompt: List[int], max_new: Optional[int] = None
               ) -> Request:
        req = Request(self.next_rid, [int(t) for t in prompt],
                      int(max_new if max_new is not None
                          else self.cfg.max_new))
        self.next_rid += 1
        self.submitted += 1
        self._pending.append(req)
        return req

    def step(self) -> int:
        """Advance the arrival process one decode step: draw n_t, then
        synthesize and enqueue n_t seeded requests. Returns n_t."""
        n = self.arrival.next()
        for _ in range(n):
            plen = int(self._prompt_rng.integers(
                self.cfg.prompt_len_min, self.cfg.prompt_len_max + 1))
            prompt = self._prompt_rng.integers(
                1, self.vocab_size, size=plen).tolist()
            self.submit(prompt)
        return n

    def pop(self) -> Optional[Request]:
        return self._pending.popleft() if self._pending else None

    # -- restart exactness -------------------------------------------------
    def state_dict(self) -> Dict:
        return {
            "arrival": self.arrival.state_dict(),
            "prompt_rng": self._prompt_rng.bit_generator.state,
            "pending": [(r.rid, list(r.prompt), r.max_new)
                        for r in self._pending],
            "next_rid": self.next_rid,
            "submitted": self.submitted,
        }

    def load_state_dict(self, s: Dict):
        self.arrival.load_state_dict(s["arrival"])
        self._prompt_rng.bit_generator.state = s["prompt_rng"]
        self._pending = deque(Request(rid, list(p), mn)
                              for rid, p, mn in s["pending"])
        self.next_rid = int(s["next_rid"])
        self.submitted = int(s["submitted"])
