from repro.sim.cluster import (SimProblem, Trace,  # noqa: F401
                               simulate_anytime, simulate_kbatch)
