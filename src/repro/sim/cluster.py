"""Event-driven cluster simulator (paper Sec. VI).

Runs AMB-DG / AMB / K-batch-async with *real JAX compute* inside a
simulated wall clock: worker speeds follow the paper's shifted
exponential (eq. (29)), communication takes a deterministic T_c split
half-and-half between the two legs, and the master updates via dual
averaging. Reproduces Fig. 2 (AMB vs AMB-DG), Fig. 3/4 (K-batch async +
staleness histogram), Fig. 5 (NN training) and Fig. 6 (b-hat/b-bar
scaling).

Design notes:
  * AMB-DG / AMB epochs are time-aligned across workers (the paper's
    synchronized network), so their simulation advances epoch-by-epoch;
    K-batch async is genuinely event-driven (a heap of message arrivals).
  * All gradient computations go through one fixed-shape jitted
    function: per-worker batches are padded to b_max and masked with the
    anytime weights, so JAX traces once.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AmbdgConfig, ModelConfig
from repro.core import dual_averaging as da
from repro.core.kbatch import KBatchMaster, Message
from repro.core.staleness import Timeline
from repro.data.synthetic import make_stream
from repro.data.timing import ShiftedExponential


@dataclass
class SimProblem:
    """Couples a model, a data stream per worker, and an error metric."""
    cfg: ModelConfig
    n_workers: int
    seed: int = 0
    seq_len: int = 0           # LM families only
    b_max: int = 4096          # per-worker per-epoch padding bound

    def __post_init__(self):
        from repro.models import build_model
        self.model = build_model(self.cfg)
        self.params0, _ = self.model.init(jax.random.PRNGKey(self.seed))
        self.streams = [make_stream(self.cfg, seed=self.seed,
                                    sample_seed=self.seed + 100 + i)
                        for i in range(self.n_workers)]
        self._grad = jax.jit(jax.grad(
            lambda p, b: self.model.loss(p, b)[0]))
        # capacity-clamp bookkeeping (see worker_grad); the engines
        # snapshot these around each update into ``trace.clamps``
        self.clamp_events = 0
        self.clamped_samples = 0

    def worker_grad(self, worker: int, params, b_i: int,
                    strict: bool = False):
        """(sum-of-gradients, count) for worker ``worker`` computing
        b_i samples — the paper's message m_i(t).

        A request above the padding bound ``b_max`` is clamped and
        COUNTED (``clamp_events``/``clamped_samples``; the engines
        surface the per-update deltas in ``trace.clamps``) — or raised
        under ``strict``, which the engines set whenever an adaptive
        batch schedule drives the sizes: a silent cap there would
        leave alpha assuming a b(t) that never actually ran."""
        b_i = int(b_i)
        if b_i > self.b_max:
            if strict:
                raise ValueError(
                    f"scheduled minibatch b_i={b_i} overflows the "
                    f"padding bound b_max={self.b_max}; grow "
                    f"SimProblem.b_max to cover the schedule's cap "
                    f"(b_cap split across alive workers)")
            self.clamp_events += 1
            self.clamped_samples += b_i - self.b_max
            b_i = self.b_max
        if self.seq_len:
            batch = self.streams[worker].next_batch(self.b_max, self.seq_len)
        else:
            batch = self.streams[worker].next_batch(self.b_max)
        w = np.zeros((self.b_max,), np.float32)
        w[:b_i] = 1.0
        batch["weights"] = w
        return self._grad(params, batch), float(b_i)

    def error(self, params) -> float:
        """Linreg: the paper's Err(t) (eq. 28) — for A with iid N(0,1)
        rows, A^T A ~ N I so Err reduces to ||w-w*||^2/||w*||^2."""
        if self.cfg.family == "linreg":
            w_star = self.streams[0].w_star
            w = np.asarray(params["w"])
            return float(np.sum((w - w_star) ** 2) / np.sum(w_star ** 2))
        return float("nan")


@dataclass
class Trace:
    scheme: str
    times: List[float] = field(default_factory=list)
    epochs: List[int] = field(default_factory=list)
    errors: List[float] = field(default_factory=list)
    losses: List[float] = field(default_factory=list)
    minibatches: List[float] = field(default_factory=list)
    staleness: List[int] = field(default_factory=list)
    # the emitted tau_t sequence when a stochastic delay process drives
    # the run (per epoch for anytime schemes, per message for k-batch)
    delays: List[int] = field(default_factory=list)
    # alive-worker count per drawn epoch when an elastic worker
    # process drives the run (core.worker_process) — exact, seeded;
    # what the elastic golden traces pin
    active: List[int] = field(default_factory=list)
    # the emitted b(t) target sequence when an adaptive batch schedule
    # drives the run (core.batch_schedule) — per epoch for anytime
    # schemes, per job for k-batch; what the schedule golden trace pins
    targets: List[int] = field(default_factory=list)
    # per-update count of capacity clamps (worker requests above
    # SimProblem.b_max that were silently capped — see worker_grad)
    clamps: List[int] = field(default_factory=list)
    final_params: object = None

    def summary(self) -> Dict:
        return {"scheme": self.scheme, "updates": len(self.times),
                "final_error": self.errors[-1] if self.errors else None,
                "final_time": self.times[-1] if self.times else None}


def _tree_sum(trees):
    out = trees[0]
    for t in trees[1:]:
        out = jax.tree.map(lambda a, b: a + b, out, t)
    return out


# ---------------------------------------------------------------------------
# AMB-DG (and AMB via the synchronous flag)
# ---------------------------------------------------------------------------
def simulate_anytime(problem: SimProblem, *, t_p: float, t_c: float,
                     total_time: float, timing: ShiftedExponential,
                     opt_cfg: AmbdgConfig, scheme: str = "ambdg",
                     rng_seed: int = 0, delay_process=None,
                     worker_process=None, batch_schedule=None) -> Trace:
    """scheme='ambdg': workers never idle; master applies gradients with
    staleness tau = ceil(T_c/T_p). scheme='amb': synchronous — fresh
    gradients, but each epoch costs T_p + T_c of wall clock.

    ``delay_process`` (ambdg only): a seeded ``core.delay_process``
    instance replacing the constant tau with a per-epoch draw tau_t —
    the downlink model: the master's t-th update applies gradients
    computed w.r.t. w(max(1, t - tau_t)), so jittered broadcasts make
    workers reference OLDER (occasionally out-of-order) versions. The
    master's update clock keeps the strategy's closed form — the delay
    process perturbs WHAT each update applies, not when it lands.
    The emitted sequence is recorded in ``trace.delays`` (exact,
    seeded), which is what the stochastic golden trace pins. With the
    process's ``adaptive_alpha`` knob on (the default — the same knob
    the device path honors), each update's step size takes the
    OBSERVED staleness ``t - ref`` of the gradients it applies instead
    of the static worst case, matching ``metrics["tau_applied"]`` on
    device.

    ``worker_process``: a seeded ``core.worker_process`` instance
    driving a per-epoch elastic active set + speed skew: each epoch's
    draw scales worker i's anytime count to floor(b_i * speed_i) and
    zeroes it when i is down (dead workers compute nothing and their
    data stream does not advance — AMB's aggregation stays exact with
    b_i = 0, paper Sec. IV-C; an ALL-dead epoch applies an exact zero
    gradient and the master coasts). The static process is a no-op by
    construction (all-alive, speed 1.0, no rng consumed), so its trace
    is bit-identical to a run without a process — the elastic
    regression pin. Alive counts are recorded in ``trace.active``.

    ``batch_schedule``: a seeded ``core.batch_schedule`` controller
    replacing the timing-driven anytime minibatch with its per-epoch
    target b(t): the target splits evenly across the alive workers
    (remainder to the lowest ranks; a share above ``problem.b_max``
    raises — grow the padding bound, never silently cap a scheduled
    batch), the step size takes b(t) in place of the static ``b_bar``,
    and after each update the controller observes the current error
    (the closed-loop signal) and the applied staleness. Targets are
    recorded in ``trace.targets``."""
    assert scheme in ("ambdg", "amb")
    from repro.core.strategy import get_strategy
    cls = get_strategy(scheme)
    tm = cls.timeline_model()
    tl = Timeline(t_p=t_p, t_c=t_c)
    tau = tl.tau if scheme == "ambdg" else 0
    if delay_process is not None and scheme != "ambdg":
        raise ValueError("stochastic delay processes apply to the "
                         "'ambdg' scheme (amb is synchronous)")
    # version-retention window: the deepest reference a draw can reach
    tau_keep = (delay_process.tau_max if delay_process is not None
                else tau)
    # the same knob the device path honors (core/ambdg.py): adaptive
    # alpha takes the observed staleness of the gradients each update
    # APPLIES — never drawn-but-unfed like the pre-fix code
    adaptive_alpha = (delay_process is not None
                      and delay_process.cfg.adaptive_alpha)
    rng = np.random.default_rng(rng_seed)
    trace = Trace(scheme=scheme)

    params_versions = {1: problem.params0}  # w(1)
    state = da.init(problem.params0)
    n = problem.n_workers

    # wall-clock algebra comes from the strategy's timeline model (the
    # exact float expressions the golden trace pins)
    n_epochs = tm.n_updates(total_time, t_p, t_c)
    update_time = lambda t: tm.update_time(t, t_p, t_c)

    for t in range(1, n_epochs + 1):
        if scheme == "ambdg" and delay_process is not None:
            tau_t = delay_process.next()
            trace.delays.append(tau_t)
            ref = max(1, t - tau_t)
        else:
            ref = max(1, t - tau) if scheme == "ambdg" else t
        w_ref = params_versions[ref]
        b = timing.minibatch_in(rng, n, t_p)
        alive = list(range(n))
        if worker_process is not None:
            w_active, w_speeds = worker_process.step()
            trace.active.append(int(w_active.sum()))
            b = np.where(w_active,
                         np.floor(b * w_speeds).astype(np.int64), 0)
            alive = [i for i in range(n) if w_active[i]]
        b_t = None
        if batch_schedule is not None:
            # the controller's target replaces the timing-driven
            # anytime draw: split evenly over the alive workers
            # (remainder to the lowest ranks)
            b_t = int(batch_schedule.target())
            trace.targets.append(b_t)
            b = np.zeros(n, np.int64)
            if alive:
                share, rem = divmod(b_t, len(alive))
                for j, i in enumerate(alive):
                    b[i] = share + (1 if j < rem else 0)
        c0 = problem.clamp_events
        msgs = [problem.worker_grad(i, w_ref, int(b[i]),
                                    strict=batch_schedule is not None)
                for i in alive]
        trace.clamps.append(problem.clamp_events - c0)
        if msgs:
            grad_sum = _tree_sum([g for g, _ in msgs])
        else:
            # all-dead epoch: an exact zero gradient (count 0 guards
            # the normalization) — the master coasts, no NaNs
            grad_sum = jax.tree.map(jnp.zeros_like, problem.params0)
        count = sum(c for _, c in msgs)
        g = jax.tree.map(lambda x: x / max(count, 1e-12), grad_sum)
        w_next, state = da.update(
            state, g, opt_cfg,
            tau=float(t - ref) if adaptive_alpha else None,
            b=None if b_t is None else float(b_t))
        params_versions[t + 1] = w_next
        # prune old versions (keep a tau_keep+2 window — the deepest
        # reference the delay process can emit)
        for old in list(params_versions):
            if old < t - tau_keep - 1:
                del params_versions[old]
        trace.times.append(update_time(t))
        trace.epochs.append(t)
        trace.errors.append(problem.error(w_next))
        trace.minibatches.append(count)
        trace.staleness.append(t - ref)
        if batch_schedule is not None:
            # closed-loop feedback: the linreg Err(t) is the loss
            # signal the adadamp controller damps against; the applied
            # staleness feeds the delay-aware scaling
            batch_schedule.observe(loss=trace.errors[-1],
                                   tau_obs=float(t - ref))
    if params_versions:
        trace.final_params = params_versions[max(params_versions)]
    return trace


# ---------------------------------------------------------------------------
# K-batch async (event-driven)
# ---------------------------------------------------------------------------
def simulate_kbatch(problem: SimProblem, *, b_per_msg: int,
                    K: Optional[int] = None, t_c: float,
                    total_time: float, timing: ShiftedExponential,
                    opt_cfg: AmbdgConfig, rng_seed: int = 0,
                    delay_process=None, worker_process=None,
                    t_p: Optional[float] = None,
                    batch_schedule=None) -> Trace:
    """Dutta et al.'s K-batch async: workers continuously compute
    fixed-size jobs (b_per_msg gradients); the master updates on every
    K-th arriving message (default: ``opt_cfg.kbatch_K``); staleness
    is random.

    ``delay_process``: a seeded ``core.delay_process`` instance
    jittering the per-message UPLINK leg — message m takes
    ``0.5 * tau_m * t_p`` seconds instead of the deterministic
    ``0.5 * t_c`` (the process emits delays in epoch units; tau
    epochs of T_p is the round trip the paper's tau = ceil(T_c/T_p)
    encodes, so a fixed draw of tau reproduces ~the deterministic
    leg). Requires ``t_p``; the broadcast leg stays ``0.5 * t_c``.
    Draws happen in message-send order (heap order is seeded and
    deterministic), recorded in ``trace.delays``.

    ``worker_process``: a seeded ``core.worker_process`` instance
    driving elastic membership on the arrival heap. The process is
    epoch-indexed; epoch e covers wall time [e*t_p, (e+1)*t_p), so it
    requires ``t_p``. A worker whose job finishes while it is down
    loses the job (crashed before sending) and restarts at the start
    of its next active epoch; job durations divide by the epoch's
    speed multiplier. The static process changes nothing by
    construction. Per-epoch alive counts land in ``trace.active``.

    ``batch_schedule``: a seeded ``core.batch_schedule`` controller
    replacing the constant ``b_per_msg`` with a per-JOB target drawn
    at job-schedule time (heap order is seeded and deterministic, so
    the sequence is exact); a lost job (worker down at delivery)
    restarts with its original size — the job is re-run, not redrawn.
    The master runs adaptive-b dual averaging (each update's alpha
    takes its triggering batch's total count — the sum of the drawn
    targets — in place of the static ``b_bar``), and after each
    update the controller observes the current error and the mean
    staleness of the triggering messages. Targets land in
    ``trace.targets``."""
    K = K if K is not None else opt_cfg.kbatch_K
    if delay_process is not None and t_p is None:
        raise ValueError("delay_process needs t_p to convert epoch-"
                         "unit delays into uplink seconds")
    if worker_process is not None and t_p is None:
        raise ValueError("worker_process needs t_p to index its "
                         "per-epoch draws on the event clock")
    rng = np.random.default_rng(rng_seed)
    trace = Trace(scheme="kbatch")
    n = problem.n_workers

    master = KBatchMaster(problem.params0, opt_cfg, K,
                          adaptive_b=batch_schedule is not None)
    # worker i's current parameter version (epoch index) and its params
    worker_version = [1] * n
    params_versions = {1: problem.params0}
    # worker i's current job size: the constant b_per_msg, or the
    # schedule's target drawn when the job was scheduled
    job_b = [b_per_msg] * n
    clamp_mark = problem.clamp_events

    # elastic membership: lazily extend the seeded per-epoch
    # (mask, speeds) sequence as event times reach new epochs
    _epochs: List[Tuple[np.ndarray, np.ndarray]] = []

    def epoch_state(e: int) -> Tuple[np.ndarray, np.ndarray]:
        while len(_epochs) <= e:
            _epochs.append(worker_process.step())
        return _epochs[e]

    def next_active_epoch(worker: int, e: int) -> Optional[int]:
        horizon = int(total_time // t_p) + 2
        for e2 in range(e + 1, horizon + 1):
            if epoch_state(e2)[0][worker]:
                return e2
        return None

    # event heap: (time, kind, worker, payload)
    events: List[Tuple[float, int, int, object]] = []
    seq = 0
    def schedule_job(worker: int) -> int:
        """Draw (and record) the next job's size for ``worker``."""
        if batch_schedule is not None:
            job_b[worker] = int(batch_schedule.target())
            trace.targets.append(job_b[worker])
        return job_b[worker]

    def job_time(worker: int, at: float = 0.0) -> float:
        b_job = job_b[worker]
        if hasattr(timing, "per_worker_time"):
            base = timing.per_worker_time(worker, b_job)
        else:
            base = float(timing.time_for(rng, 1, b_job)[0])
        if worker_process is not None:
            speed = float(epoch_state(int(at // t_p))[1][worker])
            base = base / max(speed, 1e-12)
        return base

    for i in range(n):
        schedule_job(i)
        heapq.heappush(events, (job_time(i), seq, i, "finish")); seq += 1

    while events:
        now, _, worker, kind = heapq.heappop(events)
        if now > total_time:
            break
        if kind == "finish":
            if worker_process is not None:
                e = int(now // t_p)
                if not epoch_state(e)[0][worker]:
                    # the worker is down at delivery time: the job is
                    # lost (crashed before sending); it restarts at
                    # the start of its next active epoch
                    e2 = next_active_epoch(worker, e)
                    if e2 is not None:
                        restart = e2 * t_p
                        heapq.heappush(
                            events,
                            (restart + job_time(worker, restart), seq,
                             worker, "finish")); seq += 1
                    continue
            ver = worker_version[worker]
            g, c = problem.worker_grad(worker, params_versions[ver],
                                       job_b[worker],
                                       strict=batch_schedule is not None)
            # worker id rides along: the master orders each triggering
            # batch canonically by (ref_epoch, worker), so the update
            # sequence and the Fig.-4 staleness log depend only on the
            # seeded draws, never on heap tie-breaking
            msg = Message(grad_sum=g, count=c, ref_epoch=ver,
                          worker=worker)
            # message reaches the master after T_c / 2 (or a
            # stochastic uplink drawn from the delay process)
            if delay_process is not None:
                tau_m = delay_process.next()
                trace.delays.append(tau_m)
                uplink = 0.5 * tau_m * t_p
            else:
                uplink = 0.5 * t_c
            heapq.heappush(events, (now + uplink, seq, worker,
                                    ("msg", msg))); seq += 1
            # worker immediately starts the next job (fresh size draw
            # under a schedule)
            schedule_job(worker)
            heapq.heappush(events, (now + job_time(worker, now), seq,
                                    worker, "finish")); seq += 1
        elif isinstance(kind, tuple) and kind[0] == "msg":
            updated = master.receive(kind[1])
            if updated:
                ver = master.update_count + 1
                params_versions[ver] = master.params
                trace.times.append(now)
                trace.epochs.append(master.update_count)
                trace.errors.append(problem.error(master.params))
                trace.clamps.append(problem.clamp_events - clamp_mark)
                clamp_mark = problem.clamp_events
                if batch_schedule is not None:
                    tail = master.staleness_log[-K:]
                    batch_schedule.observe(
                        loss=trace.errors[-1],
                        tau_obs=float(np.mean(tail)) if tail else None)
                # broadcast: workers get it after T_c / 2
                for i in range(n):
                    heapq.heappush(events, (now + 0.5 * t_c, seq, i,
                                            ("recv", ver))); seq += 1
        elif isinstance(kind, tuple) and kind[0] == "recv":
            ver = kind[1]
            if ver > worker_version[worker]:
                worker_version[worker] = ver
            # gc: workers only move forward, and in-flight recv targets
            # are always >= the receiving worker's current version
            floor = min(worker_version)
            for old in list(params_versions):
                if old < floor:
                    del params_versions[old]

    trace.staleness = list(master.staleness_log)
    if worker_process is not None:
        trace.active = [int(a.sum()) for a, _ in _epochs]
    trace.final_params = master.params
    return trace
