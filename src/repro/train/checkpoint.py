"""Checkpointing: sharded-state save/restore with atomic renames and
retention. Fault-tolerance contract:

  * every array leaf of TrainState (params, z, delay buffers, counts,
    head pointer) plus the data-pipeline cursor and step are saved, so
    a restarted job reproduces the exact update sequence — including
    the in-flight delayed gradients (staleness semantics survive
    restart). This covers both master pipelines: the arena path's
    GradArena (int8 ring kept as int8 on disk, per-row scales,
    error-feedback residual, head) and the flat dual variable z in
    opt_state round-trip leaf-by-leaf like any other state;
  * writes go to ``<dir>/tmp.<step>`` then os.replace() into place, so
    a crash mid-save never corrupts the latest checkpoint;
  * ``keep`` most-recent checkpoints are retained.

Format: one .npz per checkpoint (leaves flattened with path-keys) +
a small JSON manifest. Device arrays are fetched with device_get — on a
real pod each host writes its own shard set (addressable_shards); the
single-process path here is the degenerate case of that layout.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(ckpt_dir: str, step: int, state, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(state)
    np.savez(os.path.join(tmp, "state.npz"), **flat)
    manifest = {"step": int(step), "keys": sorted(flat),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                     # atomic publish
    _apply_retention(ckpt_dir, keep)
    return final


def _apply_retention(ckpt_dir: str, keep: int):
    ckpts = sorted(d for d in os.listdir(ckpt_dir)
                   if re.fullmatch(r"step_\d+", d))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, old))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(d for d in os.listdir(ckpt_dir)
                   if re.fullmatch(r"step_\d+", d))
    return int(ckpts[-1].split("_")[1]) if ckpts else None


def _migrate_ring_v1(data, template_keys) -> Dict[str, np.ndarray]:
    """Delay-ring layout v1 -> v2 migration, at the numpy level.

    A v2 template asks for per-slot keys ``<p>.ring/<k>`` (tau+1 of
    them) where a v1 checkpoint holds one stacked ``<p>.ring`` array
    of tau slots plus a dynamic head. v2's schedule starts at phase 0
    (pop slot 1 first), so the i-th oldest v1 entry ``ring[(head+i) %
    tau]`` becomes v2 slot ``1+i``; slot 0 — the first push target —
    is dead and zeroed; per-slot counts permute the same way and the
    head resets to phase 0. Returns an overlay dict consulted before
    the raw file, so old checkpoints restore without a conversion
    pass. (Same permutation as ``arena.convert_ring``.)"""
    out: Dict[str, np.ndarray] = {}
    prefixes = {k[:-len("ring/0")] for k in template_keys
                if re.search(r"\.ring/\d+$", k)}
    for prefix in prefixes:
        if f"{prefix}ring" not in data:       # not a v1 checkpoint
            continue
        ring = data[f"{prefix}ring"]
        counts = data[f"{prefix}counts"]
        head = int(data[f"{prefix}head"])
        tau = ring.shape[0]
        perm = [(head + i) % tau for i in range(tau)]
        out[f"{prefix}ring/0"] = np.zeros_like(ring[0])
        new_counts = np.zeros((tau + 1,) + counts.shape[1:], counts.dtype)
        for i, k in enumerate(perm):
            out[f"{prefix}ring/{1 + i}"] = ring[k]
            new_counts[1 + i] = counts[k]
        if f"{prefix}scales" in data:
            scales = data[f"{prefix}scales"]
            out[f"{prefix}scales/0"] = np.ones_like(scales[0])
            for i, k in enumerate(perm):
                out[f"{prefix}scales/{1 + i}"] = scales[k]
        out[f"{prefix}counts"] = new_counts
        out[f"{prefix}head"] = np.zeros_like(data[f"{prefix}head"])
    return out


def _migrate_variable_ring_v2(data, keys, paths) -> Dict[str, np.ndarray]:
    """Delay-tolerant ring, per-slot tuple (v2-shaped) -> stacked v3.

    A v3 template asks for one stacked ``<p>.ring`` array where a
    pre-PR-7 delay-tolerant checkpoint holds per-slot keys
    ``<p>.ring/<k>`` (plus the ``.due`` metadata that marks the arena
    as variable — fixed v1 checkpoints also hold a stacked ``.ring``
    and must NOT match here). Slot k of the tuple IS row k of the
    stack (the variable schedule never permuted slots: the phase is
    ``head % n_slots``), so migration is a plain np.stack; scales
    stack the same way. Returns an overlay dict consulted before the
    raw file."""
    out: Dict[str, np.ndarray] = {}
    for key, (_, leaf) in zip(keys, paths):
        m = re.fullmatch(r"(.*\.)(ring|scales)", key)
        if not m or key in data:
            continue
        prefix = m.group(1)
        if f"{prefix}due" not in data or f"{m.group(0)}/0" not in data:
            continue                          # not a tuple-variable ckpt
        n_slots = data[f"{prefix}due"].shape[0]
        out[key] = np.stack([data[f"{key}/{k}"] for k in range(n_slots)])
    return out


def _migrate_decentralized_residual(data, keys, paths
                                    ) -> Dict[str, np.ndarray]:
    """DecentralizedState grew a gossip error-feedback ``residual``
    field (int8-compressed gossip); checkpoints saved before it lack
    the ``.residual`` key. A zero residual is exactly the state every
    run under ``compression="none"`` carries (and the correct cold
    start for error feedback), so old decentralized checkpoints
    restore with a zero overlay and continue bit-for-bit. Only the
    top-level ``.residual`` is synthesized — the arena's own
    ``.arena.residual`` predates this and is always present."""
    out: Dict[str, np.ndarray] = {}
    for key, (_, leaf) in zip(keys, paths):
        if key == ".residual" and key not in data and ".z" in data:
            out[key] = np.zeros(tuple(leaf.shape),
                                np.dtype(leaf.dtype))
    return out


def restore(ckpt_dir: str, state_template, step: Optional[int] = None
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``state_template`` (arrays are
    placed back leaf-by-leaf; shapes/dtypes validated). Checkpoints
    saved under delay-ring layout v1 load transparently into a v2
    template (``_migrate_ring_v1``), per-slot-tuple delay-tolerant
    checkpoints into the stacked v3 layout
    (``_migrate_variable_ring_v2``), pre-residual decentralized
    checkpoints into the current DecentralizedState
    (``_migrate_decentralized_residual``); every restored v2 arena
    gets its static slot phase re-derived from the saved head
    counter."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "state.npz"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    keys = ["/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                     for q in p) for p, _ in paths]
    migrated = _migrate_ring_v1(data, keys)
    migrated.update(_migrate_variable_ring_v2(data, keys, paths))
    migrated.update(_migrate_decentralized_residual(data, keys, paths))
    leaves = []
    for key, (p, leaf) in zip(keys, paths):
        arr = migrated[key] if key in migrated else data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    from repro.core.arena import sync_ring_phase
    return sync_ring_phase(restored), manifest["extra"]
