"""Failure detection & elastic membership for the anytime scheme.

AMB-DG's aggregation rule makes fault tolerance cheap: a worker that
misses an epoch simply contributes b_i(t) = 0 and the weighted
normalization stays exact (paper Sec. IV-C — the cost appears only in
the b_bar/b_hat straggler ratio). This module tracks liveness and
converts it into the per-epoch anytime mask; persistent failures
trigger an elastic re-mesh request (handled by the host loop, which
records the plan, checkpoints, and readmits workers the elastic
process brings back — see ``train.loop`` and
``core.worker_process``).
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Set

import numpy as np

log = logging.getLogger(__name__)


@dataclass
class WorkerHealth:
    n_workers: int
    heartbeat_timeout: float = 30.0
    eviction_misses: int = 3
    # Epoch the clock starts at. Callers driving liveness on a virtual
    # clock (``at=float(step)`` — the elastic host loop) MUST set this,
    # otherwise the wall-clock seed makes never-heard-from workers look
    # infinitely fresh against small virtual times.
    t0: Optional[float] = None

    def __post_init__(self):
        now = time.monotonic() if self.t0 is None else self.t0
        self.last_seen = {i: now for i in range(self.n_workers)}
        self.missed: Dict[int, int] = {i: 0 for i in range(self.n_workers)}
        self.evicted: Set[int] = set()
        # heartbeats received from already-evicted workers: ignored
        # (eviction is explicit — a zombie heartbeat must not silently
        # resurrect a worker the re-mesh plan already dropped), but
        # counted and logged so the launcher can see them
        self.ignored_heartbeats: int = 0

    def heartbeat(self, worker: int, at: Optional[float] = None) -> bool:
        """Record a liveness signal. Returns True if accepted; a
        heartbeat from an EVICTED worker is ignored (readmission is
        the explicit ``readmit`` path the elastic re-mesh drives, not
        a side effect of a late packet)."""
        if worker in self.evicted:
            self.ignored_heartbeats += 1
            log.info("ignored heartbeat from evicted worker %d "
                     "(%d ignored so far)", worker,
                     self.ignored_heartbeats)
            return False
        self.last_seen[worker] = time.monotonic() if at is None else at
        self.missed[worker] = 0
        return True

    def readmit(self, worker: int, at: Optional[float] = None):
        """Elastic re-mesh: bring an evicted worker back into the
        fleet (fresh liveness state). The recovery half of the
        eviction -> re-mesh plan -> checkpoint-restore cycle."""
        self.evicted.discard(worker)
        self.missed[worker] = 0
        self.last_seen[worker] = time.monotonic() if at is None else at

    def tick(self, at: Optional[float] = None) -> List[int]:
        """Returns workers newly considered failed this epoch."""
        now = time.monotonic() if at is None else at
        newly = []
        for i in range(self.n_workers):
            if i in self.evicted:
                continue
            if now - self.last_seen[i] > self.heartbeat_timeout:
                self.missed[i] += 1
                newly.append(i)
                if self.missed[i] >= self.eviction_misses:
                    self.evicted.add(i)
        return newly

    def anytime_mask(self, b: np.ndarray, at: Optional[float] = None
                     ) -> np.ndarray:
        """Zero out the contributions of failed workers: they are
        indistinguishable from infinitely slow ones to the aggregation."""
        now = time.monotonic() if at is None else at
        out = b.copy()
        for i in range(self.n_workers):
            if i in self.evicted or now - self.last_seen[i] > self.heartbeat_timeout:
                out[i] = 0
        return out

    @property
    def needs_rescale(self) -> bool:
        """Persistent failures -> ask the launcher for an elastic
        re-mesh (drop evicted workers, rebuild, restore checkpoint)."""
        return len(self.evicted) > 0

    def rescale_plan(self) -> Dict:
        alive = [i for i in range(self.n_workers) if i not in self.evicted]
        return {"alive": alive, "n_workers": len(alive)}

    # -- restart exactness -------------------------------------------------
    def state_dict(self) -> Dict:
        return {"last_seen": dict(self.last_seen),
                "missed": dict(self.missed),
                "evicted": sorted(self.evicted),
                "ignored_heartbeats": self.ignored_heartbeats}

    def load_state_dict(self, s: Dict):
        self.last_seen = {int(k): float(v)
                          for k, v in s["last_seen"].items()}
        self.missed = {int(k): int(v) for k, v in s["missed"].items()}
        self.evicted = set(int(w) for w in s["evicted"])
        self.ignored_heartbeats = int(s.get("ignored_heartbeats", 0))


def fold_anytime_weights(weights: np.ndarray, active: np.ndarray,
                         speeds: np.ndarray, n_workers: int,
                         samples_per_worker: int) -> np.ndarray:
    """Fold one elastic ``(active, speeds)`` draw into the pipeline's
    per-sample anytime weights: worker i's effective count becomes
    b'_i = floor(b_i * speed_i) clipped to [0, samples_per_worker],
    zeroed when inactive — a dead worker contributes b_i = 0 and the
    eq. (5) normalization stays exact (paper Sec. IV-C).

    Under the all-alive/speed-1.0 draw the static process emits,
    floor(b_i * 1.0) == b_i exactly (b_i is a small integer), so the
    returned weights are bit-identical to the input — the static ≡
    no-churn regression contract ``tests/test_elastic.py`` pins."""
    w = weights.reshape(n_workers, samples_per_worker)
    b = w.sum(axis=1)                       # per-worker counts (exact
    #                                         small ints as f32/f64)
    b_eff = np.floor(b * np.asarray(speeds, np.float64))
    b_eff = np.clip(b_eff, 0, samples_per_worker).astype(np.int64)
    b_eff = np.where(np.asarray(active, bool), b_eff, 0)
    out = np.zeros_like(w)
    for i, bi in enumerate(b_eff):
        out[i, :bi] = 1.0
    return out.reshape(-1)
