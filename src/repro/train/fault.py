"""Failure detection & elastic membership for the anytime scheme.

AMB-DG's aggregation rule makes fault tolerance cheap: a worker that
misses an epoch simply contributes b_i(t) = 0 and the weighted
normalization stays exact (paper Sec. IV-C — the cost appears only in
the b_bar/b_hat straggler ratio). This module tracks liveness and
converts it into the per-epoch anytime mask; persistent failures
trigger an elastic re-mesh request (handled by the launcher, which
rebuilds the mesh and restores from the last checkpoint).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np


@dataclass
class WorkerHealth:
    n_workers: int
    heartbeat_timeout: float = 30.0
    eviction_misses: int = 3

    def __post_init__(self):
        now = time.monotonic()
        self.last_seen = {i: now for i in range(self.n_workers)}
        self.missed: Dict[int, int] = {i: 0 for i in range(self.n_workers)}
        self.evicted: Set[int] = set()

    def heartbeat(self, worker: int, at: Optional[float] = None):
        self.last_seen[worker] = time.monotonic() if at is None else at
        self.missed[worker] = 0

    def tick(self, at: Optional[float] = None) -> List[int]:
        """Returns workers newly considered failed this epoch."""
        now = time.monotonic() if at is None else at
        newly = []
        for i in range(self.n_workers):
            if i in self.evicted:
                continue
            if now - self.last_seen[i] > self.heartbeat_timeout:
                self.missed[i] += 1
                newly.append(i)
                if self.missed[i] >= self.eviction_misses:
                    self.evicted.add(i)
        return newly

    def anytime_mask(self, b: np.ndarray, at: Optional[float] = None
                     ) -> np.ndarray:
        """Zero out the contributions of failed workers: they are
        indistinguishable from infinitely slow ones to the aggregation."""
        now = time.monotonic() if at is None else at
        out = b.copy()
        for i in range(self.n_workers):
            if i in self.evicted or now - self.last_seen[i] > self.heartbeat_timeout:
                out[i] = 0
        return out

    @property
    def needs_rescale(self) -> bool:
        """Persistent failures -> ask the launcher for an elastic
        re-mesh (drop evicted workers, rebuild, restore checkpoint)."""
        return len(self.evicted) > 0

    def rescale_plan(self) -> Dict:
        alive = [i for i in range(self.n_workers) if i not in self.evicted]
        return {"alive": alive, "n_workers": len(alive)}
