"""Host training loop: fixed-time (anytime) epochs, checkpoint/restart,
failure handling.

This is the deployment loop the launcher runs, for ANY registered
strategy (``rc.strategy`` -> ``repro.api.build``). Each iteration:
  1. the data pipeline draws per-worker anytime counts b_i(t) (real
     timer on hardware; shifted-exponential model in CI) and emits the
     masked global batch;
  2. the health tracker zeroes contributions of failed workers
     (the aggregation stays exact — paper Sec. IV-C);
  3. the strategy's jitted step runs (e.g. AMB-DG: anytime accumulate
     -> delayed pod exchange -> dual-averaging update; decentralized:
     anytime accumulate -> r gossip rounds -> per-worker prox);
  4. periodic checkpoint (atomic, retention-managed) including the
     strategy state (delay buffers / per-worker duals), so staleness
     and consensus semantics survive restart.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.data.pipeline import AnytimePipeline
from repro.data.timing import ShiftedExponential
from repro.models.api import Model
from repro.train import checkpoint as ckpt
from repro.train.fault import WorkerHealth, fold_anytime_weights


@dataclasses.dataclass
class LoopConfig:
    n_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    n_workers: int = 8                  # logical anytime workers
    samples_per_worker: int = 8
    use_timing_model: bool = True
    # elastic mode: consecutive dead epochs before a worker is evicted
    # (eviction -> re-mesh plan -> immediate checkpoint; the worker is
    # readmitted when the elastic process brings it back)
    eviction_misses: int = 3


def _served_params(state, strategy_name: str):
    """The parameter tree (w = -alpha z) the publication channel
    snapshots, across strategy state layouts: ambdg/amb carry it as
    ``state.params``; kbatch wraps the base state; decentralized stacks
    per-worker copies — serve worker 0's view (post-gossip they agree
    up to consensus error, which the staleness-vs-quality column of
    BENCH_serve tracks anyway)."""
    if hasattr(state, "base"):
        state = state.base
    params = state.params
    if strategy_name == "decentralized":
        params = jax.tree.map(lambda a: a[0], params)
    return params


def train(model: Model, rc: RunConfig, loop: LoopConfig,
          log_fn: Callable[[Dict], None] = None) -> Dict:
    from repro import api
    strategy = api.build(model, rc)
    init_state, train_step = strategy.init_state, strategy.train_step
    step_fn = jax.jit(train_step, donate_argnums=(0,))

    timing = (ShiftedExponential() if loop.use_timing_model else None)
    pipeline = AnytimePipeline(
        cfg=rc.model, n_workers=loop.n_workers,
        samples_per_worker=loop.samples_per_worker,
        seq_len=rc.shape.seq_len if rc.model.family not in
        ("linreg", "cnn") else 0,
        seed=rc.seed, timing=timing, t_p=rc.ambdg.t_p)

    # stochastic staleness: the host owns the seeded delay process and
    # ships one draw per step to the device ring as batch["delay"]
    # (ambdg is the strategy with a master delay ring; the others
    # either reject or strip non-fixed processes at build time)
    delay_proc = None
    if rc.delay.process != "fixed" and rc.strategy == "ambdg":
        from repro.core.delay_process import make_delay_process
        delay_proc = make_delay_process(rc.delay, rc.ambdg.tau)

    # adaptive minibatch schedule: the host owns the seeded controller
    # (Strategy.batch_schedule(); None under the default "fixed"
    # schedule — the exact pre-existing path), draws one target per
    # step, caps the anytime weights with it, and ships it to the
    # device step as batch["b_sched"] (alpha swaps it for b_bar)
    batch_sched = strategy.batch_schedule()

    # elastic workers: the host owns the seeded worker process and
    # folds one (active_mask, speeds) draw per step into the anytime
    # weights; the "static" default keeps the exact pre-existing
    # no-churn path (no process object, no fold)
    elastic_proc = None
    if rc.elastic.process != "static":
        from repro.core.worker_process import make_worker_process
        elastic_proc = make_worker_process(rc.elastic, loop.n_workers)

    # train-while-serve: the master publishes w = -alpha z snapshots
    # into the bounded-staleness ring every publish_period master
    # updates; inference engines pop asynchronously (serve.publisher).
    # publish_period=0 (default) keeps the loop byte-identical.
    publisher = None
    if rc.serve.publish_period > 0:
        from repro.core.arena import make_layout
        from repro.serve.publisher import WeightPublisher
        params_struct = jax.eval_shape(lambda k: model.init(k)[0],
                                       jax.random.PRNGKey(0))
        publisher = WeightPublisher(make_layout(params_struct), rc.serve)

    state = init_state(jax.random.PRNGKey(rc.seed))
    start_step = 0
    # heartbeats are driven by the elastic process on a virtual epoch
    # clock (at=step; a missed epoch is a missed heartbeat), or by
    # real wall time when no process runs
    health = (WorkerHealth(loop.n_workers, heartbeat_timeout=0.5,
                           eviction_misses=loop.eviction_misses, t0=0.0)
              if elastic_proc is not None
              else WorkerHealth(loop.n_workers))
    if loop.ckpt_dir and ckpt.latest_step(loop.ckpt_dir) is not None:
        state, extra = ckpt.restore(loop.ckpt_dir, state)
        pipeline.load_state_dict(extra["pipeline"])
        if delay_proc is not None and "delay_process" in extra:
            delay_proc.load_state_dict(extra["delay_process"])
        if elastic_proc is not None and "elastic_process" in extra:
            # restart exactness: the remaining churn sequence AND the
            # liveness bookkeeping survive the restart
            elastic_proc.load_state_dict(extra["elastic_process"])
            if "health" in extra:
                health.load_state_dict(extra["health"])
        if publisher is not None and "publisher" in extra:
            # the publish ring and its staleness metadata survive too —
            # servers keep popping due snapshots across the restart
            publisher.load_state_dict(extra["publisher"])
        if batch_sched is not None and "batch_schedule" in extra:
            # the controller's counters, EMA trackers and rng survive,
            # so the remaining b(t) sequence is restart-exact
            batch_sched.load_state_dict(extra["batch_schedule"])
        start_step = extra["step"]

    wants_active = bool(getattr(strategy, "consumes_active_mask", False))
    history = []
    remesh_events = []
    t_start = time.monotonic()

    def save_ckpt(next_step: int, plan=None):
        extra = {"step": next_step, "pipeline": pipeline.state_dict()}
        if delay_proc is not None:
            # same restart-exactness contract as the data pipeline:
            # the remaining delay sequence survives the restart
            extra["delay_process"] = delay_proc.state_dict()
        if elastic_proc is not None:
            extra["elastic_process"] = elastic_proc.state_dict()
            extra["health"] = health.state_dict()
        if publisher is not None:
            extra["publisher"] = publisher.state_dict()
        if batch_sched is not None:
            extra["batch_schedule"] = batch_sched.state_dict()
        if plan is not None:
            extra["remesh_plan"] = plan
        ckpt.save(loop.ckpt_dir, next_step, state, extra=extra)

    for step in range(start_step, loop.n_steps):
        batch = pipeline.next_global_batch()
        b_target = None
        if batch_sched is not None:
            from repro.data.pipeline import apply_batch_target
            b_target = batch_sched.target()
            batch["weights"] = apply_batch_target(
                batch["weights"], b_target, loop.n_workers,
                loop.samples_per_worker)
        remesh_plan = None
        if elastic_proc is not None:
            active, speeds = elastic_proc.step()
            at = float(step)
            for i in np.flatnonzero(active):
                if int(i) in health.evicted:
                    # elastic re-mesh, recovery half: the process
                    # brought the worker back -> readmit explicitly
                    health.readmit(int(i), at=at)
                    remesh_events.append({"step": step,
                                          "event": "readmit",
                                          "worker": int(i)})
                health.heartbeat(int(i), at=at)
            before = set(health.evicted)
            health.tick(at=at)
            newly_evicted = sorted(health.evicted - before)
            batch["weights"] = fold_anytime_weights(
                batch["weights"], active, speeds, loop.n_workers,
                loop.samples_per_worker)
            if wants_active:
                batch["active"] = active.astype(np.float32)
            if newly_evicted:
                # persistent failure -> elastic re-mesh plan + an
                # immediate checkpoint after this step commits (the
                # launcher would rebuild the mesh and restore it)
                remesh_plan = health.rescale_plan()
                remesh_plan["evicted"] = sorted(health.evicted)
                remesh_events.append({"step": step, "event": "evict",
                                      "workers": newly_evicted,
                                      "plan": remesh_plan})
        else:
            # fault masking: failed workers contribute b_i = 0
            failed = health.tick()
            if failed:
                w = batch["weights"].reshape(loop.n_workers, -1)
                w[failed, :] = 0.0
                batch["weights"] = w.reshape(-1)
        if delay_proc is not None:
            batch["delay"] = np.int32(delay_proc.next())
        if b_target is not None:
            batch["b_sched"] = np.float32(b_target)
        batch = jax.tree.map(jax.numpy.asarray, batch)
        state, metrics = step_fn(state, batch)
        if batch_sched is not None:
            # closed-loop feedback: the step's loss damps adadamp, the
            # observed staleness feeds the delay-aware scaling
            batch_sched.observe(
                loss=float(metrics["loss"]),
                tau_obs=(float(metrics["tau_applied"])
                         if "tau_applied" in metrics else None))
        if publisher is not None and \
                (step + 1) % rc.serve.publish_period == 0:
            publisher.publish(_served_params(state, rc.strategy),
                              step + 1)
        if (step + 1) % loop.log_every == 0 or step == loop.n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["wall_s"] = time.monotonic() - t_start
            if elastic_proc is not None:
                m["active_workers"] = float(active.sum())
            history.append(m)
            if log_fn:
                log_fn(m)
        if loop.ckpt_dir and ((step + 1) % loop.ckpt_every == 0
                              or remesh_plan is not None):
            save_ckpt(step + 1, plan=remesh_plan)
    return {"state": state, "history": history,
            "b_history": pipeline.b_history,
            "remesh_events": remesh_events,
            "publisher": publisher}
