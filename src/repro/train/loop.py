"""Host training loop: fixed-time (anytime) epochs, checkpoint/restart,
failure handling.

This is the deployment loop the launcher runs, for ANY registered
strategy (``rc.strategy`` -> ``repro.api.build``). Each iteration:
  1. the data pipeline draws per-worker anytime counts b_i(t) (real
     timer on hardware; shifted-exponential model in CI) and emits the
     masked global batch;
  2. the health tracker zeroes contributions of failed workers
     (the aggregation stays exact — paper Sec. IV-C);
  3. the strategy's jitted step runs (e.g. AMB-DG: anytime accumulate
     -> delayed pod exchange -> dual-averaging update; decentralized:
     anytime accumulate -> r gossip rounds -> per-worker prox);
  4. periodic checkpoint (atomic, retention-managed) including the
     strategy state (delay buffers / per-worker duals), so staleness
     and consensus semantics survive restart.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.data.pipeline import AnytimePipeline
from repro.data.timing import ShiftedExponential
from repro.models.api import Model
from repro.train import checkpoint as ckpt
from repro.train.fault import WorkerHealth


@dataclasses.dataclass
class LoopConfig:
    n_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    n_workers: int = 8                  # logical anytime workers
    samples_per_worker: int = 8
    use_timing_model: bool = True


def train(model: Model, rc: RunConfig, loop: LoopConfig,
          log_fn: Callable[[Dict], None] = None) -> Dict:
    from repro import api
    strategy = api.build(model, rc)
    init_state, train_step = strategy.init_state, strategy.train_step
    step_fn = jax.jit(train_step, donate_argnums=(0,))

    timing = (ShiftedExponential() if loop.use_timing_model else None)
    pipeline = AnytimePipeline(
        cfg=rc.model, n_workers=loop.n_workers,
        samples_per_worker=loop.samples_per_worker,
        seq_len=rc.shape.seq_len if rc.model.family not in
        ("linreg", "cnn") else 0,
        seed=rc.seed, timing=timing, t_p=rc.ambdg.t_p)

    # stochastic staleness: the host owns the seeded delay process and
    # ships one draw per step to the device ring as batch["delay"]
    # (ambdg is the strategy with a master delay ring; the others
    # either reject or strip non-fixed processes at build time)
    delay_proc = None
    if rc.delay.process != "fixed" and rc.strategy == "ambdg":
        from repro.core.delay_process import make_delay_process
        delay_proc = make_delay_process(rc.delay, rc.ambdg.tau)

    state = init_state(jax.random.PRNGKey(rc.seed))
    start_step = 0
    if loop.ckpt_dir and ckpt.latest_step(loop.ckpt_dir) is not None:
        state, extra = ckpt.restore(loop.ckpt_dir, state)
        pipeline.load_state_dict(extra["pipeline"])
        if delay_proc is not None and "delay_process" in extra:
            delay_proc.load_state_dict(extra["delay_process"])
        start_step = extra["step"]

    health = WorkerHealth(loop.n_workers)
    history = []
    t_start = time.monotonic()
    for step in range(start_step, loop.n_steps):
        batch = pipeline.next_global_batch()
        # fault masking: failed workers contribute b_i = 0
        failed = health.tick()
        if failed:
            w = batch["weights"].reshape(loop.n_workers, -1)
            w[failed, :] = 0.0
            batch["weights"] = w.reshape(-1)
        if delay_proc is not None:
            batch["delay"] = np.int32(delay_proc.next())
        batch = jax.tree.map(jax.numpy.asarray, batch)
        state, metrics = step_fn(state, batch)
        if (step + 1) % loop.log_every == 0 or step == loop.n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["wall_s"] = time.monotonic() - t_start
            history.append(m)
            if log_fn:
                log_fn(m)
        if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            extra = {"step": step + 1, "pipeline": pipeline.state_dict()}
            if delay_proc is not None:
                # same restart-exactness contract as the data pipeline:
                # the remaining delay sequence survives the restart
                extra["delay_process"] = delay_proc.state_dict()
            ckpt.save(loop.ckpt_dir, step + 1, state, extra=extra)
    return {"state": state, "history": history,
            "b_history": pipeline.b_history}
