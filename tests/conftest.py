import os

# Tests run on the single real CPU device (the dry-run, and ONLY the
# dry-run, forces 512 host devices — in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property-based modules need hypothesis; on images without it (CI
# installs it) skip them at collection instead of erroring the suite.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    collect_ignore = ["test_anytime.py", "test_compression.py",
                      "test_dual_averaging.py", "test_layers_properties.py"]
