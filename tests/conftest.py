import os

# Tests run on the single real CPU device (the dry-run, and ONLY the
# dry-run, forces 512 host devices — in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
