"""Anytime accumulator: masked scan == explicit per-worker sums
(paper eq. (2)/(5) aggregation semantics)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import anytime


def _quad_loss(params, batch):
    # per-sample loss 0.5||w - x||^2, weighted sum + count
    w = params["w"]
    per = 0.5 * jnp.sum(jnp.square(w[None, :] - batch["x"]), axis=-1)
    s = jnp.sum(per * batch["weights"])
    return s, {"count": jnp.sum(batch["weights"]), "loss_sum": s}


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10**6), st.sampled_from([1, 2, 4]))
def test_scan_matches_direct(seed, n_mb):
    rng = np.random.default_rng(seed)
    B, d = 8, 5
    params = {"w": jnp.asarray(rng.standard_normal(d), jnp.float32)}
    x = rng.standard_normal((B, d)).astype(np.float32)
    weights = (rng.random(B) < 0.7).astype(np.float32)
    batch = {"x": jnp.asarray(x), "weights": jnp.asarray(weights)}

    gsum, count, m = anytime.accumulate_scan(_quad_loss, params, batch, n_mb)
    # explicit: sum of weighted per-sample gradients d/dw = (w - x_i)
    expect = np.sum((np.asarray(params["w"])[None] - x)
                    * weights[:, None], axis=0)
    np.testing.assert_allclose(np.asarray(gsum["w"]), expect, rtol=2e-5,
                               atol=1e-5)
    assert float(count) == weights.sum()


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 10**6))
def test_while_matches_scan_when_all_active(seed):
    rng = np.random.default_rng(seed)
    B, d, n_mb = 8, 4, 4
    params = {"w": jnp.asarray(rng.standard_normal(d), jnp.float32)}
    batch = {"x": jnp.asarray(rng.standard_normal((B, d)), jnp.float32),
             "weights": jnp.ones((B,), jnp.float32)}
    g1, c1, _ = anytime.accumulate_scan(_quad_loss, params, batch, n_mb)
    g2, c2, _ = anytime.accumulate_while(_quad_loss, params, batch, n_mb,
                                         jnp.int32(n_mb))
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                               rtol=1e-6)
    assert float(c1) == float(c2)


def test_while_partial_trip_count():
    """A shard that only finishes 2 of 4 microbatches contributes
    exactly those 2 (the anytime semantics)."""
    B, d, n_mb = 8, 4, 4
    params = {"w": jnp.zeros((d,), jnp.float32)}
    x = np.arange(B * d, dtype=np.float32).reshape(B, d)
    batch = {"x": jnp.asarray(x), "weights": jnp.ones((B,), jnp.float32)}
    g, c, _ = anytime.accumulate_while(_quad_loss, params, batch, n_mb,
                                       jnp.int32(2))
    expect = np.sum((0 - x[:4]), axis=0)   # first 2 microbatches = 4 rows
    np.testing.assert_allclose(np.asarray(g["w"]), expect, rtol=1e-6)
    assert float(c) == 4.0


def test_normalize_guards_zero_count():
    g = anytime.normalize({"w": jnp.ones(3)}, jnp.float32(0.0))
    assert bool(jnp.all(jnp.isfinite(g["w"])))


def test_normalize_is_global_average():
    """g(t) = sum_i g_i / sum_i b_i (paper eq. (5)) — NOT the mean of
    per-worker means; stragglers are weighted by their contribution."""
    g1, b1 = {"w": jnp.asarray([10.0])}, 10.0   # worker 1: 10 samples
    g2, b2 = {"w": jnp.asarray([1.0])}, 1.0     # straggler: 1 sample
    total = jax.tree.map(lambda a, b: a + b, g1, g2)
    g = anytime.normalize(total, jnp.float32(b1 + b2))
    np.testing.assert_allclose(np.asarray(g["w"]), [1.0])  # 11/11
