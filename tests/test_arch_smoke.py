"""Per-architecture smoke tests (required deliverable f): reduced
same-family configs, one forward/train step on CPU, asserting shapes +
finiteness; plus a decode step for every arch with a decoder."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import (AmbdgConfig, MeshConfig, RunConfig, TRAIN_4K)
from repro.core import make_train_step
from repro.models import build_model

ARCHS = list(C.ARCH_IDS) + ["amb-linreg", "amb-cnn"]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = C.get_smoke_config(arch)
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    # axes tree pairs 1:1 with param leaves (tuples are the axes leaves)
    from repro.dist.sharding import _is_axes_leaf
    paired = jax.tree.map(lambda ax, leaf: len(ax) == leaf.ndim,
                          axes, params, is_leaf=_is_axes_leaf)
    assert all(jax.tree.leaves(paired))
    batch = model.dummy_batch(4, 64)
    loss_sum, aux = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss_sum))
    assert float(aux["count"]) > 0


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_train_step(arch):
    cfg = C.get_smoke_config(arch)
    model = build_model(cfg)
    rc = RunConfig(model=cfg,
                   shape=dataclasses.replace(TRAIN_4K, seq_len=64,
                                             global_batch=8),
                   mesh=MeshConfig(n_pods=1, data=1, model=1),
                   ambdg=AmbdgConfig(tau=1, n_microbatches=2, b_bar=8.0,
                                     smoothness_L=8.0))
    init_state, train_step = make_train_step(model, rc)
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(train_step)
    for i in range(3):
        batch = model.dummy_batch(8, 64, key=jax.random.PRNGKey(i))
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
    # delay pipeline: first tau steps applied zero gradients
    assert int(state.step) == 3
    leaves = jax.tree.leaves(state.params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_decode_step(arch):
    cfg = C.get_smoke_config(arch)
    model = build_model(cfg)
    if model.decode_step is None:
        pytest.skip("no decoder")
    params, _ = model.init(jax.random.PRNGKey(0))
    cache, caxes = model.init_decode_state(2, 64)
    # axes tree maps 1:1 onto cache leaves (tuples are the axes leaves)
    from repro.dist.sharding import _is_axes_leaf
    paired = jax.tree.map(lambda ax, leaf: len(ax) == leaf.ndim,
                          caxes, cache, is_leaf=_is_axes_leaf)
    assert all(jax.tree.leaves(paired))
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((2, 1), jnp.int32)
    for pos in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        assert logits.shape == (2, 1, cfg.padded_vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["yi-6b", "xlstm-125m", "zamba2-2.7b",
                                  "mixtral-8x7b"])
def test_decode_matches_forward(arch):
    """Prefill-by-decode logits == full forward logits at the last
    position (cache correctness)."""
    cfg = C.get_smoke_config(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    S = 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                                cfg.vocab_size)
    from repro.models import transformer as tf
    full_logits, _ = tf.forward(params, cfg, tokens)
    cache, _ = model.init_decode_state(1, 64)
    step = jax.jit(model.decode_step)
    for pos in range(S):
        logits, cache = step(params, cache, tokens[:, pos:pos + 1],
                             jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits[0, 0]),
                               np.asarray(full_logits[0, -1]),
                               rtol=0.05, atol=0.15)
