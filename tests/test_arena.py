"""Flat gradient arena: the fused master pipeline (flatten -> ring
push/pop -> dual update -> unflatten) must be bit-exact vs the per-leaf
pytree reference path across staleness, pod count, and compression —
including int8 error-feedback telescoping and head wrap-around — and
must never re-flatten the tree with a full concatenate per step.

Ring layout v2 (per-slot buffers, static phase schedule) additionally
must be bit-exact vs the stacked v1 layout across the same matrix, must
survive a v1-checkpoint -> v2 migration mid-run, and must compile on
XLA:CPU with NO ring-dtype copy instructions at all (the whole-ring
copy-protection v1 pays for the pop-read/push-write hazard)."""
import dataclasses
import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (AmbdgConfig, LINREG, MeshConfig, ModelConfig,
                                RunConfig, TRAIN_4K)
from repro.core import ambdg, anytime, arena, delayed
from repro.launch.hlo import copy_shapes
from repro.optim import make_arena_optimizer, make_optimizer

# odd, row-misaligned leaf sizes exercise padding in every leaf
SHAPES = {"a": (7,), "b": {"c": (3, 5), "d": (130,)}, "e": (257,)}


def _rc(tau, compression, optimizer="dual_averaging"):
    cfg = ModelConfig(name="t", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=8)
    return RunConfig(model=cfg, shape=TRAIN_4K,
                     mesh=MeshConfig(n_pods=1, data=1, model=1),
                     ambdg=AmbdgConfig(tau=tau, b_bar=8.0, smoothness_L=2.0,
                                       pod_compression=compression),
                     optimizer=optimizer)


def _params(key):
    leaves, treedef = jax.tree.flatten(
        SHAPES, is_leaf=lambda x: isinstance(x, tuple))
    ks = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [jax.random.normal(k, s, jnp.float32)
                  for k, s in zip(ks, leaves)])


def _pod_grads(key, n_pods):
    shapes, treedef = jax.tree.flatten(
        SHAPES, is_leaf=lambda x: isinstance(x, tuple))
    ks = jax.random.split(key, len(shapes))
    return jax.tree.unflatten(
        treedef, [jax.random.normal(k, (n_pods,) + s, jnp.float32)
                  for k, s in zip(ks, shapes)])


@pytest.mark.parametrize("compression", ["none", "int8"])
@pytest.mark.parametrize("n_pods", [1, 4])
@pytest.mark.parametrize("tau", [0, 1, 4])
def test_arena_bitexact_vs_pytree(tau, n_pods, compression):
    """10 steps (tau=4 wraps the ring twice): params and the dual
    variable z must match the pytree reference bit for bit.

    One documented exception: int8 with n_pods > 1. XLA:CPU duplicates
    the dequantize+pod-sum chain into multiple fusions and lowers the
    fold of array slices with different association per fusion, so the
    two jitted programs differ by a few ULP of the summands (the
    error-feedback residual keeps the drift bounded — it does not
    accumulate). There we assert ULP-level agreement instead; see
    docs/arena.md."""
    rc = _rc(tau, compression)
    params = _params(jax.random.PRNGKey(0))
    layout = arena.make_layout(params)

    opt_p = make_optimizer(rc)
    opt_a = make_arena_optimizer(rc, layout)

    p_ref, p_arena = params, params
    opt_ref = opt_p.init(params)
    opt_ar = opt_a.init()
    buf = delayed.init_buffer(params, tau, n_pods, compression)
    ar = arena.init_arena(layout, tau, n_pods, compression)

    @jax.jit
    def step_ref(p, o, b, grads, counts):
        if b is not None:
            gs, c, b = delayed.push_pop(b, grads, counts, compression)
        else:
            gs = jax.tree.map(delayed.pod_sum, grads)
            c = jnp.sum(counts)
        g = anytime.normalize(gs, c)
        p, o = opt_p.update(o, p, g)
        return p, o, b

    @jax.jit
    def step_arena(p, o, a, grads, counts):
        p, o, a, _, _ = ambdg.arena_master_update(
            layout, opt_a, p, o, a, grads, counts, compression)
        return p, o, a

    if compression == "int8" and n_pods > 1:
        def check(a, b):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-6, atol=5e-7)
    else:
        def check(a, b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    for t in range(10):
        grads = _pod_grads(jax.random.PRNGKey(100 + t), n_pods)
        counts = jnp.full((n_pods,), 3.0 + t)
        p_ref, opt_ref, buf = step_ref(p_ref, opt_ref, buf, grads, counts)
        p_arena, opt_ar, ar = step_arena(p_arena, opt_ar, ar, grads, counts)

        for a_leaf, b_leaf in zip(jax.tree.leaves(p_ref),
                                  jax.tree.leaves(p_arena)):
            check(a_leaf, b_leaf)
        z_arena = arena.unflatten_tree(layout, opt_ar.z, cast=False)
        for a_leaf, b_leaf in zip(jax.tree.leaves(opt_ref.z),
                                  jax.tree.leaves(z_arena)):
            check(a_leaf, b_leaf)


def test_arena_l2_ball_matches_pytree():
    """l2_ball prox: elementwise ops match the pytree path; only the
    ball-norm reduction order differs (flat vs per-leaf sums), so the
    paths agree at ULP tolerance with the projection active."""
    rc = _rc(1, "none")
    rc = rc.replace(ambdg=dataclasses.replace(rc.ambdg, proximal="l2_ball",
                                              radius_C=0.05))
    params = _params(jax.random.PRNGKey(0))
    layout = arena.make_layout(params)
    opt_p, opt_a = make_optimizer(rc), make_arena_optimizer(rc, layout)
    p_ref, p_arena = params, params
    o_ref, o_ar = opt_p.init(params), opt_a.init()
    buf = delayed.init_buffer(params, 1, 2)
    ar = arena.init_arena(layout, 1, 2)
    projected = False
    for t in range(6):
        grads = _pod_grads(jax.random.PRNGKey(t), 2)
        counts = jnp.full((2,), 4.0)
        gs, c, buf = delayed.push_pop(buf, grads, counts)
        p_ref, o_ref = opt_p.update(o_ref, p_ref,
                                    anytime.normalize(gs, c))
        p_arena, o_ar, ar, _, _ = ambdg.arena_master_update(
            layout, opt_a, p_arena, o_ar, ar, grads, counts, "none")
        norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                                  for x in jax.tree.leaves(p_ref))))
        projected = projected or abs(norm - rc.ambdg.radius_C) < 1e-5
        for a_leaf, b_leaf in zip(jax.tree.leaves(p_ref),
                                  jax.tree.leaves(p_arena)):
            np.testing.assert_allclose(np.asarray(a_leaf),
                                       np.asarray(b_leaf),
                                       rtol=2e-6, atol=1e-8)
    assert projected, "radius_C too large: projection never activated"


@pytest.mark.parametrize("optimizer", ["sgd", "adam"])
def test_arena_optimizers_match_pytree(optimizer):
    """The flat-state sgd/adam arena optimizers reproduce the per-leaf
    implementations (allclose: identical formulas, FP-identical ops)."""
    rc = _rc(2, "none", optimizer=optimizer)
    params = _params(jax.random.PRNGKey(1))
    layout = arena.make_layout(params)
    opt_p, opt_a = make_optimizer(rc), make_arena_optimizer(rc, layout)
    p_ref, p_arena = params, params
    o_ref, o_ar = opt_p.init(params), opt_a.init()
    for t in range(5):
        grads = _pod_grads(jax.random.PRNGKey(t), 2)
        gs = jax.tree.map(lambda g: jnp.sum(g, axis=0), grads)
        count = jnp.float32(6.0)
        p_ref, o_ref = opt_p.update(o_ref, p_ref,
                                    anytime.normalize(gs, count))
        g_flat = arena.flatten_tree(layout, grads, leading=1)
        p_arena, o_ar = opt_a.update(o_ar, p_arena,
                                     jnp.sum(g_flat, axis=0), count)
        for a_leaf, b_leaf in zip(jax.tree.leaves(p_ref),
                                  jax.tree.leaves(p_arena)):
            np.testing.assert_array_equal(np.asarray(a_leaf),
                                          np.asarray(b_leaf))


def test_flatten_roundtrip_exact():
    params = _params(jax.random.PRNGKey(2))
    layout = arena.make_layout(params)
    mat = arena.flatten_tree(layout, params)
    back = arena.unflatten_tree(layout, mat)
    for a_leaf, b_leaf in zip(jax.tree.leaves(params),
                              jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a_leaf), np.asarray(b_leaf))
    # pod-stacked round trip
    grads = _pod_grads(jax.random.PRNGKey(3), 4)
    g_flat = arena.flatten_tree(layout, grads, leading=1)
    assert g_flat.shape == (4, layout.rows, 128)
    back = arena.unflatten_tree(layout, g_flat)
    for a_leaf, b_leaf in zip(jax.tree.leaves(grads), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a_leaf), np.asarray(b_leaf))


def test_head_wraparound_semantics():
    """The entry applied at step t is the one pushed at t - tau, across
    several full ring rotations; the first tau pops are zero. Under
    ring v2 the schedule is the static ``phase`` (mirrored by the head
    leaf), cycling through the tau+1 per-slot buffers."""
    tau, n_pods = 2, 3
    params = {"w": jnp.zeros((5,))}
    layout = arena.make_layout(params)
    ar = arena.init_arena(layout, tau, n_pods)
    assert len(ar.ring) == tau + 1 and ar.phase == 0
    for t in range(1, 9):
        gs, c, ar = arena.push_pop(layout, ar,
                                   {"w": jnp.full((n_pods, 5), float(t))},
                                   jnp.full((n_pods,), float(t)))
        w = arena.unflatten_tree(layout, gs)["w"]
        if t <= tau:
            assert float(w[0]) == 0.0 and float(c) == 0.0
        else:
            assert float(w[0]) == (t - tau) * n_pods
            assert float(c) == (t - tau) * n_pods
        assert ar.phase == t % (tau + 1)
        assert int(ar.head) == ar.phase


@pytest.mark.parametrize("compression", ["none", "int8"])
def test_push_pop_pallas_branch_matches_ref(compression):
    """The Pallas branch (staging flatten + fused kernel, interpret on
    CPU) produces the same ring rotation as the scatter/XLA branch."""
    tau, n_pods = 2, 2
    params = _params(jax.random.PRNGKey(5))
    layout = arena.make_layout(params)
    ar_r = arena.init_arena(layout, tau, n_pods, compression)
    ar_p = arena.init_arena(layout, tau, n_pods, compression)
    for t in range(4):
        grads = _pod_grads(jax.random.PRNGKey(t), n_pods)
        counts = jnp.ones((n_pods,))
        gs_r, c_r, ar_r = arena.push_pop(layout, ar_r, grads, counts,
                                         compression, impl="ref")
        gs_p, c_p, ar_p = arena.push_pop(layout, ar_p, grads, counts,
                                         compression, impl="pallas",
                                         interpret=True)
        if compression == "none":
            np.testing.assert_allclose(np.asarray(gs_r), np.asarray(gs_p),
                                       rtol=1e-6, atol=1e-7)
            np.testing.assert_array_equal(np.asarray(ar_r.ring),
                                          np.asarray(ar_p.ring))
        else:
            # a 1-ULP difference in the kernel's internal fed = g + r
            # can flip a round-half boundary: allow isolated single-step
            # quantization disagreements, nothing larger
            qd = np.abs(np.asarray(ar_r.ring, np.int32)
                        - np.asarray(ar_p.ring, np.int32))
            assert qd.max() <= 1 and (qd > 0).mean() < 1e-3
            step = float(np.asarray(ar_r.scales).max())
            gd = np.abs(np.asarray(gs_r) - np.asarray(gs_p))
            assert gd.max() <= 1.01 * n_pods * step + 1e-6
            assert (gd > 1e-6).mean() < 1e-3
        assert float(c_r) == float(c_p)


def test_int8_error_feedback_telescoping():
    """residual(t) = fed(t) - dequant(t) exactly, so over T steps:
    sum(applied) + sum(in-flight dequants) + residual_T = sum(true).
    The arena must preserve this telescoping invariant (no drift)."""
    tau, n_pods = 2, 1
    params = {"w": jnp.zeros((64,))}
    layout = arena.make_layout(params)
    ar = arena.init_arena(layout, tau, n_pods, "int8")
    rng = np.random.default_rng(0)
    true_total = np.zeros(64, np.float32)
    applied = np.zeros(64, np.float32)
    for t in range(20):
        g = 0.05 * rng.standard_normal((n_pods, 64)).astype(np.float32)
        true_total += g.sum(0)
        gs, _, ar = arena.push_pop(layout, ar, {"w": jnp.asarray(g)},
                                   jnp.ones((n_pods,)), compression="int8")
        applied += np.asarray(arena.unflatten_tree(layout, gs)["w"])
    # dequantize the tau entries still in flight + the residual; the
    # v1 view drops ring v2's spare slot (its entry is dead — already
    # popped and applied — so counting it would double-book)
    live = arena.convert_ring(ar, 1)
    in_flight = (np.asarray(live.ring, np.float32)
                 * np.asarray(live.scales)[..., None]).sum(axis=(0, 1))
    residual = np.asarray(ar.residual).sum(axis=0)
    total = applied + arena.unflatten_tree(
        layout, jnp.asarray(in_flight))["w"] + arena.unflatten_tree(
        layout, jnp.asarray(residual))["w"]
    np.testing.assert_allclose(np.asarray(total), true_total,
                               atol=1e-5, rtol=1e-5)


def _stack(x):
    """v2 slot tuples -> stacked numpy (v1 view helper for asserts)."""
    return np.stack([np.asarray(s) for s in x]) if isinstance(x, tuple) \
        else np.asarray(x)


@pytest.mark.parametrize("compression", ["none", "int8"])
@pytest.mark.parametrize("n_pods", [1, 4])
@pytest.mark.parametrize("tau", [1, 2, 4])
def test_ring_v2_matches_v1(tau, n_pods, compression):
    """Ring layout v2 (per-slot buffers, static phase) is bit-exact vs
    the stacked v1 layout across tau x pods x compression: same popped
    sums, same counts, and — through the v1 view, which undoes the
    phase permutation and drops the dead spare slot — the same ring
    contents, for 10 steps (tau=4 wraps the schedule twice)."""
    params = _params(jax.random.PRNGKey(0))
    layout = arena.make_layout(params)
    ar1 = arena.init_arena(layout, tau, n_pods, compression,
                           ring_version=1)
    ar2 = arena.init_arena(layout, tau, n_pods, compression,
                           ring_version=2)
    assert arena.ring_version(ar1) == 1 and arena.ring_version(ar2) == 2
    assert len(ar2.ring) == tau + 1

    step1 = jax.jit(functools.partial(arena.push_pop, layout,
                                      compression=compression))
    step2 = jax.jit(functools.partial(arena.push_pop, layout,
                                      compression=compression))
    for t in range(10):
        grads = _pod_grads(jax.random.PRNGKey(200 + t), n_pods)
        counts = jnp.full((n_pods,), 2.0 + t)
        gs1, c1, ar1 = step1(ar1, grads, counts)
        gs2, c2, ar2 = step2(ar2, grads, counts)
        np.testing.assert_array_equal(np.asarray(gs1), np.asarray(gs2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        view = arena.convert_ring(jax.device_get(ar2), 1)
        # compare in oldest-first order: v1 slots rotated to head
        order1 = [(int(ar1.head) + i) % tau for i in range(tau)]
        np.testing.assert_array_equal(_stack(ar1.ring)[order1],
                                      _stack(view.ring))
        if compression == "int8":
            np.testing.assert_array_equal(_stack(ar1.scales)[order1],
                                          _stack(view.scales))
            np.testing.assert_array_equal(np.asarray(ar1.residual),
                                          np.asarray(view.residual))
        np.testing.assert_array_equal(np.asarray(ar1.counts)[order1],
                                      np.asarray(view.counts))


@pytest.mark.parametrize("compression", ["none", "int8"])
@pytest.mark.parametrize("tau", [1, 2, 4])
def test_variable_ring_constant_delay_matches_static(tau, compression):
    """The delay-tolerant ring fed the CONSTANT sequence tau_t = tau is
    the static-phase v2 path: same popped sums, counts, ring slots,
    scales and residual — value-identical per step across three full
    wraps (the masked pop folds exact zeros around the one due slot,
    and the push schedule lands in the same slot indices). This is the
    degeneracy the fixed delay process rides."""
    n_pods = 2
    params = _params(jax.random.PRNGKey(0))
    layout = arena.make_layout(params)
    ar_s = arena.init_arena(layout, tau, n_pods, compression)
    ar_v = arena.init_arena(layout, tau, n_pods, compression,
                            variable=True)
    step_s = jax.jit(functools.partial(arena.push_pop, layout,
                                       compression=compression))
    step_v = jax.jit(functools.partial(arena.push_pop_variable, layout,
                                       compression=compression))
    for t in range(3 * (tau + 1) + 2):
        grads = _pod_grads(jax.random.PRNGKey(400 + t), n_pods)
        counts = jnp.full((n_pods,), 2.0 + t)
        gs_s, c_s, ar_s = step_s(ar_s, grads, counts)
        gs_v, c_v, tau_obs, ar_v = step_v(ar_v, grads, counts,
                                          jnp.int32(tau))
        np.testing.assert_array_equal(np.asarray(gs_s), np.asarray(gs_v))
        assert float(c_s) == float(c_v)
        # the fill phase pops nothing (tau_obs 0); afterwards exactly
        # the constant staleness
        assert float(tau_obs) == (float(tau) if t >= tau else 0.0)
        for s_slot, v_slot in zip(ar_s.ring, ar_v.ring):
            np.testing.assert_array_equal(np.asarray(s_slot),
                                          np.asarray(v_slot))
        np.testing.assert_array_equal(np.asarray(ar_s.counts),
                                      np.asarray(ar_v.counts))
        if compression == "int8":
            for s_sc, v_sc in zip(ar_s.scales, ar_v.scales):
                np.testing.assert_array_equal(np.asarray(s_sc),
                                              np.asarray(v_sc))
            np.testing.assert_array_equal(np.asarray(ar_s.residual),
                                          np.asarray(ar_v.residual))
        assert ar_v.phase == ar_s.phase


_VARIABLE_DELAY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import functools
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import MeshConfig
    from repro.core import arena
    from repro.dist.context import sharding_profile

    mesh_cfg = MeshConfig(n_pods=2, data=2, model=2)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    params = {"a": jnp.zeros((7,)), "b": jnp.zeros((300, 5)),
              "c": jnp.zeros((257,))}
    layout = arena.make_layout(params)
    n_pods, tau = 2, 2

    def grads_at(t):
        ks = jax.random.split(jax.random.PRNGKey(t), 3)
        return {k: jax.random.normal(kk, (n_pods,) + params[k].shape)
                for k, kk in zip(sorted(params), ks)}

    ar_s = arena.init_arena(layout, tau, n_pods, "int8")
    ar_v = arena.init_arena(layout, tau, n_pods, "int8", variable=True)
    for t in range(8):
        g = grads_at(t)
        counts = jnp.full((n_pods,), 4.0)
        # both paths under the multi-pod GSPMD profile: the static
        # schedule vs the delay-tolerant masked fold fed tau_t = tau
        with mesh, sharding_profile(mesh_cfg):
            gs_s, c_s, ar_s = arena.push_pop(
                layout, ar_s, g, counts, "int8", impl="ref")
            gs_v, c_v, tau_obs, ar_v = arena.push_pop_variable(
                layout, ar_v, g, counts, jnp.int32(tau), "int8")
        np.testing.assert_array_equal(np.asarray(gs_s), np.asarray(gs_v))
        assert float(c_s) == float(c_v)
        for s_slot, v_slot in zip(ar_s.ring, ar_v.ring):
            np.testing.assert_array_equal(np.asarray(s_slot),
                                          np.asarray(v_slot))
        for s_sc, v_sc in zip(ar_s.scales, ar_v.scales):
            np.testing.assert_array_equal(np.asarray(s_sc),
                                          np.asarray(v_sc))
        np.testing.assert_array_equal(np.asarray(ar_s.residual),
                                      np.asarray(ar_v.residual))
    print("VARIABLE_DELAY_OK")
""")


@pytest.mark.slow
def test_variable_ring_matches_static_8dev():
    """The fixed-delay degeneracy holds under the multi-pod GSPMD
    profile too (8 virtual CPU devices, pod=2 mesh): the delay-tolerant
    masked fold fed the constant sequence is bit-identical to the
    static-phase path — int8 payload, per-row scales and error-feedback
    residual included. Subprocess: the forced device count must not
    leak into this test process."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _VARIABLE_DELAY_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "VARIABLE_DELAY_OK" in out.stdout


def _arena_master_hlo(compression, ring_version, tau=2, n_pods=2):
    """Compile the donated arena master update on CPU; return (HLO
    text, layout)."""
    rc = _rc(tau, compression)
    params = _params(jax.random.PRNGKey(0))
    layout = arena.make_layout(params)
    opt_a = make_arena_optimizer(rc, layout)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(state, grads, counts):
        p, o, a = state
        p, o, a, _, _ = ambdg.arena_master_update(
            layout, opt_a, p, o, a, grads, counts, compression)
        return p, o, a

    state = jax.eval_shape(
        lambda: (params, opt_a.init(),
                 arena.init_arena(layout, tau, n_pods, compression,
                                  ring_version=ring_version)))
    grads = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((n_pods,) + p.shape, p.dtype),
        params)
    lowered = step.lower(state, grads,
                         jax.ShapeDtypeStruct((n_pods,), jnp.float32))
    return lowered.compile().as_text(), layout


@pytest.mark.parametrize("compression", ["none", "int8"])
def test_no_whole_ring_copy_protection(compression):
    """XLA:CPU inserts NO ring-dtype copy instructions for the v2
    master update: the pop reads and the push overwrites two different
    statically-indexed slot buffers, so the whole-ring copy-protection
    v1 pays for the pop-read/push-write hazard (plus the lax.switch
    operand/result copies) is structurally impossible. v1 is compiled
    too, as a positive control for the detector."""
    tau, n_pods = 2, 2
    hlo2, layout = _arena_master_hlo(compression, 2, tau, n_pods)
    hlo1, _ = _arena_master_hlo(compression, 1, tau, n_pods)
    dt = "s8" if compression == "int8" else "f32"
    slot = f"{dt}[{n_pods},{layout.rows},128]"
    ring = f"{dt}[{tau},{n_pods},{layout.rows},128]"

    copies1 = copy_shapes(hlo1)
    assert copies1.get(ring, 0) >= 1, (
        "detector sanity: v1 should pay whole-ring copy-protection; "
        f"saw {copies1}")
    copies2 = copy_shapes(hlo2)
    assert copies2.get(ring, 0) == 0 and copies2.get(slot, 0) == 0, (
        f"ring layout v2 must compile without ring-dtype copies; "
        f"saw {copies2}")
    if compression == "none":
        # no staging/fed scratch on this path: no big copies at all
        big = {k: v for k, v in copies2.items()
               if np.prod([int(d) for d in k.split("[")[1][:-1]
                           .split(",") if d]) >= layout.rows * 128}
        assert not big, big


def test_checkpoint_v1_ring_migration(tmp_path):
    """Mid-run migration: train under ring v2, convert the arena to the
    v1 layout (as a pre-migration checkpoint would hold), save, restore
    into a v2 template, continue — bit-for-bit identical to the
    uninterrupted v2 run, including the in-flight delayed gradients."""
    from repro.train import checkpoint as ckpt
    compression = "int8"
    tau, n_pods = 2, 2
    rc = _rc(tau, compression)
    params = _params(jax.random.PRNGKey(3))
    layout = arena.make_layout(params)
    opt_a = make_arena_optimizer(rc, layout)

    @jax.jit
    def step(p, o, a, grads, counts):
        p, o, a, _, _ = ambdg.arena_master_update(
            layout, opt_a, p, o, a, grads, counts, compression)
        return p, o, a

    def batches(t):
        return (_pod_grads(jax.random.PRNGKey(300 + t), n_pods),
                jnp.full((n_pods,), 3.0))

    p, o = params, opt_a.init()
    ar = arena.init_arena(layout, tau, n_pods, compression)
    for t in range(4):   # 4 steps: phase 4 % 3 == 1, mid-cycle
        p, o, ar = step(p, o, ar, *batches(t))
    assert ar.phase == 4 % (tau + 1) == 1

    # save in the v1 layout (what an old checkpoint holds)
    state_v1 = {"params": p, "opt": o, "arena": arena.convert_ring(
        jax.device_get(ar), 1)}
    assert int(state_v1["arena"].head) == 0
    ckpt.save(str(tmp_path), 3, state_v1, extra={"step": 3})

    # restore into a v2 template: migration splits + permutes the ring
    template = {"params": p, "opt": o,
                "arena": arena.init_arena(layout, tau, n_pods,
                                          compression)}
    restored, extra = ckpt.restore(str(tmp_path), template)
    assert extra["step"] == 3
    r_ar = restored["arena"]
    assert arena.ring_version(r_ar) == 2 and r_ar.phase == 0

    # continue both runs; they must agree bit for bit
    rp, ro = restored["params"], restored["opt"]
    for t in range(4, 9):
        p, o, ar = step(p, o, ar, *batches(t))
        rp, ro, r_ar = step(rp, ro, r_ar, *batches(t))
        for a_leaf, b_leaf in zip(jax.tree.leaves(p), jax.tree.leaves(rp)):
            np.testing.assert_array_equal(np.asarray(a_leaf),
                                          np.asarray(b_leaf))
        np.testing.assert_array_equal(np.asarray(o.z), np.asarray(ro.z))


_SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import MeshConfig
    from repro.core import arena
    from repro.dist.context import sharding_profile

    mesh_cfg = MeshConfig(n_pods=2, data=2, model=2)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    params = {"a": jnp.zeros((7,)), "b": jnp.zeros((300, 5)),
              "c": jnp.zeros((257,))}
    layout = arena.make_layout(params)
    n_pods, tau = 2, 2

    def grads_at(t):
        ks = jax.random.split(jax.random.PRNGKey(t), 3)
        return {k: jax.random.normal(kk, (n_pods,) + params[k].shape)
                for k, kk in zip(sorted(params), ks)}

    ar_s = arena.init_arena(layout, tau, n_pods, "int8")
    ar_r = arena.init_arena(layout, tau, n_pods, "int8")
    for t in range(5):
        g = grads_at(t)
        counts = jnp.full((n_pods,), 4.0)
        # shard_map'd Pallas kernel (interpret) on the multi-pod mesh
        with mesh, sharding_profile(mesh_cfg):
            gs_s, c_s, ar_s = arena.push_pop(
                layout, ar_s, g, counts, "int8",
                impl="pallas_sharded", interpret=True)
        # off-mesh single-program kernel: identical quantize/dequantize
        # arithmetic, deterministic pod fold — only the reduction's
        # placement (all-gather + local fold vs materialized popped)
        # differs, so everything must agree BIT for bit. (kernel vs
        # XLA-ref drift is covered, with tolerances, by
        # test_push_pop_pallas_branch_matches_ref.)
        gs_r, c_r, ar_r = arena.push_pop(layout, ar_r, g, counts,
                                         "int8", impl="pallas",
                                         interpret=True)
        np.testing.assert_array_equal(np.asarray(gs_s), np.asarray(gs_r))
        assert float(c_s) == float(c_r)
        for s_slot, r_slot in zip(ar_s.ring, ar_r.ring):
            np.testing.assert_array_equal(np.asarray(s_slot),
                                          np.asarray(r_slot))
        for s_sc, r_sc in zip(ar_s.scales, ar_r.scales):
            np.testing.assert_array_equal(np.asarray(s_sc),
                                          np.asarray(r_sc))
        np.testing.assert_array_equal(np.asarray(ar_s.residual),
                                      np.asarray(ar_r.residual))
    print("SHARD_MAP_OK")
""")


@pytest.mark.slow
def test_shard_map_kernel_matches_off_mesh_fold():
    """The shard_map'd delay-ring kernel (8 virtual CPU devices, pod=2
    mesh, interpret-mode Pallas, int8 payload all-gathered compressed)
    produces bit-identical popped sums and ring state to the off-mesh
    deterministic fold. Subprocess: the forced device count must not
    leak into this test process."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHARD_MAP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "SHARD_MAP_OK" in out.stdout


def _collect_primitives(jaxpr, acc):
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for u in vs:
                inner = getattr(u, "jaxpr", None)
                if inner is not None:
                    _collect_primitives(inner, acc)
                elif hasattr(u, "eqns"):
                    _collect_primitives(u, acc)
    return acc


@pytest.mark.parametrize("compression", ["none", "int8"])
def test_no_per_step_concatenate(compression):
    """The fused arena master update never concatenates the tree: the
    one-time flatten happened at init (layout build), and the per-step
    gradient lands via static-offset update-slices."""
    tau, n_pods = 2, 2
    rc = _rc(tau, compression)
    params = _params(jax.random.PRNGKey(0))
    layout = arena.make_layout(params)
    opt_a = make_arena_optimizer(rc, layout)

    def master(p, o, a, grads, counts):
        return ambdg.arena_master_update(layout, opt_a, p, o, a, grads,
                                         counts, compression)

    jaxpr = jax.make_jaxpr(master)(
        params, opt_a.init(), arena.init_arena(layout, tau, n_pods,
                                               compression),
        _pod_grads(jax.random.PRNGKey(1), n_pods), jnp.ones((n_pods,)))
    prims = _collect_primitives(jaxpr.jaxpr, set())
    assert "concatenate" not in prims, sorted(prims)
    # the per-leaf pytree path, by contrast, IS allowed to concatenate;
    # sanity-check the detector catches one where we expect it
    probe = jax.make_jaxpr(
        lambda t: jnp.concatenate([x.reshape(-1) for x in
                                   jax.tree.leaves(t)]))(params)
    assert "concatenate" in _collect_primitives(probe.jaxpr, set())


@pytest.mark.parametrize("compression", ["none", "int8"])
def test_checkpoint_roundtrip_arena_state(tmp_path, compression):
    """GradArena (incl. int8 ring + per-row scales + residual) threads
    through save/restore bit-exactly."""
    from repro.train import checkpoint as ckpt
    import repro.configs as C
    from repro.core import make_train_step
    from repro.models import build_model

    cfg = C.get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    rc = RunConfig(model=cfg,
                   shape=dataclasses.replace(TRAIN_4K, seq_len=32,
                                             global_batch=8),
                   mesh=MeshConfig(n_pods=1, data=1, model=1),
                   ambdg=AmbdgConfig(tau=2, n_microbatches=2, b_bar=8.0,
                                     smoothness_L=8.0,
                                     pod_compression=compression))
    init_state, train_step = make_train_step(model, rc)
    state = init_state(jax.random.PRNGKey(0))
    state, _ = jax.jit(train_step)(state, model.dummy_batch(8, 32))
    assert state.arena is not None and state.buffer is None
    if compression == "int8":
        assert all(s.dtype == jnp.int8 for s in state.arena.ring)
    ckpt.save(str(tmp_path), 1, state, extra={"step": 1})
    restored, _ = ckpt.restore(str(tmp_path), state)
    for a_leaf, b_leaf in zip(jax.tree.leaves(state),
                              jax.tree.leaves(restored)):
        assert a_leaf.dtype == b_leaf.dtype
        np.testing.assert_array_equal(np.asarray(a_leaf), np.asarray(b_leaf))
