"""Property suite for the adaptive minibatch schedules
(``core.batch_schedule``) and their consumers.

Three layers:

  * the CONTROLLERS: registry + config validation, deterministic
    sequences, bounds clipping, checkpointable state (the same
    restart-exactness contract the delay and worker processes keep);
  * the CONSUMERS: the device step takes ``batch["b_sched"]`` into
    the dual-averaging alpha (and refuses to run without it), the
    simulator splits targets across alive workers and raises on
    padding-bound overflow, the host loop caps the anytime weights
    and resumes restart-exact through ``train/checkpoint.py``;
  * the CONVERGENCE PROPERTY the subsystem exists for: on the paper's
    linreg problem, the adadamp controller reaches a target Err(t)
    with fewer total samples than EVERY fixed batch size in the sweep
    — small cheap batches through the bias phase, growth only once
    the loss plateaus (all runs seeded, so the margin is exact).

``REPRO_TEST_BATCH_SCHEDULE`` (comma-separated schedule names)
narrows the parametrized sweeps — the CI matrix leg sets it.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import (AmbdgConfig, BatchScheduleConfig, LINREG,
                                MeshConfig, ModelConfig, RunConfig,
                                TRAIN_4K)
from repro.core import make_train_step
from repro.core.batch_schedule import (BATCH_SCHEDULES,
                                       make_batch_schedule,
                                       resolve_targets)
from repro.data.pipeline import apply_batch_target
from repro.data.timing import ShiftedExponential
from repro.models import build_model
from repro.sim import SimProblem, simulate_anytime, simulate_kbatch
from repro.train import checkpoint as ckpt
from repro.train.loop import LoopConfig, train

ALL_SCHEDULES = ("fixed", "linear", "adadamp", "delay_aware")
SCHEDULES = tuple(
    s for s in os.environ.get("REPRO_TEST_BATCH_SCHEDULE",
                              ",".join(ALL_SCHEDULES)).split(",") if s)
B_BAR = 64.0
TAU = 4


def _cfg(schedule: str, **kw) -> BatchScheduleConfig:
    return BatchScheduleConfig(schedule=schedule, **kw)


def _make(schedule: str, **kw):
    return make_batch_schedule(_cfg(schedule, **kw), B_BAR, TAU)


def _drive(bs, n, *, losses=None, taus=None):
    """n targets with per-step feedback (the consumers' loop shape:
    target -> step -> observe)."""
    out = []
    for i in range(n):
        out.append(bs.target())
        bs.observe(loss=None if losses is None else losses[i],
                   tau_obs=None if taus is None else taus[i])
    return np.asarray(out, np.int64)


# ---------------------------------------------------------------------------
# the controllers
# ---------------------------------------------------------------------------
def test_registry_and_validation():
    assert set(BATCH_SCHEDULES) == set(ALL_SCHEDULES)
    with pytest.raises(ValueError, match="unknown batch schedule"):
        _make("cosine")
    with pytest.raises(ValueError, match="b0 must be >= 1"):
        make_batch_schedule(_cfg("fixed"), 0.0, TAU)   # b_bar resolves to 0
    with pytest.raises(ValueError, match="b_min must be >= 1"):
        _make("fixed", b_min=0)
    with pytest.raises(ValueError, match="b_min <= b0 <= b_cap"):
        _make("fixed", b0=32, b_cap=16)
    with pytest.raises(ValueError, match="growth_rate"):
        _make("linear", growth_rate=-1.0)
    with pytest.raises(ValueError, match="growth_factor"):
        _make("adadamp", growth_factor=1.0)
    with pytest.raises(ValueError, match="ema"):
        _make("delay_aware", ema=0.0)
    # b0=0 resolves to round(b_bar); b_cap=0 to 16*b0
    assert resolve_targets(_cfg("fixed"), B_BAR) == (64, 1, 1024)
    assert resolve_targets(_cfg("fixed", b0=10, b_cap=40), B_BAR) \
        == (10, 1, 40)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_deterministic_and_bounded(schedule):
    n = 64
    losses = np.geomspace(1.0, 1e-4, n)           # sharply improving
    taus = 1.0 + 7.0 * (np.arange(n) % 3)         # wobbling staleness
    kw = dict(b0=8, b_cap=96, seed=3)
    a = _drive(_make(schedule, **kw), n, losses=losses, taus=taus)
    b = _drive(_make(schedule, **kw), n, losses=losses, taus=taus)
    np.testing.assert_array_equal(a, b)           # same config: exact
    assert (a >= 1).all() and (a <= 96).all()     # clipped to bounds
    if schedule == "fixed":
        assert (a == 8).all()
    else:
        assert len(np.unique(a)) > 1              # genuinely adaptive


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_state_dict_resumes_mid_sequence(schedule):
    n0, n1 = 23, 41
    losses = np.geomspace(2.0, 1e-3, n0 + n1)
    taus = np.abs(np.sin(np.arange(n0 + n1))) * 8.0
    bs = _make(schedule, b0=8, b_cap=128, seed=5)
    _drive(bs, n0, losses=losses[:n0], taus=taus[:n0])
    saved = bs.state_dict()
    rest = _drive(bs, n1, losses=losses[n0:], taus=taus[n0:])
    # a fresh controller under a DIFFERENT seed, restored from the
    # snapshot, must emit the exact remaining sequence (the loop's
    # restart contract)
    bs2 = _make(schedule, b0=8, b_cap=128, seed=999)
    bs2.load_state_dict(saved)
    np.testing.assert_array_equal(
        rest, _drive(bs2, n1, losses=losses[n0:], taus=taus[n0:]))


def test_adadamp_monotone_with_capped_growth():
    bs = _make("adadamp", b0=8, b_cap=4096, growth_factor=1.5)
    assert bs.target() == 8                       # no signal yet: base
    bs.observe(loss=100.0)                        # loss(1)
    prev = bs.target()
    # a 1e6x loss collapse wants b ~ b0 * 1e6 immediately; the per-step
    # growth cap meters it out at <= growth_factor per step, and the
    # sequence is monotone non-decreasing even when the loss SPIKES up
    for loss in [1e-2, 1e-4, 50.0, 1e-4, 1e-4, 1e-4]:
        bs.observe(loss=loss)
        cur = bs.target()
        assert cur >= prev                        # monotone
        assert cur <= int(prev * 1.5) + 1         # growth metered
        prev = cur
    # garbage feedback is ignored, never crashes the controller
    bs.observe(loss=float("nan"))
    bs.observe(loss=-1.0)
    assert bs.target() >= prev


def test_linear_ramp_is_exact():
    bs = _make("linear", b0=10, b_cap=1000, growth_rate=2.5)
    want = [10 + int(np.floor(2.5 * t)) for t in range(20)]
    np.testing.assert_array_equal(bs.sequence(20), want)


def test_delay_aware_tracks_observed_staleness():
    bs = _make("delay_aware", b0=40, b_cap=4096, ema=0.5)
    assert bs.target() == 40        # ema_tau starts at the nominal tau
    for _ in range(20):
        bs.observe(tau_obs=19.0)    # persistent stragglers
    high = bs.target()
    assert high == pytest.approx(40 * 20 / (1 + TAU), abs=2)
    for _ in range(40):
        bs.observe(tau_obs=0.0)     # fresh gradients only
    low = bs.target()
    assert low < high and low == pytest.approx(40 / (1 + TAU), abs=2)


# ---------------------------------------------------------------------------
# the consumers
# ---------------------------------------------------------------------------
def _linreg_cfg(dim=16):
    return ModelConfig(name="linreg", family=LINREG, n_layers=0,
                       d_model=0, n_heads=0, n_kv_heads=0, d_ff=0,
                       vocab_size=0, linreg_dim=dim)


def _sim_common(total_time=60.0, dim=16, L=8.0):
    return dict(t_p=2.5, t_c=10.0, total_time=total_time,
                timing=ShiftedExponential(lam=2 / 3, xi=1.0, b=60),
                opt_cfg=AmbdgConfig(t_p=2.5, t_c=10.0, tau=TAU,
                                    b_bar=B_BAR, smoothness_L=L,
                                    proximal="l2_ball",
                                    radius_C=float(1.05 * np.sqrt(dim))),
                scheme="ambdg", rng_seed=11)


def test_strategy_surface_fixed_is_none():
    """Every strategy returns no controller under the default "fixed"
    schedule (consumers route to the exact pre-existing path) and a
    seeded controller otherwise."""
    from repro.api import available_strategies, build
    model = build_model(_linreg_cfg())
    for name in available_strategies():
        rc = RunConfig(model=_linreg_cfg(),
                       shape=dataclasses.replace(TRAIN_4K, seq_len=0,
                                                 global_batch=16),
                       mesh=MeshConfig(n_pods=1, data=1, model=1),
                       ambdg=AmbdgConfig(tau=2, b_bar=16.0),
                       strategy=name)
        assert build(model, rc).batch_schedule() is None
        rc2 = rc.replace(batch_schedule=_cfg("linear", b0=8))
        bs = build(model, rc2).batch_schedule()
        assert bs is not None and bs.target() == 8


def test_sim_anytime_splits_target_and_raises_on_overflow():
    common = _sim_common(total_time=30.0)
    problem = SimProblem(_linreg_cfg(), n_workers=3, seed=7, b_max=16)
    bs = _make("linear", b0=10, b_cap=64, growth_rate=0.0)
    tr = simulate_anytime(problem, batch_schedule=bs, **common)
    # the target replaces the timing draw: 10 split over 3 alive
    # workers = 4+3+3, every update
    assert tr.targets == [10] * len(tr.times)
    assert all(m == 10.0 for m in tr.minibatches)
    assert tr.clamps == [0] * len(tr.times)       # strict mode: no clamps
    # a share above b_max raises instead of silently capping (alpha
    # would otherwise assume a b(t) that never ran)
    problem = SimProblem(_linreg_cfg(), n_workers=3, seed=7, b_max=16)
    bs = _make("linear", b0=60, b_cap=600, growth_rate=0.0)
    with pytest.raises(ValueError, match="overflows the padding bound"):
        simulate_anytime(problem, batch_schedule=bs, **common)
    # ... while the NON-schedule timing path still counts clamps
    problem = SimProblem(_linreg_cfg(), n_workers=3, seed=7, b_max=4)
    tr = simulate_anytime(problem, **common)
    assert sum(tr.clamps) > 0 and problem.clamp_events == sum(tr.clamps)


def test_sim_kbatch_draws_per_job_targets():
    common = _sim_common(total_time=40.0)
    common.pop("scheme")
    problem = SimProblem(_linreg_cfg(), n_workers=3, seed=7, b_max=256)
    bs = _make("linear", b0=16, b_cap=256, growth_rate=2.0)
    tr = simulate_kbatch(problem, b_per_msg=60, K=2, **common)
    problem = SimProblem(_linreg_cfg(), n_workers=3, seed=7, b_max=256)
    tr2 = simulate_kbatch(problem, b_per_msg=60, K=2,
                          batch_schedule=bs, **common)
    # per-job targets drawn in deterministic heap order: the ramp
    assert tr2.targets[:4] == [16, 18, 20, 22]
    assert len(tr2.targets) >= len(tr2.times) * 2  # >= K jobs per update
    # adaptive-b alpha: the update sequence genuinely differs from the
    # constant-b run (same seeds, same event algebra)
    assert tr2.errors != tr.errors


def test_apply_batch_target_caps_anytime_weights():
    # 3 workers x 4 slots; workers drew b = [4, 2, 0]
    w = np.zeros((3, 4), np.float32)
    w[0, :4] = 1.0
    w[1, :2] = 1.0
    out = apply_batch_target(w.reshape(-1), 7, 3, 4).reshape(3, 4)
    # target 7 -> shares [3, 2, 2]; worker 0 capped at 3, worker 1
    # keeps its drawn 2 (the schedule can CAP the anytime draw, never
    # grant samples a worker did not finish), worker 2 stays empty
    np.testing.assert_array_equal(out.sum(1), [3.0, 2.0, 0.0])
    # a huge target degenerates to the drawn weights untouched
    out = apply_batch_target(w.reshape(-1), 1000, 3, 4).reshape(3, 4)
    np.testing.assert_array_equal(out, w)


def _device_rc(schedule_cfg):
    cfg = C.get_smoke_config("amb-linreg")
    return RunConfig(model=cfg,
                     shape=dataclasses.replace(TRAIN_4K, seq_len=0,
                                               global_batch=16),
                     mesh=MeshConfig(n_pods=1, data=1, model=1),
                     ambdg=AmbdgConfig(tau=2, n_microbatches=2,
                                       b_bar=16.0, smoothness_L=8.0),
                     batch_schedule=schedule_cfg)


def test_device_alpha_consumes_b_sched():
    """The lowered step provably runs the schedule's alpha: shipping
    b_sched == b_bar reproduces the static path bit-identically, a
    different b_sched moves the parameters, and a scheduled step
    without the scalar refuses to run."""
    model = build_model(C.get_smoke_config("amb-linreg"))
    rc_fix = _device_rc(BatchScheduleConfig())
    rc_sch = _device_rc(_cfg("linear", b0=16))
    init_fix, step_fix = make_train_step(model, rc_fix)
    init_sch, step_sch = make_train_step(model, rc_sch)
    batch = model.dummy_batch(16, 0)
    batch["weights"] = np.ones((16,), np.float32)

    def roll(init, step, extra):
        # past the tau-deep ring so updates apply real gradients
        state = init(jax.random.PRNGKey(0))
        fn = jax.jit(step)
        for _ in range(4):
            state, _ = fn(state, dict(batch, **extra))
        return state

    s_fix = roll(init_fix, step_fix, {})
    s_same = roll(init_sch, step_sch, {"b_sched": jnp.float32(16.0)})
    for a, b in zip(jax.tree.leaves(s_fix.params),
                    jax.tree.leaves(s_same.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    s_diff = roll(init_sch, step_sch, {"b_sched": jnp.float32(64.0)})
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(s_fix.params),
                               jax.tree.leaves(s_diff.params)))

    with pytest.raises(ValueError, match="b_sched"):
        jax.jit(step_sch)(init_sch(jax.random.PRNGKey(0)), dict(batch))


def test_controller_checkpoint_roundtrip(tmp_path):
    """Controller state rides the checkpoint extra dict through
    train/checkpoint.py exactly (numpy rng state and EMA trackers
    survive serialization)."""
    model = build_model(C.get_smoke_config("amb-linreg"))
    rc = _device_rc(BatchScheduleConfig())
    init_state, _ = make_train_step(model, rc)
    state = init_state(jax.random.PRNGKey(0))
    losses = np.geomspace(5.0, 1e-2, 30)
    for schedule in SCHEDULES:
        bs = _make(schedule, b0=8, b_cap=256, seed=13)
        _drive(bs, 17, losses=losses[:17], taus=losses[:17] * 2)
        ckpt.save(str(tmp_path / schedule), 17, state,
                  extra={"step": 17, "batch_schedule": bs.state_dict()})
        _, extra = ckpt.restore(str(tmp_path / schedule), state)
        bs2 = _make(schedule, b0=8, b_cap=256, seed=777)
        bs2.load_state_dict(extra["batch_schedule"])
        np.testing.assert_array_equal(
            _drive(bs, 13, losses=losses[17:], taus=losses[17:] * 2),
            _drive(bs2, 13, losses=losses[17:], taus=losses[17:] * 2))


def test_loop_resume_is_exact_with_schedule(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restart + 3 with the
    adadamp controller driving the host loop: identical parameters
    (the controller's counters and EMA trackers restore with the
    pipeline cursor — a drifted b(t) would move alpha and diverge)."""
    model = build_model(C.get_smoke_config("amb-linreg"))
    rc = _device_rc(_cfg("adadamp", b0=8, b_cap=64, growth_factor=1.5))
    loop_a = LoopConfig(n_steps=6, ckpt_dir=None, n_workers=2,
                        samples_per_worker=8, use_timing_model=True,
                        log_every=100)
    out_a = train(model, rc, loop_a)

    d = str(tmp_path / "resume")
    loop_b = LoopConfig(n_steps=3, ckpt_dir=d, ckpt_every=3, n_workers=2,
                        samples_per_worker=8, use_timing_model=True,
                        log_every=100)
    train(model, rc, loop_b)
    out_c = train(model, rc, dataclasses.replace(loop_b, n_steps=6))

    for a, b in zip(jax.tree.leaves(out_a["state"].params),
                    jax.tree.leaves(out_c["state"].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# the convergence property
# ---------------------------------------------------------------------------
FIXED_SWEEP = (64, 256, 1024)
TARGET_ERR = 5e-6


def _samples_to_target(trace, eps=TARGET_ERR):
    cum = np.cumsum(trace.minibatches)
    hit = np.nonzero(np.asarray(trace.errors) <= eps)[0]
    return int(cum[hit[0]]) if len(hit) else None


def test_adadamp_beats_every_fixed_batch_on_samples_to_target():
    """The reason the subsystem exists: on the paper's linreg problem
    (stable step-size regime, L=8), adadamp rides small cheap batches
    through the bias phase and grows only as the loss flattens —
    reaching Err(t) <= 5e-6 with fewer TOTAL samples than every fixed
    batch size in the sweep (b=64 never gets there before its noise
    floor; b=256/1024 burn large batches on the bias phase). All runs
    are seeded end to end, so the ordering is exact, not statistical
    (BENCH_batch_schedule.json tracks the same sweep across PRs)."""
    common = _sim_common(total_time=750.0)

    def run(bs_cfg):
        problem = SimProblem(_linreg_cfg(), n_workers=4, seed=7,
                             b_max=512)
        return simulate_anytime(
            problem, batch_schedule=make_batch_schedule(bs_cfg, B_BAR,
                                                        TAU), **common)

    ada = _samples_to_target(run(_cfg("adadamp", b0=8, b_cap=1024,
                                      growth_factor=1.5, ema=0.5)))
    assert ada is not None
    for b0 in FIXED_SWEEP:
        fixed = _samples_to_target(run(_cfg("fixed", b0=b0,
                                            b_cap=4096)))
        assert fixed is None or ada < fixed, (b0, ada, fixed)
