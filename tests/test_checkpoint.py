"""Checkpoint/restart: atomic save, retention, exact resume (including
the delay buffer, so staleness semantics survive restart)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import AmbdgConfig, MeshConfig, RunConfig, TRAIN_4K
from repro.core import make_train_step
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train.loop import LoopConfig, train


def _setup(tmp_path=None):
    cfg = C.get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    rc = RunConfig(model=cfg,
                   shape=dataclasses.replace(TRAIN_4K, seq_len=32,
                                             global_batch=8),
                   mesh=MeshConfig(n_pods=1, data=1, model=1),
                   ambdg=AmbdgConfig(tau=2, n_microbatches=2, b_bar=8.0,
                                     smoothness_L=8.0))
    return model, rc


def test_save_restore_roundtrip(tmp_path):
    model, rc = _setup()
    init_state, train_step = make_train_step(model, rc)
    state = init_state(jax.random.PRNGKey(0))
    batch = model.dummy_batch(8, 32)
    state, _ = jax.jit(train_step)(state, batch)

    path = ckpt.save(str(tmp_path), 1, state, extra={"step": 1})
    assert os.path.isdir(path)
    restored, extra = ckpt.restore(str(tmp_path), state)
    assert extra["step"] == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_includes_delay_buffer(tmp_path):
    """The in-flight delayed gradients are part of the checkpoint: after
    restore, the next update applies exactly what it would have."""
    model, rc = _setup()
    init_state, train_step = make_train_step(model, rc)
    step = jax.jit(train_step)
    state = init_state(jax.random.PRNGKey(0))
    batches = [model.dummy_batch(8, 32, key=jax.random.PRNGKey(i))
               for i in range(4)]
    state, _ = step(state, batches[0])
    state, _ = step(state, batches[1])
    ckpt.save(str(tmp_path), 2, state, extra={"step": 2})

    cont_state, _ = step(state, batches[2])
    restored_state, _extra = ckpt.restore(str(tmp_path), state)
    resumed_state, _ = step(restored_state, batches[2])
    for a, b in zip(jax.tree.leaves(cont_state.params),
                    jax.tree.leaves(resumed_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_retention(tmp_path):
    model, rc = _setup()
    init_state, _ = make_train_step(model, rc)
    state = init_state(jax.random.PRNGKey(0))
    for s in range(1, 6):
        ckpt.save(str(tmp_path), s, state, keep=3)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_loop_resume_is_exact(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restart + 3: identical
    parameters (pipeline cursor + buffers restored)."""
    model, rc = _setup()
    loop_a = LoopConfig(n_steps=6, ckpt_dir=None, n_workers=2,
                        samples_per_worker=4, use_timing_model=True,
                        log_every=100)
    out_a = train(model, rc, loop_a)

    d = str(tmp_path / "resume")
    loop_b = LoopConfig(n_steps=3, ckpt_dir=d, ckpt_every=3, n_workers=2,
                        samples_per_worker=4, use_timing_model=True,
                        log_every=100)
    train(model, rc, loop_b)
    loop_c = dataclasses.replace(loop_b, n_steps=6)
    out_c = train(model, rc, loop_c)   # restores at step 3, runs to 6

    for a, b in zip(jax.tree.leaves(out_a["state"].params),
                    jax.tree.leaves(out_c["state"].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
