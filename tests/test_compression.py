"""Gradient compression operators: int8 round-trip, top-k + error
feedback unbiasedness."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.optim.compression import (FeedbackState, compress_with_feedback,
                                     dequantize_int8, init_feedback,
                                     quantize_int8, topk_densify,
                                     topk_sparsify)


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10**6))
def test_int8_roundtrip_bound(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)
    q, s = quantize_int8(g)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - g))
    assert float(err) <= float(s) / 2 + 1e-7


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    vals, idx = topk_sparsify(g, 0.4)     # k = 2
    dense = topk_densify(vals, idx, g.shape)
    np.testing.assert_allclose(np.asarray(dense),
                               [0, -5.0, 0, 3.0, 0])


def test_error_feedback_accumulates_dropped_mass():
    """Sum of compressed streams tracks the sum of true gradients."""
    rng = np.random.default_rng(0)
    grads = {"w": jnp.zeros((64,))}
    state = init_feedback(grads)
    true_sum = np.zeros(64)
    sent_sum = np.zeros(64)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
        true_sum += np.asarray(g["w"])
        c, state = compress_with_feedback(state, g, frac=0.25)
        sent_sum += np.asarray(c["w"])
    # residual bounds the gap; without feedback the gap would be ~75%
    gap = np.abs(true_sum - sent_sum).max()
    res = np.abs(np.asarray(state.residual["w"])).max()
    assert gap <= res + 1e-5
    rel = np.linalg.norm(true_sum - sent_sum) / np.linalg.norm(true_sum)
    assert rel < 0.5
