"""Decentralized AMB-DG (paper Sec. V): gossip matrices, eq. (24) round
bound, consensus convergence — and the int8-compressed gossip path
(per-row bf16 scales + error feedback): residual telescoping, r=0
identity, payload accounting."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import consensus
from repro.optim.compression import (dequantize_int8_rows,
                                     quantize_int8_rows)


@pytest.mark.parametrize("topology,n", [("ring", 8), ("complete", 6),
                                        ("torus", 16)])
def test_matrices_doubly_stochastic(topology, n):
    Q = consensus.gossip_matrix(topology, n)
    assert np.allclose(Q.sum(0), 1) and np.allclose(Q.sum(1), 1)
    assert (Q >= 0).all()
    assert consensus.lambda2(Q) < 1.0          # connected


def test_complete_graph_one_round():
    Q = consensus.gossip_matrix("complete", 5)
    v = jnp.asarray(np.random.default_rng(0).standard_normal((5, 3)))
    out = consensus.run_consensus(v, Q, 1)
    np.testing.assert_allclose(np.asarray(out),
                               np.tile(np.asarray(v).mean(0), (5, 1)),
                               atol=1e-6)


def test_consensus_error_decays_at_spectral_rate():
    Q = consensus.gossip_matrix("ring", 8)
    lam = consensus.lambda2(Q)
    v = jnp.asarray(np.random.default_rng(1).standard_normal((8, 4)))
    errs = [float(consensus.consensus_error(
        consensus.run_consensus(v, Q, r))) for r in (0, 5, 10, 20)]
    assert errs[1] < errs[0] and errs[2] < errs[1] and errs[3] < errs[2]
    # rate ~ lam^r (allow slack)
    assert errs[2] <= errs[0] * lam ** 10 * 10


def test_min_rounds_eq24():
    """r >= log(2 sqrt(n)(1 + 2J/delta)) / (1 - lambda2)."""
    r = consensus.min_rounds(delta=0.1, n=16, J=1.0, lam2=0.5)
    expect = int(np.ceil(np.log(2 * 4 * (1 + 20)) / 0.5))
    assert r == expect
    with pytest.raises(ValueError):
        consensus.min_rounds(0.1, 4, 1.0, 1.0)   # disconnected


def test_min_rounds_achieves_delta():
    """Running the bound's round count achieves consensus error <= delta
    for messages with norm <= J (the paper's usage)."""
    n = 8
    Q = consensus.gossip_matrix("ring", n)
    lam = consensus.lambda2(Q)
    J, delta = 1.0, 0.05
    r = consensus.min_rounds(delta, n, J, lam)
    rng = np.random.default_rng(2)
    v = rng.standard_normal((n, 16))
    v = v / np.linalg.norm(v, axis=1, keepdims=True) * J   # ||m_i|| = J
    out = consensus.run_consensus(jnp.asarray(v), Q, r)
    assert float(consensus.consensus_error(out)) <= delta


@pytest.mark.parametrize("topology,n", [("ring", 8), ("ring", 2),
                                        ("torus", 4), ("torus", 9),
                                        ("complete", 5)])
def test_stencil_fold_matches_matrix(topology, n):
    """One ordered stencil-fold round (the shared dense/ppermute body)
    applies exactly the gossip matrix: the fold weights sum to Q, and
    a fold round equals Q @ v at float tolerance."""
    np.testing.assert_allclose(consensus._stencil_matrix(topology, n),
                               consensus.gossip_matrix(topology, n),
                               atol=1e-12)
    v = jnp.asarray(np.random.default_rng(3)
                    .standard_normal((n, 6)).astype(np.float32))
    out = consensus.gossip_round_dense(v, topology)
    np.testing.assert_allclose(
        np.asarray(out), consensus.gossip_matrix(topology, n) @
        np.asarray(v), rtol=1e-5, atol=1e-6)


def test_stencil_duplicate_terms_merged():
    """Coincident neighbours (torus side=2, ring n=2) merge into one
    term — duplicates would let XLA reassociate the fold differently
    across program variants (see consensus.topology_stencil)."""
    for topology, n in (("torus", 4), ("ring", 2)):
        terms = consensus.topology_stencil(topology, n)
        seen = [tuple(nbr) for nbr, _ in terms]
        assert len(seen) == len(set(seen)), (topology, n)


# ---------------------------------------------------------------------------
# r=0 must be the identity; eq. (24) must never disable the exchange
# ---------------------------------------------------------------------------
def test_zero_rounds_is_identity():
    """``run_consensus`` / ``run_consensus_fold`` /
    ``run_consensus_fold_int8`` with r=0 leave values (and the
    error-feedback residual) untouched, bit for bit — zero rounds
    exchanges nothing, so it must also quantize nothing."""
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((8, 3, 128)).astype(np.float32))
    res = jnp.asarray(rng.standard_normal((8, 3, 128)).astype(np.float32))
    Q = consensus.gossip_matrix("ring", 8)
    out = consensus.run_consensus(v.reshape(8, -1), Q, 0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(v.reshape(8, -1)))
    out = consensus.run_consensus_fold(v, "ring", 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(v))
    out, res_out = consensus.run_consensus_fold_int8(v, res, "ring", 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(res_out), np.asarray(res))


def test_min_rounds_never_zero():
    """eq. (24) lower-bounds the rounds needed to REACH delta; a huge
    delta (2J/delta underflowing to 0) must still schedule at least
    one round — r=0 would silently disable the gossip exchange."""
    for delta in (0.05, 1.0, 1e9, float("inf")):
        for topology, n in (("ring", 8), ("complete", 4), ("torus", 16)):
            lam = consensus.lambda2(consensus.gossip_matrix(topology, n))
            assert consensus.min_rounds(delta, n, 1.0, lam) >= 1, (
                delta, topology)
    # and the bound still grows as delta tightens
    lam = consensus.lambda2(consensus.gossip_matrix("ring", 8))
    assert (consensus.min_rounds(1e-3, 8, 1.0, lam)
            > consensus.min_rounds(0.5, 8, 1.0, lam))


# ---------------------------------------------------------------------------
# int8-compressed gossip: error feedback telescopes; payload accounting
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topology,n", [("ring", 8), ("torus", 16),
                                        ("complete", 8)])
@pytest.mark.parametrize("r", [1, 3, 8])
def test_compressed_residual_telescopes(topology, n, r):
    """Error feedback means the quantization error cannot accumulate:
    per worker, the sum of the DEQUANTIZED messages actually sent over
    r rounds plus the final residual equals the sum of the true
    (uncompressed) per-round messages, to f32 tolerance. (Exact in
    real arithmetic: each round d_k + res_{k+1} = v_k + res_k.)"""
    rng = np.random.default_rng(42)
    v = jnp.asarray(rng.standard_normal((n, 6, 128)).astype(np.float32))
    res = jnp.zeros_like(v)
    sent_sum = np.zeros(v.shape, np.float64)
    true_sum = np.zeros(v.shape, np.float64)
    for _ in range(r):
        # what this round puts on the wire (the round's own arithmetic)
        fed = v + res
        q, s = quantize_int8_rows(fed, scale_dtype=jnp.bfloat16)
        d = dequantize_int8_rows(q, s)
        true_sum += np.asarray(v, np.float64)
        sent_sum += np.asarray(d, np.float64)
        v, res = consensus.gossip_round_dense_int8(v, res, topology)
    np.testing.assert_allclose(sent_sum + np.asarray(res, np.float64),
                               true_sum, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("topology,n", [("ring", 8), ("torus", 4),
                                        ("complete", 5)])
def test_compressed_round_tracks_gossip_matrix(topology, n):
    """One compressed fold round equals Q @ dequant(quant(v)) up to
    the weighted-scale bf16 rounding — i.e. the compressed path still
    applies the doubly-stochastic matrix, to the values on the wire."""
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.standard_normal((n, 4, 128)).astype(np.float32))
    res = jnp.zeros_like(v)
    out, _ = consensus.gossip_round_dense_int8(v, res, topology)
    q, s = quantize_int8_rows(v, scale_dtype=jnp.bfloat16)
    d = np.asarray(dequantize_int8_rows(q, s), np.float64)
    Q = consensus.gossip_matrix(topology, n)
    expect = np.einsum("ij,jrl->irl", Q, d)
    np.testing.assert_allclose(np.asarray(out, np.float64), expect,
                               rtol=5e-3, atol=5e-3)


def test_compressed_consensus_reaches_delta():
    """The eq.-(24) round count still achieves the consensus-error
    target under int8 compression (the error-feedback residual keeps
    the quantization noise from swamping delta)."""
    n, J, delta = 8, 1.0, 0.05
    Q = consensus.gossip_matrix("ring", n)
    r = consensus.min_rounds(delta, n, J, consensus.lambda2(Q))
    rng = np.random.default_rng(2)
    v = rng.standard_normal((n, 2, 128)).astype(np.float32)
    v = v / np.linalg.norm(v.reshape(n, -1), axis=1)[:, None, None] * J
    out, _ = consensus.run_consensus_fold_int8(
        jnp.asarray(v), jnp.zeros_like(jnp.asarray(v)), "ring", r)
    err = float(consensus.consensus_error(
        jnp.asarray(out).reshape(n, -1)))
    assert err <= 2 * delta, err


def test_payload_bytes_per_round():
    """int8 + bf16 per-row scales cut the per-round wire payload
    ~3.9x on every topology (>= the 3.5x the benchmark pins)."""
    rows = 256
    for topology, n, n_nonself in (("ring", 8, 2), ("torus", 16, 4),
                                   ("complete", 8, 7)):
        dense_b = consensus.payload_bytes_per_round(topology, n, rows)
        int8_b = consensus.payload_bytes_per_round(
            topology, n, rows, compression="int8")
        assert dense_b == n_nonself * rows * 128 * 4
        assert int8_b == n_nonself * (rows * 128 + rows * 2)
        assert dense_b / int8_b >= 3.5


def test_compressed_scales_are_bf16_exact_products():
    """The invariant the cross-program bit-exactness rests on: every
    dequantization product q * scale (and q * bf16(w*scale)) is
    exactly representable in f32, so FMA contraction cannot move a
    bit. Verified by exhaustive q in [-127, 127] against exact
    float64 products for the scales the quantizer emits."""
    rng = np.random.default_rng(9)
    g = jnp.asarray(rng.standard_normal((32, 128)).astype(np.float32))
    q, s = quantize_int8_rows(g, scale_dtype=jnp.bfloat16)
    assert s.dtype == jnp.bfloat16
    s64 = np.asarray(s.astype(jnp.float32), np.float64)   # (32,)
    qs = np.arange(-127, 128, dtype=np.float64)
    prod64 = qs[None, :] * s64[:, None]
    prod32 = (qs[None, :].astype(np.float32)
              * s64[:, None].astype(np.float32))
    np.testing.assert_array_equal(prod32.astype(np.float64), prod64)
    # weighted scales stay exact too (torus's 1/3 is the hard case)
    ws64 = np.asarray(consensus._weighted_scale(1.0 / 3.0, s),
                      np.float64)
    wprod64 = qs[None, :] * ws64[:, None]
    wprod32 = (qs[None, :].astype(np.float32)
               * ws64[:, None].astype(np.float32))
    np.testing.assert_array_equal(wprod32.astype(np.float64), wprod64)
