"""Decentralized AMB-DG (paper Sec. V): gossip matrices, eq. (24) round
bound, consensus convergence."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import consensus


@pytest.mark.parametrize("topology,n", [("ring", 8), ("complete", 6),
                                        ("torus", 16)])
def test_matrices_doubly_stochastic(topology, n):
    Q = consensus.gossip_matrix(topology, n)
    assert np.allclose(Q.sum(0), 1) and np.allclose(Q.sum(1), 1)
    assert (Q >= 0).all()
    assert consensus.lambda2(Q) < 1.0          # connected


def test_complete_graph_one_round():
    Q = consensus.gossip_matrix("complete", 5)
    v = jnp.asarray(np.random.default_rng(0).standard_normal((5, 3)))
    out = consensus.run_consensus(v, Q, 1)
    np.testing.assert_allclose(np.asarray(out),
                               np.tile(np.asarray(v).mean(0), (5, 1)),
                               atol=1e-6)


def test_consensus_error_decays_at_spectral_rate():
    Q = consensus.gossip_matrix("ring", 8)
    lam = consensus.lambda2(Q)
    v = jnp.asarray(np.random.default_rng(1).standard_normal((8, 4)))
    errs = [float(consensus.consensus_error(
        consensus.run_consensus(v, Q, r))) for r in (0, 5, 10, 20)]
    assert errs[1] < errs[0] and errs[2] < errs[1] and errs[3] < errs[2]
    # rate ~ lam^r (allow slack)
    assert errs[2] <= errs[0] * lam ** 10 * 10


def test_min_rounds_eq24():
    """r >= log(2 sqrt(n)(1 + 2J/delta)) / (1 - lambda2)."""
    r = consensus.min_rounds(delta=0.1, n=16, J=1.0, lam2=0.5)
    expect = int(np.ceil(np.log(2 * 4 * (1 + 20)) / 0.5))
    assert r == expect
    with pytest.raises(ValueError):
        consensus.min_rounds(0.1, 4, 1.0, 1.0)   # disconnected


def test_min_rounds_achieves_delta():
    """Running the bound's round count achieves consensus error <= delta
    for messages with norm <= J (the paper's usage)."""
    n = 8
    Q = consensus.gossip_matrix("ring", n)
    lam = consensus.lambda2(Q)
    J, delta = 1.0, 0.05
    r = consensus.min_rounds(delta, n, J, lam)
    rng = np.random.default_rng(2)
    v = rng.standard_normal((n, 16))
    v = v / np.linalg.norm(v, axis=1, keepdims=True) * J   # ||m_i|| = J
    out = consensus.run_consensus(jnp.asarray(v), Q, r)
    assert float(consensus.consensus_error(out)) <= delta


@pytest.mark.parametrize("topology,n", [("ring", 8), ("ring", 2),
                                        ("torus", 4), ("torus", 9),
                                        ("complete", 5)])
def test_stencil_fold_matches_matrix(topology, n):
    """One ordered stencil-fold round (the shared dense/ppermute body)
    applies exactly the gossip matrix: the fold weights sum to Q, and
    a fold round equals Q @ v at float tolerance."""
    np.testing.assert_allclose(consensus._stencil_matrix(topology, n),
                               consensus.gossip_matrix(topology, n),
                               atol=1e-12)
    v = jnp.asarray(np.random.default_rng(3)
                    .standard_normal((n, 6)).astype(np.float32))
    out = consensus.gossip_round_dense(v, topology)
    np.testing.assert_allclose(
        np.asarray(out), consensus.gossip_matrix(topology, n) @
        np.asarray(v), rtol=1e-5, atol=1e-6)


def test_stencil_duplicate_terms_merged():
    """Coincident neighbours (torus side=2, ring n=2) merge into one
    term — duplicates would let XLA reassociate the fold differently
    across program variants (see consensus.topology_stencil)."""
    for topology, n in (("torus", 4), ("ring", 2)):
        terms = consensus.topology_stencil(topology, n)
        seen = [tuple(nbr) for nbr, _ in terms]
        assert len(seen) == len(set(seen)), (topology, n)
