"""End-to-end validation of the paper's claims in the cluster simulator
(reduced scale for CI; the full-scale runs live in benchmarks/)."""
import bisect

import numpy as np
import pytest

from repro.configs.base import AmbdgConfig, ModelConfig, LINREG
from repro.data.timing import ShiftedExponential
from repro.sim import SimProblem, simulate_anytime, simulate_kbatch

D = 512
CFG = ModelConfig(name="linreg-ci", family=LINREG, n_layers=0, d_model=0,
                  n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                  linreg_dim=D)
TIMING = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
OPT = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0, b_bar=800.0,
                  proximal="l2_ball", radius_C=float(1.05 * np.sqrt(D)))


def _time_to(tr, tgt):
    for t, e in zip(tr.times, tr.errors):
        if e <= tgt:
            return t
    return float("inf")


@pytest.fixture(scope="module")
def traces():
    dg = simulate_anytime(SimProblem(CFG, 10, b_max=512), t_p=2.5,
                          t_c=10.0, total_time=120.0, timing=TIMING,
                          opt_cfg=OPT, scheme="ambdg")
    amb = simulate_anytime(SimProblem(CFG, 10, b_max=512), t_p=2.5,
                           t_c=10.0, total_time=120.0, timing=TIMING,
                           opt_cfg=OPT, scheme="amb")
    kb = simulate_kbatch(SimProblem(CFG, 10, b_max=512), b_per_msg=60,
                         K=10, t_c=10.0, total_time=120.0, timing=TIMING,
                         opt_cfg=OPT)
    return dg, amb, kb


def test_all_converge(traces):
    dg, amb, kb = traces
    assert dg.errors[-1] < 0.2
    assert kb.errors[-1] < 0.25
    assert amb.errors[-1] < dg.errors[0]


def test_ambdg_faster_than_amb_wall_clock(traces):
    """Paper Fig. 2b: AMB-DG ~3x faster in wall clock under long T_c.
    (Target below the first-update error so the comparison is not
    degenerate at CI scale.)"""
    dg, amb, _ = traces
    tgt = min(dg.errors[0], amb.errors[0]) * 0.45
    assert _time_to(dg, tgt) * 1.8 < _time_to(amb, tgt)


def test_amb_better_per_epoch(traces):
    """Paper Fig. 2a: per-update AMB (fresh grads) beats AMB-DG."""
    dg, amb, _ = traces
    k = min(8, len(amb.errors) - 1)
    assert amb.errors[k] <= dg.errors[k] * 1.2


def test_ambdg_not_slower_than_kbatch(traces):
    """Paper Fig. 3: AMB-DG >= 1.5x faster than K-batch async (allow
    parity at CI scale)."""
    dg, _, kb = traces
    tgt = min(dg.errors[0], kb.errors[0]) * 0.45
    assert _time_to(dg, tgt) <= _time_to(kb, tgt) + 1e-9


def test_staleness_structure(traces):
    """AMB-DG staleness ramps 0..tau then stays fixed at tau (paper
    Sec. III); K-batch staleness is random with a spread (Fig. 4)."""
    dg, _, kb = traces
    assert dg.staleness[:5] == [0, 1, 2, 3, 4]
    assert all(s == 4 for s in dg.staleness[5:])
    ks = np.asarray(kb.staleness)
    assert ks.std() > 0.5          # genuinely random
    assert ks.max() >= 3


def test_minibatch_scale(traces):
    """E[b(t)] >= n*b = 600 with the paper's constants (their design
    target for T_p = 2.5)."""
    dg, _, _ = traces
    assert np.mean(dg.minibatches) >= 600
