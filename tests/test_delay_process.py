"""Property/fuzz suite for the stochastic delay subsystem.

Two layers:

  * the PROCESSES (``core.delay_process``): seeded reproducibility,
    bounds, checkpointable state, config validation;
  * the delay-tolerant RING (``arena.push_pop_variable``) replayed
    against a pure-numpy oracle over seeded random delay sequences —
    sweeping tau_max in {1, 4, 16} x all four processes — asserting
    the structural invariants the delay tolerance rests on:

      - no unread-slot overwrite: the statically-scheduled push target
        is always a slot whose entry was already applied;
      - per-slot count conservation: counts pushed == counts applied +
        counts still in flight, every step;
      - gradient mass telescoping: the same conservation for the
        gradient payload itself (exact under f32, since the masked
        fold adds exact zeros);
      - ``gradient_reference_epoch`` consistency: the popped sets and
        the observed staleness ``tau_obs`` match the
        ``staleness.delivery_schedule`` of the emitted sequence.

The ring layer parametrizes over the pop implementation: the CPU
gather reference AND the single-pass Pallas kernel in interpret mode
(``impl="pallas"`` — the oracle replay, the int8 conservation law and
the constant-sequence degeneration all hold through the kernel too).

``REPRO_TEST_DELAY`` (comma-separated process names) narrows the
process sweep and ``REPRO_TEST_TAU`` (comma-separated taus) the
tau_max sweep — the CI matrix legs compose the two, one cell per job.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DelayConfig
from repro.core import arena
from repro.core.delay_process import (DELAY_PROCESSES, make_delay_process,
                                      resolve_bounds)
from repro.core.staleness import delivery_schedule, observed_staleness

ALL_PROCESSES = ("fixed", "jitter", "heavy_tail", "bursty")
PROCESSES = tuple(
    p for p in os.environ.get("REPRO_TEST_DELAY",
                              ",".join(ALL_PROCESSES)).split(",") if p)
TAUS = [int(t) for t in
        os.environ.get("REPRO_TEST_TAU", "1,4,16").split(",") if t]
TAU = 3          # nominal staleness the processes wobble around

# pop implementations the ring tests replay through: the CPU gather
# reference and the single-pass kernel (Pallas interpret mode)
IMPLS = ("ref", "pallas")


def _impl_kw(impl: str) -> dict:
    return {"impl": impl,
            "interpret": True if impl == "pallas" else None}


def _cfg(process: str, tau_max: int, seed: int = 0, **kw) -> DelayConfig:
    return DelayConfig(process=process, tau_max=tau_max, seed=seed, **kw)


# ---------------------------------------------------------------------------
# the processes
# ---------------------------------------------------------------------------
def test_registry_and_validation():
    assert set(DELAY_PROCESSES) == set(ALL_PROCESSES)
    with pytest.raises(ValueError, match="unknown delay process"):
        make_delay_process(_cfg("lognormal", 4), TAU)
    with pytest.raises(ValueError, match="tau_max >= 1"):
        make_delay_process(_cfg("jitter", 0), TAU)
    with pytest.raises(ValueError, match="delay_min"):
        make_delay_process(_cfg("jitter", 2, delay_min=5), TAU)
    with pytest.raises(ValueError, match="delay_min"):
        make_delay_process(_cfg("jitter", 2, delay_min=-1), TAU)
    with pytest.raises(ValueError, match="tail_alpha"):
        make_delay_process(_cfg("heavy_tail", 4, tail_alpha=0.0), TAU)
    with pytest.raises(ValueError, match="probabilities"):
        make_delay_process(_cfg("bursty", 4, p_burst=1.5), TAU)
    with pytest.raises(ValueError, match="tau_max"):
        # fixed with an explicit cap below the nominal tau
        make_delay_process(_cfg("fixed", 1), TAU)
    # fixed resolves tau_max=0 to tau
    assert resolve_bounds(_cfg("fixed", 0), TAU)[1] == TAU


@pytest.mark.parametrize("tau_max", TAUS)
@pytest.mark.parametrize("process", PROCESSES)
def test_bounds_and_seeding(process, tau_max):
    if process == "fixed" and tau_max < TAU:
        pytest.skip("fixed caps at tau")
    n = 512
    a = make_delay_process(_cfg(process, tau_max, seed=1), TAU).sequence(n)
    b = make_delay_process(_cfg(process, tau_max, seed=1), TAU).sequence(n)
    lo, hi = resolve_bounds(_cfg(process, tau_max), TAU)
    assert (a >= lo).all() and (a <= hi).all()
    np.testing.assert_array_equal(a, b)          # seeded: reproducible
    if process == "fixed":
        assert (a == TAU).all()
    elif tau_max > 1:
        c = make_delay_process(_cfg(process, tau_max, seed=2),
                               TAU).sequence(n)
        assert not np.array_equal(a, c)          # seeds matter
        assert len(np.unique(a)) > 1             # genuinely stochastic


@pytest.mark.parametrize("process", PROCESSES)
def test_state_dict_resumes_mid_sequence(process):
    dp = make_delay_process(_cfg(process, 8, seed=5), TAU)
    dp.sequence(37)                               # advance
    saved = dp.state_dict()
    rest = dp.sequence(64)
    dp2 = make_delay_process(_cfg(process, 8, seed=999), TAU)
    dp2.load_state_dict(saved)
    np.testing.assert_array_equal(rest, dp2.sequence(64))


def test_heavy_tail_has_a_tail_and_bursty_bursts():
    seq = make_delay_process(_cfg("heavy_tail", 16, seed=0),
                             TAU).sequence(4096)
    # mostly delay_min, with genuine stragglers reaching the cap
    assert np.median(seq) == 1 and seq.max() == 16
    seq = make_delay_process(
        _cfg("bursty", 16, seed=0, p_burst=0.1, p_exit=0.3),
        TAU).sequence(4096)
    # geometric dwell: bursts of consecutive tau_max draws exist
    runs, cur = [], 0
    for d in seq:
        cur = cur + 1 if d == 16 else 0
        runs.append(cur)
    assert max(runs) >= 3
    assert (seq == TAU).any()                     # and normal periods


# ---------------------------------------------------------------------------
# the delay-tolerant ring vs a pure-numpy oracle
# ---------------------------------------------------------------------------
class _RingOracle:
    """Host-side model of the delay-tolerant ring: slot j holds the
    push from the last step s with s % n_slots == j, applied at
    s + tau_s. Checks the structural invariants each step."""

    def __init__(self, n_slots, n_pods, width):
        self.n_slots = n_slots
        self.slots = np.zeros((n_slots, n_pods, width), np.float32)
        self.due = np.full((n_slots,), -1, np.int64)
        self.counts = np.zeros((n_slots, n_pods), np.float32)
        self.stale = np.zeros((n_slots,), np.int64)
        self.pushed_mass = np.zeros((width,), np.float64)
        self.pushed_count = 0.0
        self.applied_mass = np.zeros((width,), np.float64)
        self.applied_count = 0.0

    def step(self, t, g, counts, d):
        k = t % self.n_slots
        # invariant 1: the overwritten slot's entry was already applied
        assert self.due[k] < t, (t, k, self.due[k])
        self.slots[k], self.counts[k] = g, counts
        self.due[k], self.stale[k] = t + d, d
        self.pushed_mass += g.sum(0)
        self.pushed_count += counts.sum()
        mask = self.due == t
        grad = self.slots[mask].sum(axis=(0, 1))
        count = float(self.counts[mask].sum())
        csums = self.counts.sum(1)
        tau_obs = (float((self.stale[mask] * csums[mask]).sum())
                   / max(count, 1.0))
        self.applied_mass += grad
        self.applied_count += count
        return grad, count, tau_obs

    def check_conservation(self, t):
        # invariants 2+3: pushed == applied + in-flight, every step
        live = self.due > t
        in_flight_count = float(self.counts[live].sum())
        assert self.pushed_count == self.applied_count + in_flight_count
        in_flight_mass = self.slots[live].sum(axis=(0, 1))
        np.testing.assert_allclose(
            self.pushed_mass, self.applied_mass + in_flight_mass,
            rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("tau_max", TAUS)
@pytest.mark.parametrize("process", PROCESSES)
def test_ring_invariants_under_random_delays(process, tau_max, impl):
    """Replay a seeded delay sequence through push_pop_variable and the
    numpy oracle: identical pops, conserved counts/mass, tau_obs
    consistent with the delivery schedule of the emitted sequence —
    through the CPU gather reference and the interpret-mode kernel."""
    if process == "fixed" and tau_max < TAU:
        pytest.skip("fixed caps at tau")
    n_pods = 2
    params = {"w": jnp.zeros((130,))}             # row-misaligned leaf
    layout = arena.make_layout(params)
    ar = arena.init_arena(layout, tau_max, n_pods, variable=True)
    oracle = _RingOracle(tau_max + 1, n_pods, 130)
    dp = make_delay_process(_cfg(process, tau_max, seed=11), TAU)
    n_steps = 3 * (tau_max + 1) + 4
    delays = dp.sequence(n_steps)
    rng = np.random.default_rng(0)

    step = jax.jit(
        lambda a, g, c, d: arena.push_pop_variable(layout, a, g, c, d,
                                                   **_impl_kw(impl)),
        donate_argnums=(0,))

    sched = delivery_schedule(delays.tolist())    # 1-indexed push steps
    for t in range(n_steps):
        g = rng.standard_normal((n_pods, 130)).astype(np.float32)
        counts = np.arange(1.0, n_pods + 1, dtype=np.float32) + t
        gs, c, tau_obs, ar = step(ar, {"w": jnp.asarray(g)},
                                  jnp.asarray(counts),
                                  jnp.int32(delays[t]))
        og, oc, otau = oracle.step(t, g, counts, int(delays[t]))
        got = np.asarray(arena.unflatten_tree(layout, gs)["w"])
        np.testing.assert_allclose(got, og, rtol=1e-6, atol=1e-5)
        assert float(c) == oc
        assert float(tau_obs) == pytest.approx(otau, rel=1e-6)
        oracle.check_conservation(t)
        # invariant 4: the popped set IS the delivery schedule of the
        # emitted sequence (1-indexed: push step s applied at
        # s + tau_s). Push s carried counts arange(1..n_pods) + (s-1),
        # so the applied count identifies exactly WHICH pushes arrived.
        due_pushes = sched.get(t + 1, [])
        expect_count = sum(n_pods * (n_pods + 1) / 2 + n_pods * (s - 1)
                           for s in due_pushes)
        assert oc == expect_count, (t, due_pushes)
        assert ar.phase == (t + 1) % (tau_max + 1)
        assert int(ar.head) == t + 1

    # the observed-staleness helper agrees with the emitted sequence
    # under equal per-push weights (constant counts): rebuild with
    # constant counts and compare tau_obs to observed_staleness
    ar = arena.init_arena(layout, tau_max, n_pods, variable=True)
    expect = observed_staleness(delays.tolist(), n_steps)
    for t in range(n_steps):
        g = jnp.ones((n_pods, 130), jnp.float32)
        gs, c, tau_obs, ar = step(ar, {"w": g},
                                  jnp.ones((n_pods,)),
                                  jnp.int32(delays[t]))
        assert float(tau_obs) == pytest.approx(expect[t], rel=1e-6)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("process", PROCESSES)
def test_ring_invariants_int8(process, impl):
    """The int8 ring keeps the same invariants: per-push quantization
    + error feedback means (applied + in-flight dequants + residual)
    telescopes to the true pushed mass."""
    tau_max, n_pods, width = 4, 1, 256
    params = {"w": jnp.zeros((width,))}
    layout = arena.make_layout(params)
    ar = arena.init_arena(layout, tau_max, n_pods, "int8", variable=True)
    dp = make_delay_process(_cfg(process, tau_max, seed=3), TAU)
    rng = np.random.default_rng(1)
    n_steps = 24
    true_mass = np.zeros((width,), np.float64)
    applied = np.zeros((width,), np.float64)
    step = jax.jit(
        lambda a, g, c, d: arena.push_pop_variable(layout, a, g, c, d,
                                                   "int8",
                                                   **_impl_kw(impl)),
        donate_argnums=(0,))
    for t in range(n_steps):
        g = 0.05 * rng.standard_normal((n_pods, width)).astype(np.float32)
        true_mass += g.sum(0)
        gs, c, tau_obs, ar = step(ar, {"w": jnp.asarray(g)},
                                  jnp.ones((n_pods,)),
                                  jnp.int32(dp.next()))
        applied += np.asarray(arena.unflatten_tree(layout, gs)["w"])
    due = np.asarray(ar.due)
    in_flight = np.zeros((width,), np.float64)
    for j in range(tau_max + 1):
        if due[j] >= n_steps:     # still undelivered
            deq = (np.asarray(ar.ring[j], np.float32)
                   * np.asarray(ar.scales[j])[..., None]).sum(0)
            in_flight += np.asarray(
                arena.unflatten_tree(layout, jnp.asarray(deq))["w"])
    residual = np.asarray(
        arena.unflatten_tree(
            layout, jnp.asarray(np.asarray(ar.residual).sum(0)))["w"])
    np.testing.assert_allclose(applied + in_flight + residual, true_mass,
                               rtol=1e-5, atol=1e-5)


def test_variable_ring_rejects_fixed_arena():
    params = {"w": jnp.zeros((8,))}
    layout = arena.make_layout(params)
    ar = arena.init_arena(layout, 2, 1)
    with pytest.raises(ValueError, match="delay-tolerant"):
        arena.push_pop_variable(layout, ar, {"w": jnp.zeros((1, 8))},
                                jnp.ones((1,)), jnp.int32(1))
    with pytest.raises(ValueError, match="v2"):
        arena.init_arena(layout, 2, 1, ring_version=1, variable=True)
    ar_v = arena.init_arena(layout, 2, 1, variable=True)
    with pytest.raises(ValueError, match="no v1 layout"):
        arena.convert_ring(ar_v, 1)
    with pytest.raises(ValueError, match="push_pop_variable"):
        arena.push_pop(layout, ar_v, {"w": jnp.zeros((1, 8))},
                       jnp.ones((1,)))


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("compression", ["none", "int8"])
def test_constant_sequence_degenerates_to_static(compression, impl):
    """A constant delay sequence tau_t == tau reduces the variable
    ring — through the gather reference AND the single-pass kernel —
    to the static fixed-tau path BIT-identically: every step has
    exactly one due slot (H = 1), so the masked fold is the static
    single-slot pop. (One carve-out, matching the fixed-ring kernel
    contract: the int8 KERNEL's unprotected in-register dequantize may
    contract into an FMA where the XLA paths round the product — the
    popped sums then differ by isolated f32 ulps; ring/scales/residual
    state stays bit-identical.)"""
    import functools
    tau, n_pods = 2, 2
    params = {"a": jnp.zeros((9,)), "b": jnp.zeros((33, 7))}
    layout = arena.make_layout(params)
    ar_s = arena.init_arena(layout, tau, n_pods, compression)
    ar_v = arena.init_arena(layout, tau, n_pods, compression,
                            variable=True)
    step_s = jax.jit(functools.partial(arena.push_pop, layout,
                                       compression=compression))
    step_v = jax.jit(functools.partial(arena.push_pop_variable, layout,
                                       compression=compression,
                                       **_impl_kw(impl)))
    for t in range(3 * (tau + 1) + 2):
        ks = jax.random.split(jax.random.PRNGKey(t), len(params))
        g = {k: jax.random.normal(kk, (n_pods,) + params[k].shape)
             for k, kk in zip(sorted(params), ks)}
        counts = jnp.full((n_pods,), 2.0 + t)
        gs_s, c_s, ar_s = step_s(ar_s, g, counts)
        gs_v, c_v, tau_obs, ar_v = step_v(ar_v, g, counts,
                                          jnp.int32(tau))
        if compression == "int8" and impl == "pallas":
            np.testing.assert_allclose(np.asarray(gs_s),
                                       np.asarray(gs_v), rtol=1e-6,
                                       atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(gs_s),
                                          np.asarray(gs_v))
        assert float(c_s) == float(c_v)
        assert float(tau_obs) == (float(tau) if t >= tau else 0.0)
        for s_slot, v_slot in zip(ar_s.ring, ar_v.ring):
            np.testing.assert_array_equal(np.asarray(s_slot),
                                          np.asarray(v_slot))
        if compression == "int8":
            for s_sc, v_sc in zip(ar_s.scales, ar_v.scales):
                np.testing.assert_array_equal(np.asarray(s_sc),
                                              np.asarray(v_sc))
            np.testing.assert_array_equal(np.asarray(ar_s.residual),
                                          np.asarray(ar_v.residual))


_SHARDED_VARPOP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import MeshConfig
    from repro.dist.context import sharding_profile
    from repro.kernels.delay_ring.ops import (ring_variable_pop,
                                              ring_variable_pop_ref,
                                              ring_variable_pop_sharded)

    mesh_cfg = MeshConfig(n_pods=2, data=2, model=2)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    n_slots, n_pods, rows = 5, 2, 256
    rng = np.random.default_rng(7)

    for comp in ("none", "int8"):
        if comp == "int8":
            ring = jnp.asarray(rng.integers(
                -127, 128, size=(n_slots, n_pods, rows, 128)), jnp.int8)
            scales = jnp.asarray(rng.uniform(
                1e-3, 1.0, size=(n_slots, n_pods, rows)), jnp.float32)
        else:
            ring = jnp.asarray(rng.normal(
                size=(n_slots, n_pods, rows, 128)), jnp.float32)
            scales = None
        for trial in range(6):
            mask = jnp.asarray(rng.integers(0, 2, size=(n_slots,)) > 0)
            with mesh, sharding_profile(mesh_cfg):
                got = ring_variable_pop_sharded(
                    ring, mask, scales=scales, mesh_cfg=mesh_cfg,
                    interpret=True)
            # dense oracle: same per-pod fold, pods left-folded
            part = ring_variable_pop_ref(ring, mask, scales=scales)
            want = np.asarray(part[0])
            for p in range(1, n_pods):
                want = want + np.asarray(part[p])
            np.testing.assert_allclose(np.asarray(got), want,
                                       rtol=1e-6, atol=1e-6)
    print("SHARDED_VARPOP_OK")
""")


@pytest.mark.slow
def test_variable_pop_sharded_matches_dense_8dev():
    """The single-reduce shard_map wrapper around the variable-pop
    kernel agrees with the dense oracle fold under a pod=2 x data=2 x
    model=2 mesh of 8 virtual CPU devices (f32 and int8) — i.e. the
    local fold + one psum is the same sum the dense path computes.
    Subprocess: the forced device count must not leak."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHARDED_VARPOP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "SHARDED_VARPOP_OK" in out.stdout
