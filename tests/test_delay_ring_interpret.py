"""Delay-ring kernels in Pallas INTERPRET mode across staleness depths.

Driven by ``REPRO_TEST_TAU`` (comma-separated taus; CI runs a matrix
leg with tau in {1, 4, 16}, the default here is the cheap {1, 4}).
Each tau runs enough steps to wrap the ring twice, checking:

  * v1 (scalar-prefetched stacked kernel) and v2 (static-phase slot
    kernel) rotate IDENTICALLY: same popped pod-sums, same ring
    contents through the v1 view — the two kernels share the
    quantize/dequantize formulas, so interpret-mode agreement is bit
    level;
  * the v2 int8 kernel vs the pure-XLA ref: quantization boundary
    flips from kernel-internal FMA contraction are allowed (isolated,
    1 step max), nothing larger — same contract as
    tests/test_arena.py::test_push_pop_pallas_branch_matches_ref;
  * the single-pass variable-pop kernel (stacked v3 ring) vs its
    expression-identical slot-fold oracle at the bit level, and full
    ``push_pop_variable`` steps kernel-vs-CPU-gather (exact
    count/tau_obs and state, fold-order tolerance on the popped
    grads).
"""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arena

TAUS = [int(t) for t in
        os.environ.get("REPRO_TEST_TAU", "1,4").split(",") if t]

SHAPES = {"a": (9,), "b": (33, 7), "c": (140,)}


def _params():
    return {k: jnp.zeros(s) for k, s in SHAPES.items()}


def _grads(key, n_pods):
    ks = jax.random.split(key, len(SHAPES))
    return {k: jax.random.normal(kk, (n_pods,) + SHAPES[k], jnp.float32)
            for k, kk in zip(sorted(SHAPES), ks)}


def _stack(x):
    return np.stack([np.asarray(s) for s in x])


@pytest.mark.parametrize("tau", TAUS)
@pytest.mark.parametrize("compression", ["none", "int8"])
def test_v1_and_v2_kernels_rotate_identically(tau, compression):
    """Scalar-prefetched v1 kernel vs static-phase v2 path, both in
    interpret mode, 2*(tau+1)+2 steps (two full wraps)."""
    n_pods = 2
    layout = arena.make_layout(_params())
    ar1 = arena.init_arena(layout, tau, n_pods, compression,
                           ring_version=1)
    ar2 = arena.init_arena(layout, tau, n_pods, compression,
                           ring_version=2)
    step = functools.partial(arena.push_pop, layout,
                             compression=compression, impl="pallas",
                             interpret=True)
    for t in range(2 * (tau + 1) + 2):
        g = _grads(jax.random.PRNGKey(t), n_pods)
        counts = jnp.full((n_pods,), 1.0 + t)
        gs1, c1, ar1 = step(ar1, g, counts)
        gs2, c2, ar2 = step(ar2, g, counts)
        np.testing.assert_array_equal(np.asarray(gs1), np.asarray(gs2))
        assert float(c1) == float(c2)
        view = arena.convert_ring(jax.device_get(ar2), 1)
        order = [(int(ar1.head) + i) % tau for i in range(tau)]
        np.testing.assert_array_equal(_stack(ar1.ring)[order],
                                      _stack(view.ring))
        if compression == "int8":
            np.testing.assert_array_equal(_stack(ar1.scales)[order],
                                          _stack(view.scales))
            np.testing.assert_array_equal(np.asarray(ar1.residual),
                                          np.asarray(view.residual))


@pytest.mark.parametrize("tau", TAUS)
@pytest.mark.parametrize("compression", ["none", "int8"])
def test_variable_pop_kernel_vs_oracle(tau, compression):
    """Single-pass variable-pop kernel (interpret mode) vs the
    expression-identical slot-fold oracle: BIT equality, over random
    masks covering H = 0 (no arrivals), H = 1 (the common case) and
    H = many due slots at once — f32 and int8+scales forms."""
    from repro.kernels.delay_ring.ops import (ring_variable_pop,
                                              ring_variable_pop_ref)
    n_slots, n_pods, rows = tau + 1, 2, 256
    rng = np.random.default_rng(17 * tau)
    ring = rng.normal(size=(n_slots, n_pods, rows, 128)).astype(np.float32)
    scales = None
    if compression == "int8":
        ring = rng.integers(-127, 128,
                            size=ring.shape).astype(np.int8)
        scales = jnp.asarray(
            rng.uniform(1e-3, 1.0,
                        size=(n_slots, n_pods, rows)).astype(np.float32))
    ring = jnp.asarray(ring)
    masks = [np.zeros((n_slots,), bool),          # H = 0
             np.eye(n_slots, dtype=bool)[0],      # H = 1
             np.ones((n_slots,), bool)]           # H = n_slots
    masks += [rng.integers(0, 2, size=(n_slots,)).astype(bool)
              for _ in range(8)]
    for m in masks:
        m = jnp.asarray(m)
        got = ring_variable_pop(ring, m, scales=scales, impl="pallas",
                                interpret=True)
        want = ring_variable_pop_ref(ring, m, scales=scales)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tau", TAUS)
@pytest.mark.parametrize("compression", ["none", "int8"])
def test_variable_full_step_kernel_vs_ref(tau, compression):
    """Full ``push_pop_variable`` steps through the kernel (interpret
    mode) vs the CPU gather reference, over a random delay sequence:
    grads agree to fold-order tolerance, count/tau_obs (fused into the
    kernel's scalar-metadata epilogue under pallas, jnp fold under ref
    — exact either way, the operands are integer-valued) agree
    EXACTLY, ring and metadata state stay bit-identical."""
    n_pods = 2
    layout = arena.make_layout(_params())
    ar_k = arena.init_arena(layout, tau, n_pods, compression,
                            variable=True)
    ar_r = arena.init_arena(layout, tau, n_pods, compression,
                            variable=True)
    rng = np.random.default_rng(23 * tau + 1)
    for t in range(2 * (tau + 1) + 2):
        g = _grads(jax.random.PRNGKey(200 + t), n_pods)
        counts = jnp.full((n_pods,), 1.0 + t)
        d = jnp.int32(rng.integers(0, tau + 1))
        gs_k, c_k, tau_k, ar_k = arena.push_pop_variable(
            layout, ar_k, g, counts, d, compression, impl="pallas",
            interpret=True)
        gs_r, c_r, tau_r, ar_r = arena.push_pop_variable(
            layout, ar_r, g, counts, d, compression, impl="ref")
        np.testing.assert_allclose(np.asarray(gs_k), np.asarray(gs_r),
                                   rtol=1e-6, atol=1e-6)
        assert float(c_k) == float(c_r)
        assert float(tau_k) == float(tau_r)
        np.testing.assert_array_equal(np.asarray(ar_k.ring),
                                      np.asarray(ar_r.ring))
        for f in ("due", "stale", "counts", "head"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ar_k, f)),
                np.asarray(getattr(ar_r, f)))
        if compression == "int8":
            np.testing.assert_array_equal(np.asarray(ar_k.scales),
                                          np.asarray(ar_r.scales))
            np.testing.assert_array_equal(np.asarray(ar_k.residual),
                                          np.asarray(ar_r.residual))


@pytest.mark.parametrize("tau", TAUS)
@pytest.mark.parametrize("compression", ["none", "int8"])
def test_variable_pop_fused_meta_vs_oracle(tau, compression):
    """The fused scalar-metadata epilogue (count / staleness-sum folded
    in the kernel's SMEM output) vs its expression-identical jnp oracle
    ``ring_variable_meta_ref``: BIT equality over random masks and
    integer-valued counts/staleness — the popped payload must also stay
    bit-identical to the meta-free call."""
    from repro.kernels.delay_ring.ops import (ring_variable_meta_ref,
                                              ring_variable_pop)
    n_slots, n_pods, rows = tau + 1, 2, 256
    rng = np.random.default_rng(29 * tau + 3)
    ring = rng.normal(size=(n_slots, n_pods, rows, 128)).astype(np.float32)
    scales = None
    if compression == "int8":
        ring = rng.integers(-127, 128, size=ring.shape).astype(np.int8)
        scales = jnp.asarray(
            rng.uniform(1e-3, 1.0,
                        size=(n_slots, n_pods, rows)).astype(np.float32))
    ring = jnp.asarray(ring)
    for trial in range(6):
        m = jnp.asarray(
            rng.integers(0, 2, size=(n_slots,)).astype(bool))
        cs = jnp.asarray(np.stack([
            rng.integers(0, 64, size=(n_slots,)),
            rng.integers(0, tau + 1, size=(n_slots,)),
        ]).astype(np.float32))
        popped, meta = ring_variable_pop(ring, m, scales=scales,
                                         counts_stale=cs, impl="pallas",
                                         interpret=True)
        bare = ring_variable_pop(ring, m, scales=scales, impl="pallas",
                                 interpret=True)
        want = ring_variable_meta_ref(m, cs)
        np.testing.assert_array_equal(np.asarray(meta), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(popped),
                                      np.asarray(bare))


@pytest.mark.parametrize("tau", TAUS)
def test_v2_int8_kernel_vs_ref(tau):
    """Interpret-mode v2 int8 kernel vs the XLA reference: isolated
    round-half boundary flips only (kernel-internal contraction)."""
    n_pods = 2
    layout = arena.make_layout(_params())
    ar_k = arena.init_arena(layout, tau, n_pods, "int8")
    ar_r = arena.init_arena(layout, tau, n_pods, "int8")
    for t in range(tau + 3):
        g = _grads(jax.random.PRNGKey(100 + t), n_pods)
        counts = jnp.ones((n_pods,))
        gs_k, _, ar_k = arena.push_pop(layout, ar_k, g, counts, "int8",
                                       impl="pallas", interpret=True)
        gs_r, _, ar_r = arena.push_pop(layout, ar_r, g, counts, "int8",
                                       impl="ref")
        qd = np.abs(_stack(ar_k.ring).astype(np.int32)
                    - _stack(ar_r.ring).astype(np.int32))
        assert qd.max() <= 1 and (qd > 0).mean() < 1e-3
        step_size = float(_stack(ar_r.scales).max())
        gd = np.abs(np.asarray(gs_k) - np.asarray(gs_r))
        assert gd.max() <= 1.01 * n_pods * step_size + 1e-6
        assert (gd > 1e-6).mean() < 1e-3
