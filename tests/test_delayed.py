"""Delayed-gradient buffer semantics: the entry applied at step t is the
one pushed at step t - tau (paper's deterministic staleness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delayed


@pytest.mark.parametrize("tau", [1, 2, 4])
@pytest.mark.parametrize("n_pods", [1, 2])
def test_pipeline_depth(tau, n_pods):
    params = {"w": jnp.zeros((3,))}
    buf = delayed.init_buffer(params, tau, n_pods)
    outs = []
    for t in range(1, 10):
        g = {"w": jnp.full((n_pods, 3), float(t))}
        counts = jnp.full((n_pods,), float(t))
        g_sum, c_sum, buf = delayed.push_pop(buf, g, counts)
        outs.append((float(g_sum["w"][0]), float(c_sum)))
    for i, (gv, cv) in enumerate(outs):
        t = i + 1
        if t <= tau:           # pipeline still filling: zero gradient
            assert gv == 0.0 and cv == 0.0
        else:                  # the entry from t - tau, summed over pods
            assert gv == float(t - tau) * n_pods
            assert cv == float(t - tau) * n_pods


def test_tau_zero_has_no_buffer():
    assert delayed.init_buffer({"w": jnp.zeros(2)}, 0, 2) is None


def test_int8_roundtrip_small_error():
    params = {"w": jnp.zeros((64,))}
    buf = delayed.init_buffer(params, 1, 2, compression="int8")
    rng = np.random.default_rng(0)
    g = rng.standard_normal((2, 64)).astype(np.float32)
    _, _, buf = delayed.push_pop(buf, {"w": jnp.asarray(g)},
                                 jnp.ones((2,)), compression="int8")
    g_sum, c, buf = delayed.push_pop(buf, {"w": jnp.zeros((2, 64))},
                                     jnp.ones((2,)), compression="int8")
    # popped = quantized version of g summed over pods
    expect = g.sum(0)
    err = np.abs(np.asarray(g_sum["w"]) - expect).max()
    scale = np.abs(g).max() / 127.0
    assert err <= 2 * scale + 1e-6


def test_int8_error_feedback_compensates():
    """With error feedback the accumulated applied gradient tracks the
    true sum despite per-step quantization."""
    params = {"w": jnp.zeros((32,))}
    buf = delayed.init_buffer(params, 1, 1, compression="int8")
    rng = np.random.default_rng(1)
    true_total = np.zeros(32, np.float32)
    applied_total = np.zeros(32, np.float32)
    g_last = None
    for t in range(30):
        g = 0.01 * rng.standard_normal((1, 32)).astype(np.float32)
        true_total += g[0]
        g_sum, _, buf = delayed.push_pop(buf, {"w": jnp.asarray(g)},
                                         jnp.ones((1,)),
                                         compression="int8")
        applied_total += np.asarray(g_sum["w"])
    # one entry still in flight; compare against all but the last push
    diff = np.abs(applied_total + 0 - (true_total - g[0])).max()
    naive_err = 30 * 0.01 / 127  # what drift would look like w/o feedback
    assert diff < 5 * naive_err


def test_buffer_axes_resolve_to_specs():
    """The axes tree maps 1:1 onto the buffer leaves (via the same
    is_leaf the sharding resolver uses) and the pod dim shards."""
    from repro.configs.base import MeshConfig
    from repro.dist.sharding import spec_for, _is_axes_leaf

    params = {"a": jnp.zeros((4, 32)), "b": {"c": jnp.zeros((16,))}}
    params_axes = {"a": ("embed", "mlp"), "b": {"c": ("mlp",)}}
    buf = delayed.init_buffer(params, 2, 2)
    axes = delayed.buffer_logical_axes(params_axes, 2)
    mc = MeshConfig(n_pods=2, data=2, model=2)
    specs = jax.tree.map(
        lambda ax, leaf: spec_for(tuple(ax), tuple(leaf.shape), mc),
        axes, buf, is_leaf=_is_axes_leaf)
    # grads leaf 'a': (tau, pod, 4, 32) -> (None, 'pod', 'data', 'model')
    sa = specs.grads["a"]
    assert sa[1] == "pod"
    assert "model" in tuple(sa)
