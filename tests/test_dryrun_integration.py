"""Integration: the real dry-run entry point lowers+compiles one cell
per kind (train / prefill / decode) in a subprocess (the dry-run forces
512 virtual devices, so it must not share this test process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(arch, shape, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, *extra],
        capture_output=True, text=True, env=env, timeout=1500)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("{")][0]
    return json.loads(line)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-0.5b", "train_4k"),      # AMB-DG train step
    ("xlstm-125m", "long_500k"),       # sub-quadratic decode
])
def test_dryrun_cell(arch, shape):
    r = _run(arch, shape)
    assert r["flops"] > 0
    mem = r["memory"]
    assert (mem["argument_bytes"] + mem["temp_bytes"]) < 16e9
    assert r["collectives"]["count"] > 0


@pytest.mark.slow
def test_dryrun_cell_variable_delay():
    """The delay-tolerant ring lowers + compiles with full production
    shardings on the 16x16 mesh: the per-step delay scalar enters the
    batch specs, the per-slot due/stale metadata threads the state
    specs, and the cell reports the process it lowered with."""
    r = _run("qwen1.5-0.5b", "train_4k",
             "--delay-process", "heavy_tail", "--tau-max", "3")
    assert r["flops"] > 0
    assert r["master"]["delay_process"] == "heavy_tail"
    assert r["master"]["tau_max"] == 3
    mem = r["memory"]
    assert (mem["argument_bytes"] + mem["temp_bytes"]) < 16e9
    assert r["collectives"]["count"] > 0
