"""Unit + property tests for the dual-averaging optimizer (paper eq. 3-4)."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import AmbdgConfig
from repro.core import dual_averaging as da


def test_alpha_schedule_matches_theorem():
    # alpha(t)^-1 = L + sqrt((t + tau)/b_bar)  (Theorem IV.1)
    cfg = AmbdgConfig(tau=4, smoothness_L=2.0, b_bar=600.0)
    for t in (1, 7, 100):
        expect = 1.0 / (2.0 + np.sqrt((t + 4) / 600.0))
        assert np.isclose(float(da.alpha(jnp.float32(t), cfg)), expect)


def test_alpha_nonincreasing():
    cfg = AmbdgConfig(tau=2, smoothness_L=1.0, b_bar=64.0)
    ts = jnp.arange(1, 200, dtype=jnp.float32)
    a = jax.vmap(lambda t: da.alpha(t, cfg))(ts)
    assert bool(jnp.all(jnp.diff(a) <= 0))


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10**6))
def test_prox_closed_form_is_argmin(seed):
    """Property: w = -alpha z minimizes <z,w> + psi(w)/alpha for
    psi = 0.5||w||^2 — check against random perturbations."""
    rng = np.random.default_rng(seed)
    z = rng.standard_normal(16).astype(np.float32)
    a = float(rng.uniform(0.01, 2.0))
    cfg = AmbdgConfig()
    w = np.asarray(da.prox_step({"w": jnp.asarray(z)}, a, cfg)["w"])

    def obj(v):
        return float(z @ v + 0.5 * v @ v / a)

    base = obj(w)
    for _ in range(10):
        delta = 0.01 * rng.standard_normal(16).astype(np.float32)
        assert obj(w + delta) >= base - 1e-5


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10**6))
def test_prox_ball_projection(seed):
    """l2_ball prox = unconstrained argmin projected onto the C-ball."""
    rng = np.random.default_rng(seed)
    z = rng.standard_normal(8).astype(np.float32) * 10
    a = 1.0
    C = 0.5
    cfg = AmbdgConfig(proximal="l2_ball", radius_C=C)
    w = np.asarray(da.prox_step({"w": jnp.asarray(z)}, a, cfg)["w"])
    assert np.linalg.norm(w) <= C + 1e-5
    # direction preserved
    wf = -a * z
    cos = w @ wf / (np.linalg.norm(w) * np.linalg.norm(wf) + 1e-12)
    assert cos > 0.999


def test_update_accumulates_z():
    cfg = AmbdgConfig(tau=0, smoothness_L=1.0, b_bar=4.0)
    params = {"w": jnp.zeros(4)}
    state = da.init(params)
    g1 = {"w": jnp.ones(4)}
    w1, state = da.update(state, g1, cfg)
    w2, state = da.update(state, g1, cfg)
    np.testing.assert_allclose(np.asarray(state.z["w"]), 2 * np.ones(4))
    assert int(state.t) == 2
    # w = -alpha(t+1) z
    expect = -float(da.alpha(jnp.float32(3), cfg)) * 2
    np.testing.assert_allclose(np.asarray(w2["w"]), expect, rtol=1e-6)


def test_convergence_on_quadratic():
    """Dual averaging drives a noisy quadratic to its optimum at the
    O(1/sqrt(m)) rate the paper proves."""
    rng = np.random.default_rng(0)
    d, b = 64, 256
    w_star = rng.standard_normal(d).astype(np.float32)
    cfg = AmbdgConfig(tau=0, smoothness_L=1.0, b_bar=float(b))
    state = da.init({"w": jnp.zeros(d)})
    w = jnp.zeros(d)
    errs = []
    for t in range(60):
        x = rng.standard_normal((b, d)).astype(np.float32)
        y = x @ w_star
        g = {"w": jnp.asarray(x.T @ (x @ np.asarray(w) - y) / b)}
        w_new, state = da.update(state, g, cfg)
        w = w_new["w"]
        errs.append(float(np.sum((np.asarray(w) - w_star) ** 2)
                          / np.sum(w_star ** 2)))
    assert errs[-1] < 0.01
    assert errs[-1] < errs[5]
