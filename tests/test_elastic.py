"""Elastic workers (core.worker_process) — seeded churn, stragglers
and crash/recovery as first-class scenarios across the Strategy API.

What is pinned here:

  * the worker processes themselves: registry/validation, seeded
    determinism vs a plain-numpy oracle, statistics of each chain
    (churn stationary up-fraction, crash/restart dwell means,
    heterogeneous persistence), ``state_dict`` mid-sequence resume;
  * ``fold_anytime_weights``: the static all-alive/speed-1.0 draw
    returns the input weights BIT-IDENTICALLY (the static == no-churn
    regression contract), and count conservation of the masked anytime
    normalization;
  * the all-dead epoch: a step whose every weight is zero applies an
    exact zero update (dual z bit-identical, everything finite) under
    the fixed AND the stochastic delay path;
  * both simulator engines: a static process is bit-identical to no
    process at all; churn runs are seeded-reproducible; the host loop
    kills ~30% of its fleet mid-run, checkpoints, restarts, and lands
    bit-exactly on the uninterrupted run;
  * masked gossip: the dense masked fold tracks the masked-matrix
    numpy oracle; the all-alive mask degenerates BIT-exactly to the
    unmasked fold; dead workers' z/params freeze bit-identically.

``REPRO_TEST_ELASTIC`` (comma-separated process names) narrows the
sweep — the CI elastic matrix leg runs one process family per job.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (AmbdgConfig, ConsensusConfig, DelayConfig,
                                ElasticConfig, LINREG, MeshConfig,
                                ModelConfig, RunConfig, TRAIN_4K)
from repro.core import consensus
from repro.core.worker_process import (WORKER_PROCESSES,
                                       make_worker_process,
                                       validate_elastic)
from repro.train.fault import fold_anytime_weights

ALL_PROCESSES = ("static", "heterogeneous", "churn", "crash_restart")
PROCESSES = tuple(
    p for p in os.environ.get("REPRO_TEST_ELASTIC",
                              ",".join(ALL_PROCESSES)).split(",") if p)

CFG = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                  n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                  linreg_dim=16)


def _ecfg(process: str, **kw) -> ElasticConfig:
    return ElasticConfig(process=process, **kw)


# ---------------------------------------------------------------------------
# the processes
# ---------------------------------------------------------------------------
def test_registry_and_validation():
    assert set(WORKER_PROCESSES) == set(ALL_PROCESSES)
    with pytest.raises(ValueError):
        validate_elastic(_ecfg("nope"))
    with pytest.raises(ValueError):
        validate_elastic(_ecfg("churn", p_fail=1.5))
    with pytest.raises(ValueError):
        # permanent drain: failures possible but recovery impossible
        validate_elastic(_ecfg("churn", p_fail=0.1, p_recover=0.0))
    with pytest.raises(ValueError):
        validate_elastic(_ecfg("crash_restart", mttf=0.0))
    with pytest.raises(ValueError):
        validate_elastic(_ecfg("crash_restart", mttr=-1.0))
    with pytest.raises(ValueError):
        validate_elastic(_ecfg("heterogeneous", speed_sigma=-0.5))
    with pytest.raises(ValueError):
        validate_elastic(_ecfg("heterogeneous", speed_min=0.0))


@pytest.mark.parametrize("process", PROCESSES)
def test_shapes_seeding_and_sanity(process):
    n = 6
    p1 = make_worker_process(_ecfg(process, seed=3), n)
    p2 = make_worker_process(_ecfg(process, seed=3), n)
    m1, s1 = p1.sequence(40)
    m2, s2 = p2.sequence(40)
    assert m1.shape == (40, n) and s1.shape == (40, n)
    assert m1.dtype == bool
    np.testing.assert_array_equal(m1, m2)       # seeded determinism
    np.testing.assert_array_equal(s1, s2)
    assert (s1 >= 0).all() and np.isfinite(s1).all()
    if process != "static":
        p3 = make_worker_process(_ecfg(process, seed=4), n)
        m3, s3 = p3.sequence(40)
        assert (not np.array_equal(m1, m3)) or (not np.array_equal(s1, s3))


@pytest.mark.parametrize("process", PROCESSES)
def test_state_dict_resumes_mid_sequence(process):
    n = 5
    cfg = _ecfg(process, seed=11)
    ref = make_worker_process(cfg, n)
    full_m, full_s = ref.sequence(30)
    p = make_worker_process(cfg, n)
    for _ in range(13):
        p.step()
    # JSON round-trip: the checkpoint manifest is JSON
    sd = json.loads(json.dumps(p.state_dict()))
    q = make_worker_process(cfg, n)
    q.load_state_dict(sd)
    tail_m, tail_s = q.sequence(17)
    np.testing.assert_array_equal(tail_m, full_m[13:])
    np.testing.assert_array_equal(tail_s, full_s[13:])


def test_static_draws_all_alive_and_consumes_no_rng():
    p = make_worker_process(_ecfg("static"), 4)
    state0 = json.dumps(p.state_dict()["rng"], sort_keys=True)
    m, s = p.sequence(10)
    assert m.all() and (s == 1.0).all()
    assert json.dumps(p.state_dict()["rng"], sort_keys=True) == state0


def test_churn_matches_numpy_oracle_and_stationary_fraction():
    """The Gilbert-Elliott up/down chain must replay exactly from a
    twin numpy generator, and its long-run up-fraction must approach
    p_recover / (p_fail + p_recover)."""
    n, p_fail, p_recover, seed = 4, 0.2, 0.6, 7
    proc = make_worker_process(
        _ecfg("churn", p_fail=p_fail, p_recover=p_recover, seed=seed), n)
    masks, _ = proc.sequence(4000)
    rng = np.random.default_rng(seed)
    up = np.ones(n, dtype=bool)
    for t in range(4000):
        u = rng.uniform(size=n)
        fail = up & (u < p_fail)
        recover = (~up) & (u < p_recover)
        up = (up & ~fail) | recover
        np.testing.assert_array_equal(masks[t], up, err_msg=f"t={t}")
    stat = p_recover / (p_fail + p_recover)
    assert abs(masks.mean() - stat) < 0.05


def test_crash_restart_dwell_times_follow_mttf_mttr():
    mttf, mttr = 40.0, 8.0
    proc = make_worker_process(
        _ecfg("crash_restart", mttf=mttf, mttr=mttr, seed=5), 8)
    masks, _ = proc.sequence(6000)

    def dwells(col, value):
        runs, cur = [], 0
        for v in col:
            if v == value:
                cur += 1
            elif cur:
                runs.append(cur)
                cur = 0
        return runs

    up_runs = [r for c in masks.T for r in dwells(c, True)]
    down_runs = [r for c in masks.T for r in dwells(c, False)]
    assert abs(np.mean(up_runs) - mttf) / mttf < 0.25
    assert abs(np.mean(down_runs) - mttr) / mttr < 0.25
    # availability = MTTF / (MTTF + MTTR)
    assert abs(masks.mean() - mttf / (mttf + mttr)) < 0.05


def test_heterogeneous_speeds_persist_and_center_on_one():
    proc = make_worker_process(
        _ecfg("heterogeneous", speed_sigma=0.5, speed_min=0.05, seed=0),
        256)
    m, s0 = proc.step()
    _, s1 = proc.step()
    assert m.all()
    np.testing.assert_array_equal(s0, s1)        # persistent skew
    assert (s0 >= 0.05).all()
    assert len(np.unique(s0)) > 200              # genuinely heterogeneous
    # lognormal(-sigma^2/2, sigma) has mean 1: the fleet-average rate
    # stays calibrated
    assert abs(float(s0.mean()) - 1.0) < 0.1


# ---------------------------------------------------------------------------
# the anytime weights fold
# ---------------------------------------------------------------------------
def test_fold_static_draw_is_bit_identical():
    """All-alive / speed-1.0 (what the static process emits) must
    return the input weights bitwise — the regression pin that keeps
    rc.elastic's default off the hot path's numerics entirely."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        n, spw = int(rng.integers(1, 9)), int(rng.integers(1, 17))
        b = rng.integers(0, spw + 1, size=n)
        w = np.zeros((n, spw), np.float32)
        for i, bi in enumerate(b):
            w[i, :bi] = 1.0
        w = w.reshape(-1)
        out = fold_anytime_weights(w, np.ones(n, bool), np.ones(n), n,
                                   spw)
        assert out.dtype == w.dtype
        np.testing.assert_array_equal(out, w)


def test_fold_masks_and_scales_counts():
    rng = np.random.default_rng(1)
    for _ in range(100):
        n, spw = int(rng.integers(1, 7)), int(rng.integers(1, 13))
        b = rng.integers(0, spw + 1, size=n)
        w = np.zeros((n, spw), np.float32)
        for i, bi in enumerate(b):
            w[i, :bi] = 1.0
        active = rng.uniform(size=n) < 0.7
        speeds = rng.lognormal(0.0, 0.6, size=n)
        out = fold_anytime_weights(w.reshape(-1), active, speeds, n,
                                   spw).reshape(n, spw)
        for i in range(n):
            expect = (min(int(np.floor(b[i] * speeds[i])), spw)
                      if active[i] else 0)
            assert out[i].sum() == expect, (i, b[i], speeds[i], active[i])
            # prefix-ones rows: the pipeline's weight layout
            np.testing.assert_array_equal(
                out[i], np.r_[np.ones(expect), np.zeros(spw - expect)]
                .astype(np.float32))


def test_masked_normalization_conserves_counts():
    """eq. (5): the weighted aggregation normalizes by the SUM of the
    surviving counts — feeding a folded weight vector through a
    weighted-mean must equal the mean over exactly the surviving
    samples (count conservation, no dead-sample leakage)."""
    rng = np.random.default_rng(2)
    n, spw = 4, 8
    x = rng.normal(size=(n * spw,))
    b = rng.integers(1, spw + 1, size=n)
    w = np.zeros((n, spw), np.float32)
    for i, bi in enumerate(b):
        w[i, :bi] = 1.0
    active = np.array([True, False, True, True])
    out = fold_anytime_weights(w.reshape(-1), active, np.ones(n), n, spw)
    count = out.sum()
    assert count == sum(b[i] for i in range(n) if active[i])
    got = float((x * out).sum() / max(count, 1e-12))
    keep = np.concatenate(
        [x[i * spw:i * spw + b[i]] for i in range(n) if active[i]])
    assert abs(got - keep.mean()) < 1e-9


# ---------------------------------------------------------------------------
# the all-dead epoch on the device step (fixed AND stochastic delay)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("delay", ["fixed", "heavy_tail"])
def test_all_dead_epoch_is_exact_zero_update(delay):
    """An all-dead epoch contributes an EXACT zero to the delay ring:
    its slot pops as a zero update tau (or tau_t) steps later. Once
    enough consecutive dead epochs drain the live slots, the dual z
    freezes bit-identically — under the fixed AND the stochastic
    delay process — and nothing ever goes non-finite."""
    from repro.models import build_model
    model = build_model(CFG)
    tau_max = 4
    rc = RunConfig(
        model=CFG,
        shape=dataclasses.replace(TRAIN_4K, seq_len=0, global_batch=8),
        mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(tau=2, n_microbatches=2, b_bar=8.0,
                          smoothness_L=4.0),
        delay=(DelayConfig() if delay == "fixed" else
               DelayConfig(process="heavy_tail", tau_max=tau_max,
                           seed=13)))
    import repro.api as api
    s = api.build(model, rc)
    state = s.init_state(jax.random.PRNGKey(0))
    step = jax.jit(s.train_step)
    from repro.core.delay_process import make_delay_process
    dproc = (make_delay_process(rc.delay, rc.ambdg.tau)
             if delay != "fixed" else None)

    def batchify(key, weights, tau_t=None):
        b = model.dummy_batch(8, key=key)
        b["weights"] = jnp.asarray(weights, jnp.float32)
        if dproc is not None:
            b["delay"] = jnp.int32(dproc.next() if tau_t is None
                                   else tau_t)
        return b

    # warm the ring with live epochs so dead epochs pop REAL in-flight
    # gradients before the zeros drain through
    for t in range(4):
        state, _ = step(state, batchify(jax.random.PRNGKey(t),
                                        np.ones(8)))
    # data-independence holds from the FIRST dead epoch: with every
    # weight zero the pushed message is exactly zero regardless of the
    # samples, so two different dead batches give bit-identical state
    dead_a = batchify(jax.random.PRNGKey(50), np.zeros(8), tau_t=2)
    dead_b = batchify(jax.random.PRNGKey(51), np.zeros(8), tau_t=2)
    out_a, m = step(state, dead_a)
    out_b, _ = step(state, dead_b)
    assert np.isfinite(float(m["loss"]))
    for a, b in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # drain: after > tau_max consecutive dead epochs every pending
    # live slot has popped; from then on each pop is an exact zero and
    # z freezes bit-identically
    drain = tau_max + 2
    for t in range(drain):
        state, _ = step(state, batchify(jax.random.PRNGKey(60 + t),
                                        np.zeros(8)))
    z_frozen = np.asarray(state.opt_state.z).copy()
    for t in range(3):
        state, m = step(state, batchify(jax.random.PRNGKey(80 + t),
                                        np.zeros(8)))
        np.testing.assert_array_equal(np.asarray(state.opt_state.z),
                                      z_frozen, err_msg=f"dead step {t}")
        assert np.isfinite(float(m["loss"]))
        for leaf in jax.tree.leaves(state):
            assert bool(jnp.all(jnp.isfinite(leaf)))


# ---------------------------------------------------------------------------
# simulator engines
# ---------------------------------------------------------------------------
def _sim_fixture():
    from repro.sim import SimProblem
    from repro.data.timing import ShiftedExponential
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=180.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(16)))
    problem = lambda: SimProblem(CFG, n_workers=3, seed=7, b_max=128)
    return problem, timing, opt


def test_sim_anytime_static_process_is_bit_identical():
    from repro.sim import simulate_anytime
    problem, timing, opt = _sim_fixture()
    ref = simulate_anytime(problem(), t_p=2.5, t_c=10.0, total_time=40.0,
                           timing=timing, opt_cfg=opt, rng_seed=11)
    st = simulate_anytime(problem(), t_p=2.5, t_c=10.0, total_time=40.0,
                          timing=timing, opt_cfg=opt, rng_seed=11,
                          worker_process=make_worker_process(
                              _ecfg("static"), 3))
    assert ref.minibatches == st.minibatches
    assert ref.errors == st.errors
    assert ref.staleness == st.staleness
    assert st.active == [3] * len(st.epochs)


def test_sim_kbatch_static_process_is_bit_identical():
    from repro.sim import simulate_kbatch
    problem, timing, opt = _sim_fixture()
    ref = simulate_kbatch(problem(), b_per_msg=60, K=2, t_c=10.0,
                          total_time=40.0, timing=timing, opt_cfg=opt,
                          rng_seed=11)
    st = simulate_kbatch(problem(), b_per_msg=60, K=2, t_c=10.0,
                         total_time=40.0, timing=timing, opt_cfg=opt,
                         rng_seed=11, t_p=2.5,
                         worker_process=make_worker_process(
                             _ecfg("static"), 3))
    assert ref.times == st.times
    assert ref.errors == st.errors
    assert ref.staleness == st.staleness


@pytest.mark.parametrize("process",
                         [p for p in PROCESSES if p != "static"])
def test_sim_runs_are_seeded_and_finite(process):
    from repro.sim import simulate_anytime, simulate_kbatch
    problem, timing, opt = _sim_fixture()
    kw = (dict(p_fail=0.3, p_recover=0.4) if process == "churn" else
          dict(mttf=8.0, mttr=3.0) if process == "crash_restart" else
          dict(speed_sigma=0.6))
    mk = lambda: make_worker_process(_ecfg(process, seed=11, **kw), 3)
    a1 = simulate_anytime(problem(), t_p=2.5, t_c=10.0, total_time=40.0,
                          timing=timing, opt_cfg=opt, rng_seed=11,
                          worker_process=mk())
    a2 = simulate_anytime(problem(), t_p=2.5, t_c=10.0, total_time=40.0,
                          timing=timing, opt_cfg=opt, rng_seed=11,
                          worker_process=mk())
    assert a1.active == a2.active and a1.errors == a2.errors
    assert all(np.isfinite(e) for e in a1.errors)
    assert len(a1.active) == len(a1.epochs)
    k1 = simulate_kbatch(problem(), b_per_msg=60, K=2, t_c=10.0,
                         total_time=40.0, timing=timing, opt_cfg=opt,
                         rng_seed=11, t_p=2.5, worker_process=mk())
    k2 = simulate_kbatch(problem(), b_per_msg=60, K=2, t_c=10.0,
                         total_time=40.0, timing=timing, opt_cfg=opt,
                         rng_seed=11, t_p=2.5, worker_process=mk())
    assert k1.times == k2.times and k1.errors == k2.errors
    assert all(np.isfinite(e) for e in k1.errors)


def test_sim_anytime_all_dead_epochs_coast():
    """A churn chain that drains the fleet produces all-dead epochs:
    their minibatch count is 0, the error curve stays finite, and the
    master's state coasts through them."""
    from repro.sim import simulate_anytime
    problem, timing, opt = _sim_fixture()
    wp = make_worker_process(
        _ecfg("churn", p_fail=0.95, p_recover=0.05, seed=1), 3)
    tr = simulate_anytime(problem(), t_p=2.5, t_c=10.0, total_time=40.0,
                          timing=timing, opt_cfg=opt, rng_seed=11,
                          worker_process=wp)
    assert 0 in tr.active
    dead = [i for i, a in enumerate(tr.active) if a == 0]
    for i in dead:
        assert tr.minibatches[i] == 0
    assert all(np.isfinite(e) for e in tr.errors)


def test_sim_kbatch_next_active_epoch_horizon_bounded():
    """Seeded regression for the lost-job restart scan: a worker that
    crashes late and never recovers must not strand the event loop —
    ``next_active_epoch`` gives up at the run horizon
    (total_time // t_p + 2), the lazily-extended per-epoch draw list
    stays bounded by that horizon, and the draws it DID take are the
    process's seeded sequence in strict epoch order (the scan reads
    epochs, never re-draws them)."""
    from repro.sim import simulate_kbatch
    problem, timing, opt = _sim_fixture()
    # mttr >> total_time: the first crash is permanent for this run
    kw = dict(mttf=6.0, mttr=1000.0, seed=11)
    run = lambda: simulate_kbatch(
        problem(), b_per_msg=60, K=2, t_c=10.0, total_time=40.0,
        timing=timing, opt_cfg=opt, rng_seed=11, t_p=2.5,
        worker_process=make_worker_process(
            _ecfg("crash_restart", **kw), 3))
    tr = run()
    assert all(np.isfinite(e) for e in tr.errors)
    horizon = int(40.0 // 2.5) + 2
    # epoch_state is probed at most up to the scan's last index
    assert 0 < len(tr.active) <= horizon + 1
    # the fleet genuinely drains (permanent crashes), yet the run ends
    assert tr.active[-1] < 3
    # draw order: a fresh process stepped len(active) times emits the
    # exact same alive counts — event-heap timing never perturbs or
    # reorders the seeded per-epoch sequence
    wp = make_worker_process(_ecfg("crash_restart", **kw), 3)
    replay = [int(wp.step()[0].sum()) for _ in range(len(tr.active))]
    assert tr.active == replay
    tr2 = run()
    assert tr2.active == tr.active and tr2.errors == tr.errors


def test_api_simulate_auto_wires_worker_process():
    """api.simulate(built_instance, ...) feeds rc.elastic's seeded
    process into the engine exactly like an explicit kwarg."""
    import repro.api as api
    from repro.models import build_model
    from repro.sim import simulate_anytime
    problem, timing, opt = _sim_fixture()
    ecfg = _ecfg("churn", p_fail=0.3, p_recover=0.4, seed=11)
    rc = RunConfig(model=CFG, shape=TRAIN_4K, strategy="ambdg",
                   ambdg=opt, elastic=ecfg)
    tr_api = api.simulate(api.build(build_model(CFG), rc), problem(),
                          t_p=2.5, t_c=10.0, total_time=40.0,
                          timing=timing, opt_cfg=opt, rng_seed=11)
    tr_ref = simulate_anytime(problem(), t_p=2.5, t_c=10.0,
                              total_time=40.0, timing=timing,
                              opt_cfg=opt, rng_seed=11,
                              worker_process=make_worker_process(ecfg, 3))
    assert tr_api.active == tr_ref.active
    assert tr_api.errors == tr_ref.errors


def test_persistent_speeds_time_for_rejects_partial_fleet():
    """The n=1 misuse that silently lost the worker identity now
    raises; per_worker_time is the per-worker path."""
    from repro.data.timing import PersistentWorkerSpeeds, ShiftedExponential
    pw = PersistentWorkerSpeeds(ShiftedExponential(), n_workers=4, seed=0)
    rng = np.random.default_rng(0)
    full = pw.time_for(rng, 4, 60)
    assert full.shape == (4,)
    with pytest.raises(ValueError):
        pw.time_for(rng, 1, 60)
    for w in range(4):
        assert pw.per_worker_time(w, 60) == pytest.approx(full[w])


# ---------------------------------------------------------------------------
# the host loop: churn -> evict -> re-mesh -> checkpoint-restore
# ---------------------------------------------------------------------------
def _loop_fixture(elastic, n_steps=12, ckpt_dir=None, ckpt_every=6):
    from repro.models import build_model
    from repro.train.loop import LoopConfig
    model = build_model(CFG)
    rc = RunConfig(model=CFG,
                   shape=dataclasses.replace(TRAIN_4K, seq_len=0,
                                             global_batch=16),
                   mesh=MeshConfig(n_pods=1, data=1, model=1),
                   ambdg=AmbdgConfig(tau=1, n_microbatches=2, b_bar=16.0,
                                     smoothness_L=4.0),
                   strategy="ambdg", elastic=elastic, seed=0)
    lc = LoopConfig(n_steps=n_steps, ckpt_dir=ckpt_dir,
                    ckpt_every=ckpt_every, log_every=1, n_workers=4,
                    samples_per_worker=4, eviction_misses=2)
    return model, rc, lc


def test_loop_static_elastic_is_current_path_bitwise():
    """rc.elastic's default ("static") must not touch the loop at all:
    same params as a config that never heard of elasticity."""
    from repro.train.loop import train
    model, rc, lc = _loop_fixture(ElasticConfig())
    out_a = train(model, rc, lc)
    out_b = train(model, rc.replace(elastic=ElasticConfig()), lc)
    for a, b in zip(jax.tree.leaves(out_a["state"]),
                    jax.tree.leaves(out_b["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert out_a["remesh_events"] == []


def test_loop_churn_evicts_readmits_and_reports():
    from repro.train.loop import train
    model, rc, lc = _loop_fixture(
        _ecfg("churn", p_fail=0.5, p_recover=0.3, seed=9))
    out = train(model, rc, lc)
    events = [e["event"] for e in out["remesh_events"]]
    assert "evict" in events and "readmit" in events
    ev = next(e for e in out["remesh_events"] if e["event"] == "evict")
    assert set(ev["plan"]) >= {"alive", "n_workers", "evicted"}
    assert all("active_workers" in h for h in out["history"])
    assert all(np.isfinite(h["loss"]) for h in out["history"])


def test_loop_churn_restart_reproduces_golden_run(tmp_path):
    """The acceptance scenario: a seeded churn run that kills a chunk
    of the fleet mid-run, checkpoints (incl. worker process + health
    bookkeeping), restarts, and must land BIT-exactly where the
    uninterrupted run lands."""
    import shutil
    from repro.train.loop import train
    churn = _ecfg("churn", p_fail=0.5, p_recover=0.3, seed=9)

    d = str(tmp_path / "ckpt")
    model, rc, lc = _loop_fixture(churn, n_steps=12, ckpt_dir=d,
                                  ckpt_every=6)
    out_full = train(model, rc, lc)            # uninterrupted
    leaves_full = [np.asarray(x) for x in
                   jax.tree.leaves(out_full["state"])]
    shutil.rmtree(d)

    model, rc, lc6 = _loop_fixture(churn, n_steps=6, ckpt_dir=d,
                                   ckpt_every=6)
    train(model, rc, lc6)                      # first half + checkpoint
    model, rc, lc12 = _loop_fixture(churn, n_steps=12, ckpt_dir=d,
                                    ckpt_every=6)
    out_resumed = train(model, rc, lc12)       # restore + second half
    for a, b in zip(leaves_full, jax.tree.leaves(out_resumed["state"])):
        np.testing.assert_array_equal(a, np.asarray(b))


# ---------------------------------------------------------------------------
# masked gossip (decentralized)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topology,n", [("ring", 8), ("torus", 4),
                                        ("complete", 6)])
def test_masked_fold_tracks_masked_matrix_oracle(topology, n):
    """r masked fold rounds == the masked-matrix power: dead sources'
    weight reroutes to each receiver's self term, rows sum to 1."""
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((n, 16)).astype(np.float32))
    active = (rng.uniform(size=n) < 0.7).astype(np.float64)
    if active.sum() == 0:
        active[0] = 1.0
    r = 5
    out = consensus.run_consensus_fold_masked(
        v, topology, r, jnp.asarray(active, jnp.float32))
    Q = consensus.gossip_matrix(topology, n)
    Qe = np.zeros_like(Q)
    for i in range(n):
        for j in range(n):
            if i != j:
                Qe[i, j] = Q[i, j] * active[j]
        Qe[i, i] = Q[i, i] + sum(Q[i, j] * (1.0 - active[j])
                                 for j in range(n) if j != i)
    np.testing.assert_allclose(Qe.sum(axis=1), np.ones(n), atol=1e-12)
    oracle = np.linalg.matrix_power(Qe, r) @ np.asarray(v, np.float64)
    np.testing.assert_allclose(np.asarray(out), oracle, rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("topology,n", [("ring", 8), ("torus", 4),
                                        ("complete", 6)])
def test_masked_fold_all_alive_degenerates_bitwise(topology, n):
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.standard_normal((n, 8, 16)).astype(np.float32))
    masked = consensus.run_consensus_fold_masked(
        v, topology, 4, jnp.ones((n,), jnp.float32))
    plain = consensus.run_consensus_fold(v, topology, 4)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(plain))


def test_masked_consensus_error_ignores_dead_workers():
    v = jnp.asarray(np.array([[1.0, 1.0], [100.0, -3.0], [1.0, 1.0]],
                             np.float32))
    active = jnp.asarray(np.array([1.0, 0.0, 1.0], np.float32))
    err = consensus.consensus_error_masked(v, active)
    assert float(err) == 0.0                   # alive workers agree
    err_all = consensus.consensus_error_masked(v, jnp.ones(3))
    assert float(err_all) > 1.0
    # all-dead: exact zero, not NaN
    assert float(consensus.consensus_error_masked(v, jnp.zeros(3))) == 0.0


def _dec_rc(elastic, n=4, **consensus_kw):
    kw = dict(topology="ring", n_workers=n, rounds=3,
              gossip_impl="dense")
    kw.update(consensus_kw)
    return RunConfig(
        model=CFG,
        shape=dataclasses.replace(TRAIN_4K, seq_len=0, global_batch=32),
        mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(tau=1, n_microbatches=2, b_bar=32.0,
                          smoothness_L=1.0),
        strategy="decentralized",
        consensus=ConsensusConfig(**kw),
        elastic=elastic)


def test_decentralized_elastic_rejects_int8_compression():
    import repro.api as api
    from repro.models import build_model
    with pytest.raises(ValueError, match="int8"):
        api.build(build_model(CFG),
                  _dec_rc(_ecfg("churn"), compression="int8"))


def test_decentralized_step_requires_active_mask():
    import repro.api as api
    from repro.models import build_model
    model = build_model(CFG)
    s = api.build(model, _dec_rc(_ecfg("churn")))
    assert s.consumes_active_mask
    state = s.init_state(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="active"):
        s.train_step(state, model.dummy_batch(32))
    # the static build does NOT consume (and must not require) a mask
    s0 = api.build(model, _dec_rc(ElasticConfig()))
    assert not s0.consumes_active_mask


def test_decentralized_masked_step_vs_dense_oracle():
    """The strategy's in-program masked gossip == the dense masked
    fold re-applied to the captured messages (bit for bit on alive
    rows), dead workers' z AND params frozen bit-identically."""
    import repro.api as api
    from repro.models import build_model
    model = build_model(CFG)
    rc = _dec_rc(_ecfg("churn", p_fail=0.3, p_recover=0.5, seed=4),
                 debug_messages=True)
    s = api.build(model, rc)
    wp = make_worker_process(rc.elastic, 4)
    state = s.init_state(jax.random.PRNGKey(0))
    step = jax.jit(s.train_step)
    oracle = jax.jit(lambda m0, a: consensus.run_consensus_fold_masked(
        m0, "ring", s.rounds, a))
    saw_dead = False
    for t in range(6):
        b = model.dummy_batch(32, key=jax.random.PRNGKey(100 + t))
        active, _ = wp.step()
        b["active"] = active.astype(np.float32)
        prev_z = np.asarray(state.z)
        prev_p = [np.asarray(x) for x in jax.tree.leaves(state.params)]
        state, m = step(state, b)
        live = active > 0
        oz = np.asarray(oracle(m["gossip_m0"], m["gossip_active"]))
        np.testing.assert_array_equal(np.asarray(state.z)[live],
                                      oz[live], err_msg=f"step {t}")
        np.testing.assert_array_equal(np.asarray(state.z)[~live],
                                      prev_z[~live], err_msg=f"step {t}")
        for p_old, p_new in zip(prev_p, jax.tree.leaves(state.params)):
            np.testing.assert_array_equal(np.asarray(p_new)[~live],
                                          p_old[~live])
        assert float(m["active_workers"]) == float(active.sum())
        saw_dead = saw_dead or (~live).any()
    assert saw_dead                             # the seed exercises churn


def test_decentralized_all_alive_elastic_is_static_path_bitwise():
    """A churn build fed the all-alive mask every step must match the
    static build bit for bit — the masked fold degenerates exactly."""
    import repro.api as api
    from repro.models import build_model
    model = build_model(CFG)
    s0 = api.build(model, _dec_rc(ElasticConfig()))
    s1 = api.build(model, _dec_rc(_ecfg("churn", seed=3)))
    st0 = s0.init_state(jax.random.PRNGKey(0))
    st1 = s1.init_state(jax.random.PRNGKey(0))
    step0 = jax.jit(s0.train_step)
    step1 = jax.jit(s1.train_step)
    for t in range(4):
        b = model.dummy_batch(32, key=jax.random.PRNGKey(200 + t))
        st0, _ = step0(st0, dict(b))
        b["active"] = np.ones(4, np.float32)
        st1, _ = step1(st1, b)
    for a, b_ in zip(jax.tree.leaves(st0), jax.tree.leaves(st1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
