"""Fault tolerance: failed workers contribute b_i=0 and training
continues; health tracking evicts persistent failures; elastic plan."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import AmbdgConfig, MeshConfig, RunConfig, TRAIN_4K
from repro.core import make_train_step
from repro.models import build_model
from repro.train.fault import WorkerHealth


def test_failed_worker_zero_weight_keeps_training():
    cfg = C.get_smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    rc = RunConfig(model=cfg,
                   shape=dataclasses.replace(TRAIN_4K, seq_len=32,
                                             global_batch=8),
                   mesh=MeshConfig(n_pods=1, data=1, model=1),
                   ambdg=AmbdgConfig(tau=0, n_microbatches=2, b_bar=8.0,
                                     smoothness_L=8.0))
    init_state, train_step = make_train_step(model, rc)
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(train_step)
    batch = model.dummy_batch(8, 32)
    # workers 0..1 own rows 0..3 / 4..7; worker 1 fails
    w = np.ones(8, np.float32)
    w[4:] = 0.0
    batch["weights"] = jnp.asarray(w)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["applied_count"]) == 4 * 31  # only worker 0
    # full failure of an epoch: zero update, no NaNs
    batch["weights"] = jnp.zeros(8, jnp.float32)
    params_before = jax.tree.leaves(state.params)[0].copy()
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])) or True  # loss is 0/0-guarded
    assert bool(jnp.all(jnp.isfinite(jax.tree.leaves(state.params)[0])))


def test_health_eviction_and_rescale_plan():
    h = WorkerHealth(4, heartbeat_timeout=1.0, eviction_misses=2)
    now = 100.0
    for i in range(4):
        h.heartbeat(i, at=now)
    # worker 2 goes silent; the others keep heartbeating
    assert h.tick(at=now + 0.5) == []
    for t in (2.0, 3.5, 5.0):
        for i in (0, 1, 3):
            h.heartbeat(i, at=now + t)
        h.tick(at=now + t)
    assert 2 in h.evicted
    assert h.needs_rescale
    plan = h.rescale_plan()
    assert plan["n_workers"] == 3 and 2 not in plan["alive"]


def test_anytime_mask_zeroes_failed():
    h = WorkerHealth(3, heartbeat_timeout=1.0)
    now = 10.0
    for i in range(3):
        h.heartbeat(i, at=now)
    h.heartbeat(0, at=now + 5)
    h.heartbeat(1, at=now + 5)
    b = np.array([10, 20, 30])
    masked = h.anytime_mask(b, at=now + 5)
    assert list(masked) == [10, 20, 0]
