"""Fault tolerance: failed workers contribute b_i=0 and training
continues; health tracking evicts persistent failures; elastic plan."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import AmbdgConfig, MeshConfig, RunConfig, TRAIN_4K
from repro.core import make_train_step
from repro.models import build_model
from repro.train.fault import WorkerHealth


def test_failed_worker_zero_weight_keeps_training():
    cfg = C.get_smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    rc = RunConfig(model=cfg,
                   shape=dataclasses.replace(TRAIN_4K, seq_len=32,
                                             global_batch=8),
                   mesh=MeshConfig(n_pods=1, data=1, model=1),
                   ambdg=AmbdgConfig(tau=0, n_microbatches=2, b_bar=8.0,
                                     smoothness_L=8.0))
    init_state, train_step = make_train_step(model, rc)
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(train_step)
    batch = model.dummy_batch(8, 32)
    # workers 0..1 own rows 0..3 / 4..7; worker 1 fails
    w = np.ones(8, np.float32)
    w[4:] = 0.0
    batch["weights"] = jnp.asarray(w)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["applied_count"]) == 4 * 31  # only worker 0
    # Full failure of an epoch: with every weight zero the applied
    # update is EXACTLY zero (the count guard makes g = 0/eps = 0), so
    # the dual z must stay bit-identical. The params still move — dual
    # averaging reapplies w = -alpha(t) z with t advanced — but only
    # through the deterministic alpha schedule on the UNCHANGED dual,
    # never through the (all-masked) batch data.
    z_before = np.asarray(state.opt_state.z).copy()
    dead = model.dummy_batch(8, 32, key=jax.random.PRNGKey(7))
    dead["weights"] = jnp.zeros(8, jnp.float32)
    state_a, metrics = step(state, dead)
    assert np.isfinite(float(metrics["loss"]))          # 0/0-guarded to 0
    assert float(metrics["applied_count"]) == 0.0
    np.testing.assert_array_equal(np.asarray(state_a.opt_state.z),
                                  z_before)
    for leaf in jax.tree.leaves(state_a.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # data-independence: the same zero-weight epoch over DIFFERENT
    # samples must produce the bit-identical state (weights mask every
    # contribution before it ever reaches the aggregation)
    other = model.dummy_batch(8, 32, key=jax.random.PRNGKey(99))
    other["weights"] = jnp.zeros(8, jnp.float32)
    state_b, _ = step(state, other)
    for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the schedule itself: a second dead epoch keeps z fixed and
    # rescales params by exactly alpha(t+1)/alpha(t) (proximal "l2":
    # w(t) = -alpha(t) z elementwise)
    state_aa, _ = step(state_a, dead)
    np.testing.assert_array_equal(np.asarray(state_aa.opt_state.z),
                                  z_before)
    from repro.core import dual_averaging as da
    t1 = float(np.asarray(state_a.opt_state.t))
    t2 = float(np.asarray(state_aa.opt_state.t))
    ratio = (np.float32(da.alpha(jnp.float32(t2 + 1.0), rc.ambdg))
             / np.float32(da.alpha(jnp.float32(t1 + 1.0), rc.ambdg)))
    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_aa.params)):
        np.testing.assert_allclose(np.asarray(b),
                                   np.asarray(a) * ratio,
                                   rtol=1e-6, atol=1e-8)


def test_health_eviction_and_rescale_plan():
    h = WorkerHealth(4, heartbeat_timeout=1.0, eviction_misses=2)
    now = 100.0
    for i in range(4):
        h.heartbeat(i, at=now)
    # worker 2 goes silent; the others keep heartbeating
    assert h.tick(at=now + 0.5) == []
    for t in (2.0, 3.5, 5.0):
        for i in (0, 1, 3):
            h.heartbeat(i, at=now + t)
        h.tick(at=now + t)
    assert 2 in h.evicted
    assert h.needs_rescale
    plan = h.rescale_plan()
    assert plan["n_workers"] == 3 and 2 not in plan["alive"]


def test_heartbeat_from_evicted_worker_is_ignored():
    """Eviction is explicit: a zombie heartbeat from an evicted worker
    must not silently resurrect it — it is dropped and counted."""
    h = WorkerHealth(3, heartbeat_timeout=0.5, eviction_misses=2, t0=0.0)
    for t in (1.0, 2.0):
        h.heartbeat(0, at=t)
        h.heartbeat(1, at=t)
        h.tick(at=t)
    assert h.evicted == {2}
    assert h.ignored_heartbeats == 0
    assert h.heartbeat(2, at=2.0) is False
    assert h.heartbeat(2, at=2.1) is False
    assert h.ignored_heartbeats == 2
    assert h.evicted == {2}                      # still out
    assert h.missed[2] >= 2                      # untouched by zombies
    # live workers are unaffected
    assert h.heartbeat(0, at=2.2) is True
    assert h.ignored_heartbeats == 2


def test_readmit_restores_worker():
    """readmit() is the explicit recovery path: fresh liveness state,
    heartbeats accepted again, rescale plan includes the worker."""
    h = WorkerHealth(2, heartbeat_timeout=0.5, eviction_misses=1, t0=0.0)
    h.heartbeat(0, at=1.0)
    h.tick(at=1.0)
    assert h.evicted == {1}
    assert h.rescale_plan()["alive"] == [0]
    h.readmit(1, at=1.0)
    assert h.evicted == set() and h.missed[1] == 0
    assert not h.needs_rescale
    assert h.heartbeat(1, at=1.2) is True
    assert h.rescale_plan()["alive"] == [0, 1]
    # state_dict round-trips the eviction bookkeeping (string keys —
    # the checkpoint manifest is JSON)
    h.heartbeat(0, at=5.0)
    h.tick(at=5.0)                               # evicts 1 again
    assert h.evicted == {1}
    import json
    sd = json.loads(json.dumps(h.state_dict()))
    h2 = WorkerHealth(2, heartbeat_timeout=0.5, eviction_misses=1, t0=0.0)
    h2.load_state_dict(sd)
    assert h2.evicted == h.evicted
    assert h2.missed == h.missed
    assert h2.last_seen == h.last_seen
    assert h2.ignored_heartbeats == h.ignored_heartbeats


def test_anytime_mask_zeroes_failed():
    h = WorkerHealth(3, heartbeat_timeout=1.0)
    now = 10.0
    for i in range(3):
        h.heartbeat(i, at=now)
    h.heartbeat(0, at=now + 5)
    h.heartbeat(1, at=now + 5)
    b = np.array([10, 20, 30])
    masked = h.anytime_mask(b, at=now + 5)
    assert list(masked) == [10, 20, 0]
