"""Unit tests for the HLO text probes (``repro.launch.hlo``) against
captured optimized-HLO fixtures.

The fixtures are REAL lines captured from compiled matrix cells at two
mesh sizes (8 devices: qwen1.5 on a 2x2x2 mesh; 512 devices: chatglm
on a 2x16x16 mesh), covering both ``replica_groups`` text forms XLA
emits — the iota form ``[n,g]<=[dims]`` with and without a transpose
suffix ``T(...)``, and the explicit ``{{...},...}`` form — plus a
``collective-permute`` with ``source_target_pairs`` and copies with
and without source metadata.  ``synthetic_edge.txt`` hand-authors the
two forms the captures never produced (an async ``copy-start`` tuple
and a ``reduce-scatter``) in the same format.

Every expected number below is hand-computed from the ring formulas in
``hlo.collective_bytes``'s docstring, so a parser regression shows up
as a wrong byte count, not just a changed count.
"""
import os

import pytest

from repro.launch.hlo import (HloParseError, collective_bytes,
                              collective_bytes_by_dtype, copy_bytes,
                              copy_records, copy_shapes)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "hlo")


def fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


# ---------------------------------------------------------------------------
# 8-device capture: iota-with-transpose + explicit groups + permute + a2a
# ---------------------------------------------------------------------------
class TestMesh8:
    def test_collective_census(self):
        text = fixture("mesh8_train.txt")
        got = collective_bytes(text, strict=True)
        # ag.124: f32[64]=256 B over [4,2] iota groups (n=2) -> 128
        # ag.123: f32[256,32]=32768 B over {{0,4},...} (n=2)  -> 16384
        assert got["all-gather"] == 128 + 16384
        # ar.34: f32[1,1,128]=512 B over {{0,1,2,3},...} (n=4)
        #        -> 2*(4-1)*512//4 = 768
        assert got["all-reduce"] == 768
        # cp.97: f32[1,4,128,64]=131072 B, permute moves P
        assert got["collective-permute"] == 131072
        # a2a.13: tuple result 2*f32[1,32,1,48]=12288 B (n=2) -> 6144
        assert got["all-to-all"] == 6144
        assert got["reduce-scatter"] == 0
        assert got["count"] == 5

    def test_by_dtype_matches_total(self):
        text = fixture("mesh8_train.txt")
        by_dtype = collective_bytes_by_dtype(text, strict=True)
        assert by_dtype == {"f32": 128 + 16384 + 768 + 131072 + 6144}

    def test_copy_census(self):
        text = fixture("mesh8_train.txt")
        # two residual layout copies f32[1,192,128]=98304 B each,
        # one dot_general operand copy f32[48,64]=12288 B
        assert copy_shapes(text) == {"f32[1,192,128]": 2, "f32[48,64]": 1}
        assert copy_bytes(text) == 2 * 98304 + 12288

    def test_copy_record_metadata(self):
        recs = list(copy_records(fixture("mesh8_train.txt")))
        assert len(recs) == 3
        residual = [r for r in recs if r["op_name"] == "state.arena.residual"]
        assert len(residual) == 2
        # pure layout copies of an input parameter carry the parameter
        # name and NO source location
        assert all(r["source_file"] is None for r in residual)
        assert residual[0]["operand"].endswith("%param_3.614")
        (other,) = [r for r in recs if r not in residual]
        assert other["source_file"].endswith("layers.py")
        assert other["source_line"] == 298
        assert other["bytes"] == 12288


# ---------------------------------------------------------------------------
# 512-device capture: large iota groups, s8 payload on the DCN edge
# ---------------------------------------------------------------------------
class TestMesh512:
    def test_collective_census(self):
        text = fixture("mesh512_train.txt")
        got = collective_bytes(text, strict=True)
        # ag.19: s8[2,3,128]=768 B over [256,2] iota groups (n=2) -> 384
        assert got["all-gather"] == 384
        # ar.631: f32[1,16,128]=8192 B over [32,16] iota (n=16)
        #         -> 2*15*8192//16 = 15360
        assert got["all-reduce"] == 15360
        assert got["count"] == 2

    def test_by_dtype_separates_compressed_payload(self):
        by_dtype = collective_bytes_by_dtype(fixture("mesh512_train.txt"),
                                             strict=True)
        assert by_dtype == {"s8": 384, "f32": 15360}


# ---------------------------------------------------------------------------
# Hand-authored forms the captures never produced
# ---------------------------------------------------------------------------
class TestSyntheticEdge:
    def test_copy_start_tuple_result(self):
        text = fixture("synthetic_edge.txt")
        # copy-start result is (dest, src, context): every typed shape
        # in the tuple is censused
        shapes = copy_shapes(text)
        assert shapes == {"f32[2,24,128]": 2, "u32[]": 1}
        assert copy_bytes(text) == 2 * 24576 + 4

    def test_copy_start_records(self):
        recs = list(copy_records(fixture("synthetic_edge.txt")))
        assert {r["op_name"] for r in recs} == {"state.arena.ring"}
        assert all(r["operand"].endswith("%param.5") for r in recs)

    def test_reduce_scatter(self):
        got = collective_bytes(fixture("synthetic_edge.txt"), strict=True)
        # rs.5: result f32[32,128]=16384 B over [8,8] iota (n=8)
        #       -> input (n*result) counted (n-1)/n: 7*16384 = 114688
        assert got["reduce-scatter"] == 114688


# ---------------------------------------------------------------------------
# Strict mode: raise instead of silently deflating the census
# ---------------------------------------------------------------------------
GARBAGE_GROUPS = ("  %all-reduce.1 = f32[128]{0} all-reduce(f32[128]{0} %x),"
                  " replica_groups=bogus, to_apply=%add\n")
EMPTY_GROUPS = ("  %all-reduce.1 = f32[128]{0} all-reduce(f32[128]{0} %x),"
                " replica_groups={}, to_apply=%add\n")
UNKNOWN_DTYPE = ("  %all-gather.1 = qq8[64]{0} all-gather(qq8[32]{0} %x),"
                 " replica_groups=[4,2]<=[8], dimensions={0}\n")


class TestStrictMode:
    @pytest.mark.parametrize("text", [GARBAGE_GROUPS, EMPTY_GROUPS],
                             ids=["garbage", "empty"])
    def test_unrecognized_replica_groups_raises(self, text):
        with pytest.raises(HloParseError, match="replica_groups"):
            collective_bytes(text, strict=True)
        with pytest.raises(HloParseError, match="replica_groups"):
            collective_bytes_by_dtype(text, strict=True)

    def test_zero_byte_region_raises(self):
        with pytest.raises(HloParseError, match="0 bytes"):
            collective_bytes(UNKNOWN_DTYPE, strict=True)
        with pytest.raises(HloParseError, match="0 bytes"):
            collective_bytes_by_dtype(UNKNOWN_DTYPE, strict=True)

    def test_non_strict_degrades_softly(self):
        # the pre-strict behavior the report paths still rely on:
        # unparsed groups count as n=1 (an all-reduce becomes
        # wire-free), an unknown dtype as 0 bytes
        assert collective_bytes(GARBAGE_GROUPS)["all-reduce"] == 0
        assert collective_bytes(UNKNOWN_DTYPE)["all-gather"] == 0

    def test_source_target_pairs_exempt(self):
        # a collective-permute legitimately has no replica_groups
        text = fixture("mesh8_train.txt")
        got = collective_bytes(text, strict=True)  # must not raise
        assert got["collective-permute"] == 131072
