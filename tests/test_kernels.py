"""Pallas kernel allclose tests vs pure-jnp oracles (interpret=True on
CPU), with shape/dtype sweeps per the deliverable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.delay_ring.ops import ring_push_pop, ring_push_pop_ref
from repro.kernels.dual_update.ops import dual_update, dual_update_arena
from repro.kernels.dual_update.ref import dual_update_fused_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.linear_scan.ops import linear_scan, ssd_mamba2
from repro.kernels.linear_scan.ref import linear_scan_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,D,causal,window",
    [
        (2, 4, 2, 256, 256, 64, True, None),
        (1, 8, 8, 128, 128, 128, True, None),
        (2, 4, 1, 256, 512, 64, True, None),      # MQA, right-aligned q
        (1, 4, 2, 256, 256, 64, True, 128),       # sliding window
        (1, 2, 2, 128, 256, 64, False, None),     # bidirectional
    ])
def test_flash_attention(B, Hq, Hkv, Sq, Skv, D, causal, window, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(keys[1], (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(keys[2], (B, Hkv, Skv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,BHG,S,ds,hd,chunk", [
    (4, 4, 256, 32, 64, 128),
    (6, 2, 256, 16, 32, 64),     # grouped B/C (GQA-style broadcast)
    (2, 2, 512, 64, 64, 128),
])
def test_linear_scan(BH, BHG, S, ds, hd, chunk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    g = (-jnp.abs(jax.random.normal(keys[0], (BH, S))) * 0.1).astype(
        jnp.float32)
    q = jax.random.normal(keys[1], (BHG, S, ds), dtype)
    k = (jax.random.normal(keys[2], (BHG, S, ds), dtype) * 0.1).astype(dtype)
    v = jax.random.normal(keys[3], (BH, S, hd), dtype)
    out = linear_scan(g, q, k, v, chunk=chunk, interpret=True)
    ref = linear_scan_ref(g, q, k, v)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32)))) / scale
    assert err < tol


def test_ssd_mamba2_matches_model_path():
    """Kernel == the model's XLA ssd_chunked (the integration contract)."""
    from repro.models.ssm import ssd_chunked
    Bt, S, nh, hd, g, ds = 2, 256, 4, 32, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(keys[0], (Bt, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, S, nh)))
    A = -jnp.exp(jax.random.normal(keys[2], (nh,)))
    B = jax.random.normal(keys[3], (Bt, S, g, ds)) * 0.2
    Cm = jax.random.normal(keys[4], (Bt, S, g, ds)) * 0.2
    y_kernel = ssd_mamba2(x, dt, A, B, Cm, chunk=64, interpret=True)
    y_xla, _ = ssd_chunked(x, dt, A, B, Cm, 64)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_xla),
                               atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("shapes", [
    [(7,)], [(128,)], [(10, 100), (77,), (3, 5, 7)],
])
def test_dual_update(shapes):
    rng = np.random.default_rng(0)
    z = {f"p{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
         for i, s in enumerate(shapes)}
    g = {f"p{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
         for i, s in enumerate(shapes)}
    alpha = 0.37
    z_ref = jax.tree.map(lambda a, b: a + b, z, g)
    w_ref = jax.tree.map(lambda a: -alpha * a, z_ref)
    z2, w2 = dual_update(jax.tree.map(jnp.copy, z), g, alpha,
                         interpret=True)
    for kk in z:
        np.testing.assert_allclose(np.asarray(z2[kk]), np.asarray(z_ref[kk]),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(w2[kk]), np.asarray(w_ref[kk]),
                                   rtol=1e-6)


@pytest.mark.parametrize("head", [0, 1, 2])
@pytest.mark.parametrize("tau,n_pods,rows", [(3, 2, 256), (1, 1, 512)])
def test_delay_ring_kernel_f32(tau, n_pods, rows, head):
    """Pallas slot rotation == jnp oracle, untouched slots retained
    (the aliasing passthrough contract)."""
    if head >= tau:
        pytest.skip("head out of range")
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    ring = jax.random.normal(keys[0], (tau, n_pods, rows, 128), jnp.float32)
    g = jax.random.normal(keys[1], (n_pods, rows, 128), jnp.float32)
    h = jnp.int32(head)
    popped, ring_new, _, _ = ring_push_pop(ring, g, h, impl="pallas",
                                           interpret=True)
    popped_r, ring_r, _, _ = ring_push_pop_ref(ring, g, h)
    np.testing.assert_array_equal(np.asarray(popped), np.asarray(popped_r))
    np.testing.assert_array_equal(np.asarray(ring_new), np.asarray(ring_r))


@pytest.mark.parametrize("head", [0, 2])
def test_delay_ring_kernel_int8(head):
    tau, n_pods, rows = 3, 2, 256
    keys = jax.random.split(jax.random.PRNGKey(1), 5)
    ring = jax.random.randint(keys[0], (tau, n_pods, rows, 128), -127, 128,
                              jnp.int8)
    scales = jax.random.uniform(keys[1], (tau, n_pods, rows)) + 0.01
    # the int8 contract takes the already error-fed gradient
    fed = (jax.random.normal(keys[3], (n_pods, rows, 128), jnp.float32)
           + 0.1 * jax.random.normal(keys[2], (n_pods, rows, 128)))
    scale_new = jax.random.uniform(keys[4], (n_pods, rows)) + 0.01
    h = jnp.int32(head)
    outs = ring_push_pop(ring, fed, h, scales=scales,
                         scale_new=scale_new, impl="pallas", interpret=True)
    refs = ring_push_pop_ref(ring, fed, h, scales=scales,
                             scale_new=scale_new)
    # popped payload, int8 ring and scales must be identical
    for o, r in zip(outs[:3], refs[:3]):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r))
    # residual: fed - q*s may fuse into an FMA in one lowering and not
    # the other -> 1-ULP differences are allowed
    # (atol ~ 1 ULP of fed, not of the tiny residual remainder)
    np.testing.assert_allclose(np.asarray(outs[3]), np.asarray(refs[3]),
                               rtol=1e-6, atol=2.5e-7)


def test_dual_update_arena_fused():
    """Fused count-normalizing kernel == oracle, incl. count=0 guard."""
    rows = 512
    keys = jax.random.split(jax.random.PRNGKey(2), 2)
    z = jax.random.normal(keys[0], (rows, 128), jnp.float32)
    g = jax.random.normal(keys[1], (rows, 128), jnp.float32)
    for count in (7.0, 0.0):
        z_k, w_k = dual_update_arena(z, g, jnp.float32(count),
                                     jnp.float32(0.37),
                                     impl="pallas", interpret=True)
        z_r, w_r = dual_update_fused_ref(
            z, g, jnp.maximum(jnp.float32(count), 1e-12), jnp.float32(0.37))
        np.testing.assert_allclose(np.asarray(z_k), np.asarray(z_r),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r),
                                   rtol=1e-6)
        assert bool(jnp.all(jnp.isfinite(w_k)))


def test_mlstm_chunked_matches_recurrence():
    """Chunk-parallel mLSTM == naive stabilized recurrence."""
    from repro.models.xlstm import mlstm_sequence
    B, S, nh, hd = 2, 64, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(keys[0], (B, S, nh, hd))
    k = jax.random.normal(keys[1], (B, S, nh, hd)) * 0.3
    v = jax.random.normal(keys[2], (B, S, nh, hd))
    logf = jax.nn.log_sigmoid(jax.random.normal(keys[3], (B, S, nh)) + 2)
    logi = jax.random.normal(keys[4], (B, S, nh)) * 0.5

    y_chunk = mlstm_sequence(q, k, v, logf, logi, chunk=16)

    # naive recurrence
    C = np.zeros((B, nh, hd, hd)); n = np.zeros((B, nh, hd))
    m = np.full((B, nh), -1e30)
    ys = np.zeros((B, S, nh, hd))
    qn, kn, vn = map(np.asarray, (q, k, v))
    lf, li = np.asarray(logf), np.asarray(logi)
    for t in range(S):
        m_new = np.maximum(lf[:, t] + m, li[:, t])
        fw = np.exp(lf[:, t] + m - m_new)
        iw = np.exp(li[:, t] - m_new)
        C = C * fw[..., None, None] + np.einsum(
            "bhd,bhe,bh->bhde", kn[:, t], vn[:, t], iw)
        n = n * fw[..., None] + kn[:, t] * iw[..., None]
        m = m_new
        qs = qn[:, t] / np.sqrt(hd)
        num = np.einsum("bhd,bhde->bhe", qs, C)
        den = np.maximum(np.abs(np.einsum("bhd,bhd->bh", qs, n)),
                         np.exp(-m))
        ys[:, t] = num / den[..., None]
    np.testing.assert_allclose(np.asarray(y_chunk), ys, atol=2e-4,
                               rtol=1e-3)
