"""Flash-attention backward kernel vs jax.grad of the reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.bwd import (flash_attention_train,
                                               flash_fwd_lse)
from repro.kernels.flash_attention.ref import attention_ref


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,causal,window", [
    (1, 2, 2, 128, 128, 64, True, None),
    (1, 4, 2, 128, 128, 64, True, None),       # GQA group-sum in dkv
    (1, 2, 1, 128, 256, 64, True, None),       # MQA, right-aligned q
    (1, 2, 2, 128, 128, 64, True, 64),         # sliding window
    (1, 2, 2, 128, 128, 64, False, None),      # bidirectional
])
def test_bwd_matches_reference(B, Hq, Hkv, Sq, Skv, D, causal, window):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(keys[1], (B, Hkv, Skv, D), jnp.float32)
    v = jax.random.normal(keys[2], (B, Hkv, Skv, D), jnp.float32)

    def loss_kernel(q, k, v):
        o = flash_attention_train(q, k, v, causal, window, 64, 64, True)
        return jnp.sum(o * jnp.cos(o))          # nontrivial cotangent

    def loss_ref(q, k, v):
        o = attention_ref(q, k, v, causal=causal, window=window)
        return jnp.sum(o * jnp.cos(o))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3, err_msg=name)


def test_fwd_lse_matches_plain_fwd():
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (1, 2, 128, 64))
    k = jax.random.normal(keys[1], (1, 2, 128, 64))
    v = jax.random.normal(keys[2], (1, 2, 128, 64))
    o, lse = flash_fwd_lse(q, k, v, causal=True, window=None,
                           scale=64 ** -0.5, block_q=64, block_k=64,
                           interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)
    # lse sanity: softmax weights recomputed from lse sum to 1
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(64)
    mask = np.tril(np.ones((128, 128), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - lse[..., None])
    np.testing.assert_allclose(np.asarray(p.sum(-1)),
                               np.ones((1, 2, 128)), atol=1e-4, rtol=1e-4)
