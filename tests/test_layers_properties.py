"""Property tests for the layer substrate: RoPE isometry/relativity,
mask algebra, chunked-attention equivalence."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.layers import (apply_rope, causal_mask, chunked_gqa_attend,
                                 gqa_attend, prefix_lm_mask)


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 10**6), st.sampled_from([0.5, 1.0]))
def test_rope_preserves_norm(seed, partial):
    """Rotations are isometries: per-head vector norms are unchanged."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8)
    y = apply_rope(x, pos, theta=10000.0, partial=partial)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 10**6))
def test_rope_relative_property(seed):
    """<rope(q,i), rope(k,j)> depends only on i - j (full rotation)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)

    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([i]), 10000.0)
        kj = apply_rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(100, 100)) < 1e-4


def test_mask_algebra():
    m = np.asarray(causal_mask(6, 6))
    assert m[3, 3] and m[3, 2] and not m[3, 4]
    w = np.asarray(causal_mask(6, 6, window=2))
    assert w[3, 2] and not w[3, 1]          # window excludes older
    p = np.asarray(prefix_lm_mask(6, 6, 3))
    assert p[0, 2] and p[2, 0]              # bidirectional in prefix
    assert p[3, 4] == False and p[4, 3]     # causal after
    # offset consistency: rows of the offset mask == rows of the full mask
    full = np.asarray(causal_mask(8, 8, window=3))
    part = np.asarray(causal_mask(4, 8, window=3, q_offset=4))
    np.testing.assert_array_equal(part, full[4:])


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 10**6), st.sampled_from([32, 64]))
def test_chunked_attention_equivalence(seed, chunk):
    keys = jax.random.split(jax.random.PRNGKey(seed % 2**31), 3)
    B, S, Hq, Hkv, D = 1, 128, 4, 2, 16
    q = jax.random.normal(keys[0], (B, S, Hq, D))
    k = jax.random.normal(keys[1], (B, S, Hkv, D))
    v = jax.random.normal(keys[2], (B, S, Hkv, D))
    mask_fn = lambda off, qn: causal_mask(qn, S, window=48, q_offset=off)
    full = gqa_attend(q, k, v, mask_fn(0, S))
    ck = chunked_gqa_attend(q, k, v, mask_fn, chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ck),
                               atol=2e-5, rtol=1e-4)


def test_persistent_speeds_tail():
    """Persistent stragglers (the SciNet regime) produce a heavier
    K-batch staleness tail than per-job redraw (Fig. 4 fidelity)."""
    from repro.data.timing import PersistentWorkerSpeeds, ShiftedExponential
    base = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    pw = PersistentWorkerSpeeds(base, 10, seed=3)
    rng = np.random.default_rng(0)
    # persistent: same speeds every draw
    a = pw.sample_times(rng, 10)
    b = pw.sample_times(rng, 10)
    np.testing.assert_array_equal(a, b)
    # per_worker_time consistent with the drawn speed
    assert pw.per_worker_time(0, 60) == a[0]
