"""The scenario-matrix harness contracts (docs/matrix.md):

  * the no-clobber ``XLA_FLAGS`` device-count contract of
    ``repro.launch.xla`` — the PR-10 bugfix: importing the dry-run (or
    any benchmark) must never override a count the caller pinned;
  * ``resolve_cell_rc``'s explicit-only ``tau_max`` override (an
    explicit 0 is a value, not "unset");
  * ``parse_mesh`` / ``mesh_label`` roundtrips;
  * the closed-form wire models the matrix invariants compare the
    strict HLO census against (hand-computed expectations);
  * (slow) the end-to-end subprocess regressions: an import with the
    flag already pinned leaves the device count alone, and one real
    8-device matrix cell passes all three invariants.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import wire_model
from repro.launch.xla import (ENV_VAR, FLAG,
                              ensure_host_platform_device_count,
                              pinned_host_device_count,
                              without_host_device_flag)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# XLA_FLAGS no-clobber contract (the import-time bugfix)
# ---------------------------------------------------------------------------
class TestEnsureHostDeviceCount:
    @pytest.fixture(autouse=True)
    def clean_env(self):
        # explicit snapshot/restore: the tests (and the function under
        # test) write os.environ directly, which monkeypatch.delenv on
        # an ABSENT var would not roll back
        saved = {k: os.environ.get(k) for k in ("XLA_FLAGS", ENV_VAR)}
        for k in saved:
            os.environ.pop(k, None)
        yield
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v

    def test_default_appended_once(self):
        assert ensure_host_platform_device_count(default=64) == 64
        assert os.environ["XLA_FLAGS"] == f"{FLAG}=64"
        # idempotent: a second call appends nothing
        assert ensure_host_platform_device_count(default=64) == 64
        assert os.environ["XLA_FLAGS"].count(FLAG) == 1

    def test_preexisting_flag_wins_and_is_never_rewritten(self):
        os.environ["XLA_FLAGS"] = f"--xla_cpu_foo=1 {FLAG}=48"
        # the pre-PR-10 clobber: this used to append =512 (and XLA
        # takes the LAST occurrence)
        assert ensure_host_platform_device_count(default=512) == 48
        assert os.environ["XLA_FLAGS"] == f"--xla_cpu_foo=1 {FLAG}=48"

    def test_env_var_injects_count(self):
        os.environ[ENV_VAR] = "128"
        assert ensure_host_platform_device_count(default=512) == 128
        assert pinned_host_device_count() == 128

    def test_explicit_count_beats_env_var(self):
        os.environ[ENV_VAR] = "128"
        assert ensure_host_platform_device_count(32, default=512) == 32

    def test_conflicting_request_raises(self):
        os.environ["XLA_FLAGS"] = f"{FLAG}=48"
        with pytest.raises(ValueError, match="already pinned"):
            ensure_host_platform_device_count(64)
        os.environ[ENV_VAR] = "64"
        with pytest.raises(ValueError, match=ENV_VAR):
            ensure_host_platform_device_count()
        # a MATCHING request is not a conflict
        os.environ[ENV_VAR] = "48"
        assert ensure_host_platform_device_count() == 48

    def test_last_occurrence_wins(self):
        # XLA's own precedence, mirrored by the probe
        assert pinned_host_device_count(f"{FLAG}=8 {FLAG}=64") == 64
        assert pinned_host_device_count("--xla_cpu_foo=1") is None

    def test_without_host_device_flag(self):
        flags = f"--xla_cpu_foo=1 {FLAG}=8 --bar=2 {FLAG}=64"
        assert without_host_device_flag(flags) == "--xla_cpu_foo=1 --bar=2"
        assert without_host_device_flag("") == ""
        # only the exact flag token is removed
        assert without_host_device_flag("--bar=2") == "--bar=2"


# ---------------------------------------------------------------------------
# resolve_cell_rc: explicit-only tau_max override
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dryrun():
    """Import ``launch.dryrun`` without leaking its import-time flag
    append into this test process's env (jax's backend reads XLA_FLAGS
    lazily, so restoring before any jax computation keeps the suite on
    the single real CPU device)."""
    saved = os.environ.get("XLA_FLAGS")
    try:
        import repro.launch.dryrun as dr
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved
    return dr


class TestResolveCellRcTauMax:
    def _rc(self, dryrun, tau_max):
        import dataclasses
        rc = dryrun.build_run_config("qwen1.5-0.5b", "train_4k", False)
        return rc.replace(delay=dataclasses.replace(
            rc.delay, tau_max=tau_max))

    def test_none_keeps_rc_value(self, dryrun):
        rc = self._rc(dryrun, 2)
        out = dryrun.resolve_cell_rc("qwen1.5-0.5b", "train_4k", False,
                                     rc=rc, delay_process="jitter",
                                     tau_max=None)
        assert out.delay.tau_max == 2
        assert out.delay.process == "jitter"

    def test_explicit_zero_is_a_value(self, dryrun):
        # the pre-PR-10 `tau_max or rc.delay.tau_max or 4` turned an
        # explicit 0 into the default
        rc = self._rc(dryrun, 2)
        out = dryrun.resolve_cell_rc("qwen1.5-0.5b", "train_4k", False,
                                     rc=rc, delay_process="jitter",
                                     tau_max=0)
        assert out.delay.tau_max == 0

    def test_explicit_value_verbatim(self, dryrun):
        rc = self._rc(dryrun, 2)
        out = dryrun.resolve_cell_rc("qwen1.5-0.5b", "train_4k", False,
                                     rc=rc, delay_process="heavy_tail",
                                     tau_max=7)
        assert out.delay.tau_max == 7

    def test_unset_rc_falls_back_to_default(self, dryrun):
        rc = self._rc(dryrun, 0)   # 0 in the rc itself means unset
        out = dryrun.resolve_cell_rc("qwen1.5-0.5b", "train_4k", False,
                                     rc=rc, delay_process="jitter",
                                     tau_max=None)
        assert out.delay.tau_max == 4

    def test_fixed_delay_leaves_rc_alone(self, dryrun):
        rc = self._rc(dryrun, 2)
        out = dryrun.resolve_cell_rc("qwen1.5-0.5b", "train_4k", False,
                                     rc=rc)
        assert out.delay is rc.delay


# ---------------------------------------------------------------------------
# parse_mesh / mesh_label
# ---------------------------------------------------------------------------
class TestParseMesh:
    def test_roundtrip(self):
        from repro.launch.mesh import mesh_label, parse_mesh
        for spec in ("16x16", "8x8", "2x16x16", "2x4x8", "8x16"):
            assert mesh_label(parse_mesh(spec)) == spec

    def test_pod_one_collapses(self):
        from repro.launch.mesh import mesh_label, parse_mesh
        cfg = parse_mesh("1x8x8")
        assert cfg == parse_mesh("8x8")
        assert mesh_label(cfg) == "8x8"

    def test_factors(self):
        from repro.launch.mesh import parse_mesh
        cfg = parse_mesh("2x4x8")
        assert (cfg.n_pods, cfg.data, cfg.model) == (2, 4, 8)

    @pytest.mark.parametrize("bad", ["abc", "8", "2x2x2x2", "0x8", "8x-1"])
    def test_rejects(self, bad):
        from repro.launch.mesh import parse_mesh
        with pytest.raises(ValueError):
            parse_mesh(bad)


# ---------------------------------------------------------------------------
# closed-form wire models (hand-computed, integer floor division)
# ---------------------------------------------------------------------------
class TestWireModel:
    def test_master_uncompressed(self):
        # psum of the f32 (96,128) slot over 2 pods:
        # 2*(2-1)*49152//2 = 49152
        got = wire_model.master_pod_exchange_bytes(96, 2, "none")
        assert got == {"f32": 49152}

    def test_master_int8(self):
        # s8 all-gather of (2,96,128): (2-1)*24576//2 = 12288
        # f32 scales all-gather of (2,96): (2-1)*768//2 = 384
        got = wire_model.master_pod_exchange_bytes(96, 2, "int8")
        assert got == {"s8": 12288, "f32": 384}

    def test_variable_psum(self):
        # one f32 psum regardless of compression:
        # 2*(4-1)*(96*128*4)//4 = 73728
        got = wire_model.variable_pod_exchange_bytes(96, 4)
        assert got == {"f32": 73728}

    def test_publish_pop(self):
        # s8 snapshot: (8-1)*(96*128)//8 = 10752
        # u16 scale bits: (8-1)*(96*2)//8 = 168
        got = wire_model.publish_pop_bytes(96, 8)
        assert got == {"s8": 10752, "u16": 168}

    def test_single_pod_is_wire_free(self):
        assert wire_model.master_pod_exchange_bytes(96, 1, "int8") == {}
        assert wire_model.variable_pod_exchange_bytes(96, 1) == {}
        assert wire_model.publish_pop_bytes(96, 1) == {}

    def test_gossip_split_sums_to_consensus_total(self):
        from repro.core import consensus
        for comp in ("none", "int8"):
            split = wire_model.gossip_round_bytes("ring", 8, 96,
                                                  compression=comp)
            assert sum(split.values()) == consensus.payload_bytes_per_round(
                "ring", 8, 96, compression=comp)


# ---------------------------------------------------------------------------
# subprocess regressions (slow tier): the bug this PR fixes, end to end
# ---------------------------------------------------------------------------
def _sub_env(**extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    env.pop(ENV_VAR, None)
    env.update(extra)
    return env


@pytest.mark.slow
def test_import_with_pinned_flag_keeps_device_count():
    """The acceptance regression: importing ``repro.launch.dryrun``
    with the flag already pinned used to append ``=512`` (and XLA
    takes the last occurrence); the backend must now initialize with
    the caller's count."""
    code = ("import os, jax\n"
            "import repro.launch.dryrun as d\n"
            "print(d.HOST_DEVICES, jax.device_count(),"
            " os.environ['XLA_FLAGS'].count("
            "'--xla_force_host_platform_device_count'))\n")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=_sub_env(XLA_FLAGS=f"{FLAG}=4", JAX_PLATFORMS="cpu"),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.split() == ["4", "4", "1"]


@pytest.mark.slow
def test_matrix_cell_invariants_8dev():
    """One real 8-device matrix cell end to end: ring-copy freedom,
    compressed DCN edges, census == analytic wire model."""
    out_json = os.path.join(REPO, "tests", ".m8_cell.json")
    try:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.matrix",
             "--devices", "8", "--cells", "m8-ambdg-qwen15-2x2x2-int8",
             "--json", out_json],
            env=_sub_env(JAX_PLATFORMS="cpu", **{ENV_VAR: "8"}),
            capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        with open(out_json) as f:
            result = json.load(f)
        (row,) = result["results"]
        inv = row["invariants"]
        assert inv["ok"]
        assert inv["ring_copies"]["violations"] == []
        assert inv["exchange"]["census_matches_model"]
        assert inv["exchange"]["compressed_edges"] is True
        assert inv["exchange"]["census_by_dtype"] == \
            inv["exchange"]["analytic_by_dtype"]
    finally:
        if os.path.exists(out_json):
            os.unlink(out_json)
