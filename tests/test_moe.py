"""MoE dispatch: einsum and gather implementations are numerically
equivalent; capacity dropping is deterministic; grouping preserves
results."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models.common import ParamFactory
from repro.models.moe import (moe_apply_einsum, moe_apply_gather, moe_init,
                              _capacity)


@pytest.fixture(scope="module")
def setup():
    cfg = C.get_smoke_config("mixtral-8x7b")
    f = ParamFactory(jax.random.PRNGKey(0))
    moe_init(f, cfg)
    return cfg, f.params["moe"]


def test_einsum_equals_gather(setup):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y1, a1 = moe_apply_einsum(p, cfg, x)
    y2, a2 = moe_apply_gather(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    assert float(a1) == float(a2)


def test_group_size_invariance(setup):
    """Full-capacity routing is group-size independent (no drops)."""
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
    big = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     dispatch_group=128))
    small = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     dispatch_group=32))
    y1, _ = moe_apply_einsum(p, big, x)
    y2, _ = moe_apply_einsum(p, small, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_capacity_rounding():
    cfg = C.get_smoke_config("mixtral-8x7b")
    c = _capacity(cfg, 4096)
    assert c % 8 == 0
    assert c >= 4096 * cfg.moe.top_k / cfg.moe.n_experts


def test_gradients_flow_through_router(setup):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model))

    def loss(p):
        y, aux = moe_apply_einsum(p, cfg, x)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["w_router"]))) > 0
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
