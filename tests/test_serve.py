"""Serving engine integration: continuous batched greedy decode."""
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serve.engine import Engine


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mixtral-8x7b"])
def test_generate(arch):
    cfg = C.get_smoke_config(arch)
    model = build_model(cfg)
    engine = Engine(model, batch_slots=3, max_len=48)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (4, 6, 5)]
    out = engine.generate(prompts, max_new=6)
    for i, o in enumerate(out):
        assert len(o) == len(prompts[i]) + 6
        assert all(0 <= t < cfg.padded_vocab_size for t in o)
    assert engine.stats.decode_tokens == 3 * 6


def test_greedy_is_deterministic():
    cfg = C.get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    prompts = [[5, 7, 9]]
    a = Engine(model, 1, 32).generate(prompts, max_new=5)
    b = Engine(model, 1, 32).generate(prompts, max_new=5)
    assert a == b
