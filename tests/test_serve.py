"""Serving subsystem: continuous batching, seeded request arrivals,
and the bounded-staleness weight-publication channel.

Property suite invariants (ISSUE 8):
  * queue conservation — every submitted request is, at any instant,
    exactly one of pending / in flight / completed;
  * staleness of every SERVED snapshot <= the configured bound, and a
    publish never overwrites a slot that is still servable
    (no-unread-overwrite);
  * int8-published weights dequantize BIT-identically to the
    gossip-path quantizer on the same rows;
  * ragged prompts decode identically batched vs solo (per-slot
    positions mean no padding exists to leak through the cache).

The golden serve trace (tests/golden/serve_trace.json) pins one seeded
admit/evict/publish schedule exactly — pure host bookkeeping (seeded
numpy + integer staleness), so it is platform-stable. Regenerate after
an INTENTIONAL scheduler/publisher change with:

    PYTHONPATH=src python tests/test_serve.py --regen

``REPRO_TEST_SERVE`` (comma-separated arrival-process names) narrows
the arrival-parametrized tests — the CI serve matrix runs one process
per leg; unset locally, everything runs.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import ServeConfig
from repro.core.arena import flatten_tree, make_layout
from repro.models import build_model
from repro.optim.compression import (dequantize_int8_rows,
                                     quantize_int8_rows)
from repro.serve import (Engine, RequestQueue, WeightPublisher,
                         make_arrival_process, publish_ring_slots)

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "serve_trace.json")

ARRIVALS = tuple(
    a for a in os.environ.get("REPRO_TEST_SERVE",
                              "poisson,bursty").split(",") if a)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mixtral-8x7b"])
def test_generate(arch):
    cfg = C.get_smoke_config(arch)
    model = build_model(cfg)
    engine = Engine(model, batch_slots=3, max_len=48)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (4, 6, 5)]
    out = engine.generate(prompts, max_new=6)
    for i, o in enumerate(out):
        assert len(o) == len(prompts[i]) + 6
        assert all(0 <= t < cfg.padded_vocab_size for t in o)
    assert engine.stats.decode_tokens == 3 * 6


def test_greedy_is_deterministic():
    cfg = C.get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    prompts = [[5, 7, 9]]
    a = Engine(model, 1, 32).generate(prompts, max_new=5)
    b = Engine(model, 1, 32).generate(prompts, max_new=5)
    assert a == b


def test_stats_count_active_slots_only():
    """ISSUE 8 satellite: 2 live requests in 3 slots must count 2
    slots' worth of tokens, not 3 (the seed added ``self.slots`` per
    step regardless of occupancy)."""
    cfg = C.get_smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    engine = Engine(model, batch_slots=3, max_len=48)
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (4, 6)]
    engine.generate(prompts, max_new=3)
    assert engine.stats.decode_tokens == 2 * 3
    assert engine.stats.prefill_tokens == (4 - 1) + (6 - 1)


def test_ragged_prompt_equivalence():
    """ISSUE 8 satellite: batched ragged prompts == each prompt decoded
    solo. Per-slot positions start at 0 on admit, so no padding exists
    and a slot's validity mask covers only its own cache writes —
    shorter prompts can't see pad zeros (the seed's left-pad bug) or a
    neighbour's positions. Dense arch: rows are batch-independent."""
    cfg = C.get_smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (3, 7, 5)]
    batched = Engine(model, 3, 48).generate(prompts, max_new=6)
    for i, p in enumerate(prompts):
        solo = Engine(model, 1, 48).generate([p], max_new=6)
        assert batched[i] == solo[0], f"prompt {i} diverges from solo"


@pytest.mark.parametrize("arrival", ARRIVALS)
def test_queue_conservation(arrival):
    """Every submitted request is exactly one of pending / in flight /
    completed, at every step of a seeded serve run."""
    cfg = C.get_smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    sc = ServeConfig(slots=3, max_len=24, max_new=3, arrival=arrival,
                     arrival_rate=0.8, prompt_len_min=2,
                     prompt_len_max=5, seed=3)
    engine = Engine(model, sc.slots, sc.max_len)
    queue = RequestQueue(sc, cfg.vocab_size)
    for _ in range(32):
        queue.step()
        engine.step(queue)
        assert queue.submitted == (len(queue) + engine.in_flight
                                   + engine.stats.completed)
    assert engine.stats.completed > 0          # the run did real work
    assert engine.stats.admitted == engine.in_flight + \
        engine.stats.completed


@pytest.mark.parametrize("arrival", ARRIVALS)
def test_arrival_and_queue_state_roundtrip(arrival):
    """Restart exactness: the remaining arrival sequence AND the
    pending queue survive a state_dict/load_state_dict cycle."""
    cfg = C.get_smoke_config("qwen1.5-0.5b")
    sc = ServeConfig(arrival=arrival, arrival_rate=1.1, seed=9)
    proc = make_arrival_process(sc)
    proc.sequence(7)
    snap = proc.state_dict()
    want = proc.sequence(10).tolist()
    fresh = make_arrival_process(sc)
    fresh.load_state_dict(snap)
    assert fresh.sequence(10).tolist() == want

    q = RequestQueue(sc, cfg.vocab_size)
    for _ in range(6):
        q.step()
    snap = q.state_dict()
    q2 = RequestQueue(sc, cfg.vocab_size)
    q2.load_state_dict(snap)
    for _ in range(6):
        assert q.step() == q2.step()
    assert [(r.rid, r.prompt) for r in q._pending] == \
        [(r.rid, r.prompt) for r in q2._pending]
    assert (q.submitted, q.next_rid) == (q2.submitted, q2.next_rid)


def _tiny_params(key):
    """A small multi-leaf tree exercising padding + multi-row leaves."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(k1, (9,)),
        "b": jax.random.normal(k2, (33, 7)),
        "c": jax.random.normal(k3, (140,)),
    }


def test_publisher_bit_identical_to_gossip_quantizer():
    """ISSUE 8 acceptance: the published int8 payload + bf16 scales are
    byte-identical to ``quantize_int8_rows`` (the gossip wire format)
    on the same arena rows, and the popped tree dequantizes through the
    exact same q.f32 * scale.f32 product."""
    params = _tiny_params(jax.random.PRNGKey(4))
    layout = make_layout(params)
    sc = ServeConfig(publish_period=1, staleness_bound=2)
    pub = WeightPublisher(layout, sc)
    k = pub.publish(params, step=0)

    w = flatten_tree(layout, params)
    q_want, s_want = quantize_int8_rows(w, scale_dtype=jnp.bfloat16)
    bits = lambda x: np.asarray(        # noqa: E731  (bf16 has no npy dtype)
        jax.lax.bitcast_convert_type(x, jnp.uint16))
    np.testing.assert_array_equal(np.asarray(pub.ring[k]),
                                  np.asarray(q_want))
    np.testing.assert_array_equal(bits(pub.scales[k]), bits(s_want))

    popped, stale = pub.pop(now=0)
    assert stale == 0
    want_rows = dequantize_int8_rows(q_want, s_want)
    np.testing.assert_array_equal(
        np.asarray(flatten_tree(layout, popped)), np.asarray(want_rows))


def test_publisher_staleness_bound_and_no_unread_overwrite():
    """Property pair: (1) every successful pop reports staleness within
    [0, bound] and returns exactly the snapshot published at the
    freshest due step; (2) a publish only ever overwrites a slot whose
    snapshot has already expired (age > bound at overwrite time) — the
    ring-depth construction ``bound // period + 1``."""
    params = _tiny_params(jax.random.PRNGKey(5))
    layout = make_layout(params)
    period, bound = 2, 5
    sc = ServeConfig(publish_period=period, staleness_bound=bound)
    pub = WeightPublisher(layout, sc)
    assert pub.n_slots == publish_ring_slots(sc) == bound // period + 1

    published = {}                     # master step -> payload tree
    rng = np.random.default_rng(6)
    for step in range(0, 24, period):
        k = pub.seq % pub.n_slots
        old = int(pub.pub_step[k])
        if old >= 0:                   # (2) overwritten -> expired
            assert step - old > bound, (step, old)
        tree = jax.tree.map(
            lambda a: a + rng.standard_normal(a.shape).astype(a.dtype),
            params)
        pub.publish(tree, step)
        published[step] = tree
        for now in range(step, step + period):
            got, stale = pub.pop(now)
            assert got is not None and 0 <= stale <= bound
            src = published[now - stale]   # (1) exactly that snapshot
            want = quantize_int8_rows(flatten_tree(layout, src),
                                      scale_dtype=jnp.bfloat16)
            got_rows = flatten_tree(layout, got)
            np.testing.assert_array_equal(
                np.asarray(got_rows),
                np.asarray(dequantize_int8_rows(*want)))
    # nothing due before the first publish or after everything expires
    fresh = WeightPublisher(layout, sc)
    assert fresh.pop(0) == (None, None) and fresh.misses == 1
    got, stale = pub.pop(22 + bound + period + 1)
    assert got is None and stale is None


def test_publisher_state_roundtrip():
    """The publish ring (including bf16 scales, carried as u16 bits)
    and its staleness metadata survive a checkpoint cycle."""
    params = _tiny_params(jax.random.PRNGKey(7))
    layout = make_layout(params)
    sc = ServeConfig(publish_period=2, staleness_bound=4)
    pub = WeightPublisher(layout, sc)
    pub.publish(params, 0)
    pub.publish(jax.tree.map(lambda a: 2 * a, params), 2)
    pub.pop(3)
    fresh = WeightPublisher(layout, sc)
    fresh.load_state_dict(pub.state_dict())
    a, sa = pub.pop(3)
    b, sb = fresh.pop(3)
    assert sa == sb
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)
    np.testing.assert_array_equal(pub.pub_step, fresh.pub_step)
    assert pub.seq == fresh.seq


def _golden_trace():
    """One seeded serve run: publish every 3 master steps, the engine
    refreshes every 5 (so observed staleness cycles through nonzero
    values), Poisson arrivals. The trace is host bookkeeping only —
    admits/evicts/queue depth/staleness are platform-exact."""
    cfg = C.get_smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    sc = ServeConfig(slots=3, max_len=32, max_new=4, arrival="poisson",
                     arrival_rate=0.7, publish_period=3,
                     staleness_bound=6, prompt_len_min=2,
                     prompt_len_max=6, seed=5)
    engine = Engine(model, sc.slots, sc.max_len)
    queue = RequestQueue(sc, cfg.vocab_size)
    pub = WeightPublisher(make_layout(engine.params), sc)
    engine.attach_publisher(pub)
    rows = []
    for t in range(40):
        slot = pub.publish(engine.params, t) \
            if t % sc.publish_period == 0 else -1
        stale = engine.refresh_weights(t) if t % 5 == 0 else None
        arrived = queue.step()
        ev = engine.step(queue)
        rows.append({"step": t, "arrived": arrived,
                     "admits": ev["admits"], "evicts": ev["evicts"],
                     "active": ev["active"], "queued": ev["queued"],
                     "publish_slot": slot, "staleness": stale})
    return rows, engine, sc


def test_golden_serve_trace():
    rows, engine, sc = _golden_trace()
    # acceptance: every served snapshot satisfies the bound
    served = [r["staleness"] for r in rows if r["staleness"] is not None]
    assert served and all(0 <= s <= sc.staleness_bound for s in served)
    assert any(s > 0 for s in served)          # bound actually exercised
    assert engine.stats.staleness_max <= sc.staleness_bound
    with open(GOLDEN) as f:
        want = json.load(f)
    assert rows == want["trace"]
    assert engine.stats.completed == want["completed"]
    assert engine.stats.admitted == want["admitted"]


def _regen():
    rows, engine, _ = _golden_trace()
    with open(GOLDEN, "w") as f:
        json.dump({"trace": rows,
                   "completed": engine.stats.completed,
                   "admitted": engine.stats.admitted}, f, indent=1)
    print(f"wrote {GOLDEN} ({len(rows)} steps, "
          f"{engine.stats.completed} completed)")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        sys.exit(pytest.main([__file__, "-q"]))
