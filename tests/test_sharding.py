"""Sharding resolver unit tests: divisibility fallback, no double axis
use, train vs serve profiles."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig
from repro.dist.sharding import spec_for

MC = MeshConfig(n_pods=1, data=16, model=16)
MC2 = MeshConfig(n_pods=2, data=16, model=16)


def test_fsdp_tp_weights():
    # (d_model, d_ff): FSDP x TP
    assert spec_for(("embed", "mlp"), (4096, 14336), MC) == P("data", "model")


def test_divisibility_fallback():
    # kv heads 4 don't divide model=16 -> replicated
    assert spec_for(("layers", "batch", "kv_seq", "heads", None),
                    (32, 128, 32768, 4, 128), MC) == \
        P(None, "data", "model")
    # batch=1 (long_500k): falls through to kv_seq on data
    assert spec_for(("layers", "batch", "kv_seq", "heads", None),
                    (32, 1, 524288, 4, 128), MC) == \
        P(None, None, "data")


def test_no_double_axis_use():
    # both dims want 'data' -> only the first gets it
    s = spec_for(("embed", "embed"), (4096, 4096), MC)
    assert s == P("data")  # trailing None trimmed


def test_pod_axis_multi_pod():
    s = spec_for((None, "pod", "embed", "mlp"), (2, 2, 4096, 1024), MC2)
    assert s == P(None, "pod", "data", "model")
    # single pod: "pod" resolves to nothing
    s1 = spec_for(("pod", "embed"), (1, 4096), MC)
    assert s1 == P(None, "data")


def test_batch_uses_pod_and_data():
    s = spec_for(("batch", None), (256, 4096), MC2)
    assert s == P(("pod", "data"))


def test_serve_profile_replicates_embed():
    assert spec_for(("embed", "mlp"), (4096, 14336), MC,
                    profile="serve") == P(None, ("data", "model"))
    assert spec_for(("embed",), (4096,), MC, profile="serve") == P()


def test_vocab_sharding():
    assert spec_for(("embed", "vocab"), (4096, 64000), MC) == \
        P("data", "model")
