"""Seeded golden-trace regression for the cluster simulator.

A short AMB vs AMB-DG linear-regression run (fixed seeds, small
config) must keep producing the trace committed in
``tests/golden/sim_trace.json`` — the simulator is what reproduces the
paper's Fig. 2 wall-clock behavior, and refactors of the event loop /
timing model / dual-averaging plumbing can silently shift it.

Wall-clock times, epoch indices, minibatch counts and staleness come
from pure Python/numpy bookkeeping and must match EXACTLY; error
values go through jax compute and are compared at tolerance (the
golden file pins behavior, not one XLA build's rounding).

Regenerate (after an INTENTIONAL simulator change) with:

    PYTHONPATH=src python tests/test_sim_golden.py --regen
"""
import json
import os

import numpy as np
import pytest

from repro.configs.base import AmbdgConfig, LINREG, ModelConfig
from repro.data.timing import ShiftedExponential
from repro.sim import SimProblem, simulate_anytime

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "sim_trace.json")


def _run_traces():
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=64)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=180.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(64)))
    out = {}
    for scheme in ("ambdg", "amb"):
        trace = simulate_anytime(
            SimProblem(cfg, n_workers=3, seed=7, b_max=128),
            t_p=2.5, t_c=10.0, total_time=60.0, timing=timing,
            opt_cfg=opt, scheme=scheme, rng_seed=11)
        out[scheme] = {
            "times": [round(t, 9) for t in trace.times],
            "epochs": list(trace.epochs),
            "errors": [float(e) for e in trace.errors],
            "minibatches": [float(b) for b in trace.minibatches],
            "staleness": [int(s) for s in trace.staleness],
        }
    return out


def test_sim_trace_matches_golden():
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = _run_traces()
    assert set(got) == set(golden)
    for scheme, g in golden.items():
        t = got[scheme]
        # the timeline itself: exact (pure Python float arithmetic)
        assert t["times"] == g["times"], scheme
        assert t["epochs"] == g["epochs"], scheme
        # anytime minibatch draws: exact (seeded numpy)
        assert t["minibatches"] == g["minibatches"], scheme
        # deterministic staleness: tau after fill for ambdg, 0 for amb
        assert t["staleness"] == g["staleness"], scheme
        # error curve: through jax compute -> tolerance
        np.testing.assert_allclose(t["errors"], g["errors"],
                                   rtol=1e-4, atol=1e-7,
                                   err_msg=scheme)
    # the paper's qualitative Fig-2 contract, pinned alongside the
    # numbers: AMB-DG fits ~(T_p + T_c)/T_p times more updates into
    # the same wall clock than synchronous AMB
    assert len(golden["ambdg"]["times"]) > 3 * len(golden["amb"]["times"])


if __name__ == "__main__":
    import sys
    if "--regen" not in sys.argv:
        sys.exit("refusing to overwrite the golden trace without --regen")
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    with open(GOLDEN, "w") as f:
        json.dump(_run_traces(), f, indent=1)
    print(f"wrote {GOLDEN}")
