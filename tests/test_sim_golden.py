"""Seeded golden-trace regression for the cluster simulator — and for
the on-device decentralized strategy (compressed + uncompressed
gossip).

A short AMB vs AMB-DG linear-regression run (fixed seeds, small
config) must keep producing the trace committed in
``tests/golden/sim_trace.json`` — the simulator is what reproduces the
paper's Fig. 2 wall-clock behavior, and refactors of the event loop /
timing model / dual-averaging plumbing can silently shift it.
``tests/golden/decentralized_trace.json`` pins the decentralized
strategy the same way: a seeded run per gossip compression mode
("none" / "int8"), with the timeline column from the strategy's
TimelineModel closed form.

Wall-clock times, epoch indices, minibatch counts and staleness come
from pure Python/numpy bookkeeping and must match EXACTLY; error
values go through jax compute and are compared at tolerance (the
golden file pins behavior, not one XLA build's rounding).

Regenerate (after an INTENTIONAL simulator/strategy change) with:

    PYTHONPATH=src python tests/test_sim_golden.py --regen
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs.base import (AmbdgConfig, ConsensusConfig, LINREG,
                                MeshConfig, ModelConfig, RunConfig,
                                TRAIN_4K)
from repro.data.timing import ShiftedExponential
from repro.sim import SimProblem, simulate_anytime

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "sim_trace.json")
GOLDEN_DEC = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden", "decentralized_trace.json")
GOLDEN_STOCH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "golden", "stochastic_trace.json")
GOLDEN_BS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "golden", "batch_schedule_trace.json")


def _run_traces():
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=64)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=180.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(64)))
    out = {}
    for scheme in ("ambdg", "amb"):
        trace = simulate_anytime(
            SimProblem(cfg, n_workers=3, seed=7, b_max=128),
            t_p=2.5, t_c=10.0, total_time=60.0, timing=timing,
            opt_cfg=opt, scheme=scheme, rng_seed=11)
        out[scheme] = {
            "times": [round(t, 9) for t in trace.times],
            "epochs": list(trace.epochs),
            "errors": [float(e) for e in trace.errors],
            "minibatches": [float(b) for b in trace.minibatches],
            "staleness": [int(s) for s in trace.staleness],
        }
    return out


def test_sim_trace_matches_golden():
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = _run_traces()
    assert set(got) == set(golden)
    for scheme, g in golden.items():
        t = got[scheme]
        # the timeline itself: exact (pure Python float arithmetic)
        assert t["times"] == g["times"], scheme
        assert t["epochs"] == g["epochs"], scheme
        # anytime minibatch draws: exact (seeded numpy)
        assert t["minibatches"] == g["minibatches"], scheme
        # deterministic staleness: tau after fill for ambdg, 0 for amb
        assert t["staleness"] == g["staleness"], scheme
        # error curve: through jax compute -> tolerance
        np.testing.assert_allclose(t["errors"], g["errors"],
                                   rtol=1e-4, atol=1e-7,
                                   err_msg=scheme)
    # the paper's qualitative Fig-2 contract, pinned alongside the
    # numbers: AMB-DG fits ~(T_p + T_c)/T_p times more updates into
    # the same wall clock than synchronous AMB
    assert len(golden["ambdg"]["times"]) > 3 * len(golden["amb"]["times"])


def _run_stochastic_traces():
    """Seeded stochastic-delay runs of both simulator engines under the
    ``heavy_tail`` process: AMB-DG (per-epoch downlink staleness) and
    k-batch (per-message uplink jitter). The emitted delay sequence,
    the timeline, epochs, minibatch draws and staleness log are pure
    Python/numpy — pinned EXACTLY; error curves go through jax and are
    pinned at tolerance. This is the delay-process twin of the fixed
    golden traces above: any refactor of the delay subsystem, the
    seeded draws, or the event loop shows up here."""
    from repro.configs.base import DelayConfig
    from repro.core.delay_process import make_delay_process
    from repro.sim import simulate_kbatch

    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=64)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=180.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(64)))
    dcfg = DelayConfig(process="heavy_tail", tau_max=6, seed=13)
    # adaptive_alpha is part of the pinned regime: since the
    # sim/device alpha-drift fix, simulate_anytime steps with the
    # OBSERVED staleness of what each update applies (the same knob
    # the device path honors), so the error column depends on it
    out = {"delay_config": {"process": dcfg.process,
                            "tau_max": dcfg.tau_max, "seed": dcfg.seed,
                            "adaptive_alpha": dcfg.adaptive_alpha}}

    trace = simulate_anytime(
        SimProblem(cfg, n_workers=3, seed=7, b_max=128),
        t_p=2.5, t_c=10.0, total_time=60.0, timing=timing,
        opt_cfg=opt, scheme="ambdg", rng_seed=11,
        delay_process=make_delay_process(dcfg, opt.staleness))
    out["ambdg"] = {
        "times": [round(t, 9) for t in trace.times],
        "epochs": list(trace.epochs),
        "delays": [int(d) for d in trace.delays],
        "staleness": [int(s) for s in trace.staleness],
        "minibatches": [float(b) for b in trace.minibatches],
        "errors": [float(e) for e in trace.errors],
    }

    trace = simulate_kbatch(
        SimProblem(cfg, n_workers=3, seed=7, b_max=128),
        b_per_msg=32, K=3, t_c=10.0, total_time=60.0, timing=timing,
        opt_cfg=opt, rng_seed=11,
        delay_process=make_delay_process(dcfg, opt.staleness), t_p=2.5)
    out["kbatch"] = {
        "times": [round(t, 9) for t in trace.times],
        "epochs": list(trace.epochs),
        "delays": [int(d) for d in trace.delays],
        "staleness": [int(s) for s in trace.staleness],
        "errors": [float(e) for e in trace.errors],
    }
    return out


def test_stochastic_trace_matches_golden():
    with open(GOLDEN_STOCH) as f:
        golden = json.load(f)
    got = _run_stochastic_traces()
    assert set(got) == set(golden)
    assert got["delay_config"] == golden["delay_config"]
    for scheme in ("ambdg", "kbatch"):
        t, g = got[scheme], golden[scheme]
        # the seeded delay sequence itself: exact (THE pinned artifact)
        assert t["delays"] == g["delays"], scheme
        # timeline + bookkeeping: exact (pure Python/numpy)
        assert t["times"] == g["times"], scheme
        assert t["epochs"] == g["epochs"], scheme
        assert t["staleness"] == g["staleness"], scheme
        if "minibatches" in g:
            assert t["minibatches"] == g["minibatches"], scheme
        # error curve: through jax compute -> tolerance
        np.testing.assert_allclose(t["errors"], g["errors"],
                                   rtol=1e-4, atol=1e-7, err_msg=scheme)
    # qualitative contracts pinned alongside the numbers: the heavy
    # tail actually bites (draws beyond the fixed tau, staleness
    # jitters instead of saturating) yet AMB-DG's update cadence is
    # unchanged — wall-clock robustness is the subsystem's point
    g = golden["ambdg"]
    assert max(g["delays"]) > 4 and min(g["delays"]) >= 1
    assert len(set(g["staleness"])) > 1
    assert g["times"] == [round(t * 2.5 + 5.0, 9)
                          for t in g["epochs"]]


def _run_batch_schedule_traces():
    """Seeded adaptive-minibatch runs of both simulator engines: AMB-DG
    under the adadamp controller composed with the heavy_tail delay
    process (adaptive alpha takes BOTH the observed staleness and the
    scheduled b(t)), and k-batch under the linear ramp with per-job
    target draws. The target sequence, the resulting minibatch counts,
    the timeline and the clamp column are pure Python/numpy — pinned
    EXACTLY (this is what the schedule subsystem promises to keep);
    error curves go through jax and are pinned at tolerance."""
    from repro.configs.base import BatchScheduleConfig, DelayConfig
    from repro.core.batch_schedule import make_batch_schedule
    from repro.core.delay_process import make_delay_process
    from repro.sim import simulate_kbatch

    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=64)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    opt = AmbdgConfig(t_p=2.5, t_c=10.0, tau=4, smoothness_L=1.0,
                      b_bar=180.0, proximal="l2_ball",
                      radius_C=float(1.05 * np.sqrt(64)))
    dcfg = DelayConfig(process="heavy_tail", tau_max=6, seed=13)
    ada = BatchScheduleConfig(schedule="adadamp", b0=12, b_cap=96,
                              growth_factor=2.0, ema=0.3, seed=5)
    lin = BatchScheduleConfig(schedule="linear", b0=16, b_cap=128,
                              growth_rate=2.0, seed=5)
    out = {"schedule_config": {"anytime": ada.schedule,
                               "kbatch": lin.schedule,
                               "b0": [ada.b0, lin.b0],
                               "b_cap": [ada.b_cap, lin.b_cap],
                               "seed": ada.seed}}

    trace = simulate_anytime(
        SimProblem(cfg, n_workers=3, seed=7, b_max=128),
        t_p=2.5, t_c=10.0, total_time=60.0, timing=timing,
        opt_cfg=opt, scheme="ambdg", rng_seed=11,
        delay_process=make_delay_process(dcfg, opt.staleness),
        batch_schedule=make_batch_schedule(ada, opt.b_bar,
                                           opt.staleness))
    out["ambdg"] = {
        "times": [round(t, 9) for t in trace.times],
        "targets": [int(b) for b in trace.targets],
        "minibatches": [float(b) for b in trace.minibatches],
        "delays": [int(d) for d in trace.delays],
        "staleness": [int(s) for s in trace.staleness],
        "clamps": [int(c) for c in trace.clamps],
        "errors": [float(e) for e in trace.errors],
    }

    trace = simulate_kbatch(
        SimProblem(cfg, n_workers=3, seed=7, b_max=128),
        b_per_msg=32, K=3, t_c=10.0, total_time=60.0, timing=timing,
        opt_cfg=opt, rng_seed=11, t_p=2.5,
        batch_schedule=make_batch_schedule(lin, opt.b_bar,
                                           opt.staleness))
    out["kbatch"] = {
        "times": [round(t, 9) for t in trace.times],
        "targets": [int(b) for b in trace.targets],
        "staleness": [int(s) for s in trace.staleness],
        "clamps": [int(c) for c in trace.clamps],
        "errors": [float(e) for e in trace.errors],
    }
    return out


def test_batch_schedule_trace_matches_golden():
    with open(GOLDEN_BS) as f:
        golden = json.load(f)
    got = _run_batch_schedule_traces()
    assert set(got) == set(golden)
    assert got["schedule_config"] == golden["schedule_config"]
    for scheme in ("ambdg", "kbatch"):
        t, g = got[scheme], golden[scheme]
        # the emitted target sequence: exact (THE pinned artifact)
        assert t["targets"] == g["targets"], scheme
        # timeline + bookkeeping: exact (pure Python/numpy)
        assert t["times"] == g["times"], scheme
        assert t["staleness"] == g["staleness"], scheme
        assert t["clamps"] == g["clamps"], scheme
        if "minibatches" in g:
            assert t["minibatches"] == g["minibatches"], scheme
        if "delays" in g:
            assert t["delays"] == g["delays"], scheme
        # error curve: through jax compute -> tolerance
        np.testing.assert_allclose(t["errors"], g["errors"],
                                   rtol=1e-4, atol=1e-7, err_msg=scheme)
    # qualitative contracts pinned alongside the numbers: strict mode
    # admits no capacity clamps; the anytime targets actually split
    # into the applied minibatch (count == b(t) every update); the
    # closed-loop schedules genuinely move
    g = golden["ambdg"]
    assert all(c == 0 for c in g["clamps"])
    assert g["minibatches"] == [float(b) for b in g["targets"]]
    assert all(b <= a for a, b in zip(g["targets"][1:],
                                      g["targets"][:-1]))  # monotone
    gk = golden["kbatch"]
    assert len(set(gk["targets"])) > 1             # the ramp ramps
    assert all(c == 0 for c in gk["clamps"])


def _run_decentralized_traces():
    """A seeded 8-step decentralized run per gossip compression mode:
    4 workers on a ring, r=3 rounds, the DENSE fold (pinned, so the
    trace is independent of the local device count). The timeline
    column applies the strategy's TimelineModel closed form — the
    exact float algebra the Strategy API promises to keep."""
    import jax

    import repro.api as api
    from repro.models import build_model

    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                      linreg_dim=32)
    model = build_model(cfg)
    batch, n, t_p, t_c = 32, 4, 2.5, 10.0
    out = {}
    for compression in ("none", "int8"):
        rc = RunConfig(
            model=cfg,
            shape=dataclasses.replace(TRAIN_4K, seq_len=0,
                                      global_batch=batch),
            mesh=MeshConfig(n_pods=1, data=1, model=1),
            ambdg=AmbdgConfig(t_p=t_p, t_c=t_c, tau=1, n_microbatches=2,
                              b_bar=float(batch), smoothness_L=1.0),
            strategy="decentralized",
            consensus=ConsensusConfig(topology="ring", n_workers=n,
                                      rounds=3, gossip_impl="dense",
                                      compression=compression))
        s = api.build(model, rc)
        tm = type(s).timeline_model()
        state = s.init_state(jax.random.PRNGKey(rc.seed))
        step = jax.jit(s.train_step, donate_argnums=(0,))
        times, steps, cons_errs, losses = [], [], [], []
        for t in range(1, 9):
            b = model.dummy_batch(batch, key=jax.random.PRNGKey(1000 + t))
            state, m = step(state, b)
            times.append(round(tm.update_time(t, t_p, t_c), 9))
            steps.append(int(m["step"]))
            cons_errs.append(float(m["consensus_error"]))
            losses.append(float(m["loss"]))
        out[compression] = {
            "rounds": s.rounds, "times": times, "steps": steps,
            "consensus_errors": cons_errs, "losses": losses,
        }
    return out


def test_decentralized_trace_matches_golden():
    with open(GOLDEN_DEC) as f:
        golden = json.load(f)
    got = _run_decentralized_traces()
    assert set(got) == set(golden) == {"none", "int8"}
    for compression, g in golden.items():
        t = got[compression]
        # timeline + step counters: exact (pure Python/closed form)
        assert t["times"] == g["times"], compression
        assert t["steps"] == g["steps"], compression
        assert t["rounds"] == g["rounds"], compression
        # consensus error + loss: through jax compute -> tolerance
        np.testing.assert_allclose(t["consensus_errors"],
                                   g["consensus_errors"],
                                   rtol=1e-4, atol=1e-7,
                                   err_msg=compression)
        np.testing.assert_allclose(t["losses"], g["losses"],
                                   rtol=1e-4, atol=1e-7,
                                   err_msg=compression)
    # qualitative contract, pinned alongside the numbers: int8's
    # error feedback keeps its consensus error in the same regime as
    # the uncompressed exchange (not drifting off across steps)
    assert (golden["int8"]["consensus_errors"][-1]
            <= 2 * golden["none"]["consensus_errors"][-1]
            + 1e-6)


if __name__ == "__main__":
    import sys
    if "--regen" not in sys.argv:
        sys.exit("refusing to overwrite the golden trace without --regen")
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    with open(GOLDEN, "w") as f:
        json.dump(_run_traces(), f, indent=1)
    print(f"wrote {GOLDEN}")
    with open(GOLDEN_DEC, "w") as f:
        json.dump(_run_decentralized_traces(), f, indent=1)
    print(f"wrote {GOLDEN_DEC}")
    with open(GOLDEN_STOCH, "w") as f:
        json.dump(_run_stochastic_traces(), f, indent=1)
    print(f"wrote {GOLDEN_STOCH}")
    with open(GOLDEN_BS, "w") as f:
        json.dump(_run_batch_schedule_traces(), f, indent=1)
    print(f"wrote {GOLDEN_BS}")
